"""The paper's application: knot screening + knot-core localization."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.apps import knots
from repro.core import Broker, MonitorAgent, Submitter, WorkerAgent


def test_screen_separates_knots_from_coils():
    ids = list(range(32))
    coords, truth = knots.synthesize_batch(ids, n_points=128)
    wr, acn, _ = knots.writhe_and_acn(jnp.asarray(coords))
    wr = np.asarray(wr)
    deep = []
    for w, t in zip(wr, truth):
        if t in ("trefoil", "cinquefoil"):
            assert abs(w) >= knots.WRITHE_KNOT_THRESHOLD, (t, w)
        elif t == "deep_trefoil":
            deep.append(abs(w))
        else:
            assert abs(w) < knots.WRITHE_KNOT_THRESHOLD, (t, w)
    # open-chain knot detection is probabilistic (paper §4: random-closure
    # percentages); deep knots with wandering tails occasionally screen low.
    rate = np.mean([d >= knots.WRITHE_KNOT_THRESHOLD for d in deep])
    assert rate >= 0.75, (rate, deep)


def test_figure8_is_writhe_blind():
    """Documented limitation: the figure-8 knot is amphichiral (Wr ≈ 0), so a
    writhe screen cannot see it — the reason the paper's production pipeline
    computes HOMFLY-PT polynomials rather than geometric invariants."""
    f8 = knots.figure8(160)
    wr, _, _ = knots.writhe_and_acn(jnp.asarray(f8[None]))
    assert abs(float(wr[0])) < knots.WRITHE_KNOT_THRESHOLD


def test_knot_core_localizes_deep_knot():
    """For a deep knot (coil–trefoil–coil) the detected core must overlap the
    embedded trefoil and exclude most of the tails (the paper's deep/shallow
    distinction)."""
    n, core_len = 192, 96
    chain = knots.deep_knot(n, core=core_len, seed=5)
    _, _, wmap = knots.writhe_and_acn(jnp.asarray(chain[None]))
    core = knots.knot_core(np.asarray(wmap)[0])
    assert core is not None
    a, b = core
    tail = (n - core_len) // 2
    true_a, true_b = tail, tail + core_len
    overlap = max(0, min(b, true_b) - max(a, true_a))
    assert overlap > core_len * 0.7, (core, (true_a, true_b))
    assert (b - a) < n * 0.85  # tails were trimmed


def test_unknot_has_no_core():
    coil = knots.random_coil(128, seed=11)
    _, _, wmap = knots.writhe_and_acn(jnp.asarray(coil[None]))
    assert knots.knot_core(np.asarray(wmap)[0]) is None


def test_knot_campaign_end_to_end():
    """The AlphaKnot campaign in miniature: batched submission through KSA,
    load-balanced across two agents, results aggregated at the monitor."""
    broker = Broker(default_partitions=4)
    sub = Submitter(broker, "kn")
    mon = MonitorAgent(broker, "kn", poll_interval_s=0.01).start()
    a1 = WorkerAgent(broker, "kn", slots=1, poll_interval_s=0.01).start()
    a2 = WorkerAgent(broker, "kn", slots=1, poll_interval_s=0.01).start()
    try:
        ids = list(range(48))
        task_ids = sub.submit_batches("knot_batch", ids, batch_size=12,
                                      params={"n_points": 96,
                                              "stage2": True})
        assert len(task_ids) == 4
        assert mon.wait_all(task_ids, timeout=240.0)
        knotted = []
        processed = kept = 0
        for t in task_ids:
            r = mon.task(t).result
            knotted += r["knotted"]
            processed += r["processed"]
            kept += r["kept"]
        assert processed == 48
        assert kept <= 48
        # knotted population is ids % 4 in {0, 2, 3} (minus quality drops)
        assert all(i % 4 in (0, 2, 3) for i in knotted)
        assert len(knotted) >= kept * 0.4
        assert a1.tasks_completed + a2.tasks_completed == 4
    finally:
        a1.stop()
        a2.stop()
        mon.stop()
        broker.close()
