"""Serving-tier tests: flash-decode kernel parity (dense + paged, Pallas
interpret vs XLA lowering vs a naive oracle), page-allocator invariants,
paged/flash engine parity against whole-sequence greedy decoding,
eviction-mid-generation resume, and the replicated router's SLO admission
and exact request accounting."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.kernels.flash_decode import (flash_decode, flash_decode_paged,
                                        flash_decode_paged_xla,
                                        flash_decode_xla)
from repro.models import init_params, model_spec
from repro.models.transformer import forward
from repro.obs.metrics import MetricsRegistry
from repro.serve import (PageAllocator, ServeEngine, ServeReplicaSet,
                         register_serve_metrics, ttft_slo)


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("stablelm_1_6b")
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0),
                         jnp.dtype(cfg.dtype))
    return cfg, params


def _greedy_reference(cfg, params, prompt, max_new):
    toks = list(prompt)
    for _ in range(max_new):
        logits, _, _ = forward(params, cfg,
                               {"tokens": jnp.asarray([toks], jnp.int32)})
        logits = logits[0, -1, :cfg.vocab_size]
        toks.append(int(jnp.argmax(logits)))
    return toks[len(prompt):]


# ---------------------------------------------------------------------------
# kernel parity: dense flash-decode
# ---------------------------------------------------------------------------

def _oracle(q, k, v, qpos, kpos, window=None):
    """Naive per-(slot, head) softmax attention over valid key positions."""
    q, k, v = np.asarray(q), np.asarray(k), np.asarray(v)
    qpos, kpos = np.asarray(qpos), np.asarray(kpos)
    b, _, h, dk = q.shape
    g = h // k.shape[2]
    out = np.zeros((b, 1, h, v.shape[3]), np.float32)
    for bi in range(b):
        mask = (kpos[bi] >= 0) & (kpos[bi] <= qpos[bi])
        if window is not None:
            mask &= kpos[bi] > qpos[bi] - window
        if not mask.any():
            continue
        for hi in range(h):
            s = (k[bi, mask, hi // g] @ q[bi, 0, hi]) * dk ** -0.5
            w = np.exp(s - s.max())
            w /= w.sum()
            out[bi, 0, hi] = w @ v[bi, mask, hi // g]
    return out


def _rand_qkv(rng, b, s, h, kh, dk, dv=None):
    dv = dk if dv is None else dv
    return (jnp.asarray(rng.standard_normal((b, 1, h, dk)), jnp.float32),
            jnp.asarray(rng.standard_normal((b, s, kh, dk)), jnp.float32),
            jnp.asarray(rng.standard_normal((b, s, kh, dv)), jnp.float32))


def _dense_kpos(qpos, s):
    """Contiguous-cache positions: slot index = position, -1 past the end."""
    pos = np.tile(np.arange(s, dtype=np.int32), (len(qpos), 1))
    return jnp.asarray(np.where(pos <= np.asarray(qpos)[:, None], pos, -1))


@pytest.mark.parametrize("kh", [4, 2, 1])  # GQA group sizes 1, 2, 4
def test_flash_decode_parity_causal_ragged(kh):
    rng = np.random.default_rng(0)
    q, k, v = _rand_qkv(rng, b=3, s=96, h=4, kh=kh, dk=16)
    qpos = jnp.asarray([5, 40, 95], jnp.int32)  # ragged occupancy
    kpos = _dense_kpos(qpos, 96)
    ref = _oracle(q, k, v, qpos, kpos)
    pall = flash_decode(q, k, v, qpos, kpos, block_k=32, interpret=True)
    xla = flash_decode_xla(q, k, v, qpos, kpos, block_k=32)
    unb = flash_decode_xla(q, k, v, qpos, kpos, block_k=32, bounded=False)
    for got in (pall, xla, unb):
        np.testing.assert_allclose(np.asarray(got), ref, atol=2e-5)


def test_flash_decode_parity_window():
    rng = np.random.default_rng(1)
    q, k, v = _rand_qkv(rng, b=2, s=64, h=4, kh=2, dk=8)
    qpos = jnp.asarray([20, 63], jnp.int32)
    kpos = _dense_kpos(qpos, 64)
    ref = _oracle(q, k, v, qpos, kpos, window=16)
    pall = flash_decode(q, k, v, qpos, kpos, window=16, block_k=16,
                        interpret=True)
    xla = flash_decode_xla(q, k, v, qpos, kpos, window=16, block_k=16)
    np.testing.assert_allclose(np.asarray(pall), ref, atol=2e-5)
    np.testing.assert_allclose(np.asarray(xla), ref, atol=2e-5)


def test_flash_decode_parity_ring_positions():
    """Ring-buffer caches hand the kernel permuted, non-monotonic positions
    with negatives for not-yet-written slots — the mask must not assume
    slot index == position (and XLA must run unbounded)."""
    rng = np.random.default_rng(2)
    s = 32
    q, k, v = _rand_qkv(rng, b=2, s=s, h=2, kh=2, dk=8)
    t = np.asarray([45, 7])  # tokens seen so far per slot
    kpos = np.empty((2, s), np.int32)
    for bi in range(2):
        j = np.arange(s)
        kpos[bi] = t[bi] - 1 - ((t[bi] - 1 - j) % s)  # ring layout
    kpos = jnp.asarray(kpos)
    qpos = jnp.asarray(t - 1, jnp.int32)
    ref = _oracle(q, k, v, qpos, kpos, window=s)
    pall = flash_decode(q, k, v, qpos, kpos, window=s, block_k=16,
                        interpret=True)
    xla = flash_decode_xla(q, k, v, qpos, kpos, window=s, block_k=16,
                           bounded=False)
    np.testing.assert_allclose(np.asarray(pall), ref, atol=2e-5)
    np.testing.assert_allclose(np.asarray(xla), ref, atol=2e-5)


def test_flash_decode_padded_and_empty_slots():
    """Inactive batch lanes (all positions invalid) must come out exactly
    zero, not NaN — the online softmax divides by max(l, eps)."""
    rng = np.random.default_rng(3)
    q, k, v = _rand_qkv(rng, b=3, s=32, h=2, kh=1, dk=8)
    qpos = jnp.asarray([10, 0, 0], jnp.int32)
    kpos = np.array(_dense_kpos(qpos, 32))
    kpos[1:] = -1  # lanes 1, 2 inactive: nothing valid
    kpos = jnp.asarray(kpos)
    for fn in (lambda: flash_decode(q, k, v, qpos, kpos, block_k=16,
                                    interpret=True),
               lambda: flash_decode_xla(q, k, v, qpos, kpos, block_k=16)):
        got = np.asarray(fn())
        assert np.isfinite(got).all()
        np.testing.assert_array_equal(got[1:], 0.0)
        ref = _oracle(q, k, v, qpos, kpos)
        np.testing.assert_allclose(got, ref, atol=2e-5)


# ---------------------------------------------------------------------------
# kernel parity: paged flash-decode
# ---------------------------------------------------------------------------

def test_flash_decode_paged_parity():
    rng = np.random.default_rng(4)
    b, kh, h, dk, ps, pps, npg = 3, 2, 4, 8, 8, 4, 16
    pool_k = jnp.asarray(rng.standard_normal((npg, ps, kh, dk)), jnp.float32)
    pool_v = jnp.asarray(rng.standard_normal((npg, ps, kh, dk)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((b, 1, h, dk)), jnp.float32)
    qpos = jnp.asarray([5, 20, 30], jnp.int32)
    # bind a logical prefix of pages per slot (unique physical pages > 0),
    # leave the rest unbound (-1); slot 0 fits in one page
    table = np.full((b, pps), -1, np.int32)
    free = list(range(1, npg))
    for bi in range(b):
        for li in range((int(qpos[bi]) // ps) + 1):
            table[bi, li] = free.pop()
    table = jnp.asarray(table)
    # oracle over the gathered logical view
    gk = np.asarray(pool_k)[np.maximum(np.asarray(table), 0)]
    gv = np.asarray(pool_v)[np.maximum(np.asarray(table), 0)]
    gk = gk.reshape(b, pps * ps, kh, dk)
    gv = gv.reshape(b, pps * ps, kh, dk)
    lpos = np.tile(np.arange(pps * ps, dtype=np.int32), (b, 1))
    lpos = np.where(np.asarray(table)[:, lpos[0] // ps] >= 0, lpos, -1)
    ref = _oracle(q, gk, gv, qpos, lpos)
    pall = flash_decode_paged(q, pool_k, pool_v, qpos, table, interpret=True)
    xla = flash_decode_paged_xla(q, pool_k, pool_v, qpos, table)
    np.testing.assert_allclose(np.asarray(pall), ref, atol=2e-5)
    np.testing.assert_allclose(np.asarray(xla), ref, atol=2e-5)


# ---------------------------------------------------------------------------
# page allocator
# ---------------------------------------------------------------------------

def test_page_allocator_bind_free_reuse():
    al = PageAllocator(n_pages=9, page_size=4, n_slots=2, pages_per_slot=4)
    assert al.capacity == 8 and al.free_pages == 8
    for pos in range(0, 16, 4):
        assert al.ensure(0, pos)
        assert al.ensure(0, pos)  # idempotent re-bind
        al.check()
    assert al.used_pages == 4 and al.free_pages == 4
    assert al.ensure(1, 0) and al.ensure(1, 4)
    al.check()
    freed = al.release(0)
    assert freed == 4 and al.free_pages == 6
    al.check()
    # released pages are reusable; exhaustion reports False, mutates nothing
    for pos in range(0, 16, 4):
        assert al.ensure(0, pos)
    assert al.ensure(1, 8) and al.ensure(1, 12)
    assert al.free_pages == 0
    al.check()
    assert al.release(1) == 4 and al.free_pages == 4
    al.check()


def test_page_allocator_exhaustion_and_trash_page():
    al = PageAllocator(n_pages=3, page_size=4, n_slots=2, pages_per_slot=2)
    assert al.ensure(0, 0) and al.ensure(0, 4)
    assert not al.ensure(1, 0)  # exhausted
    assert al.table[1, 0] == -1  # nothing half-bound
    assert 0 not in al.table[al.table >= 0]  # trash page never handed out
    al.check()
    al.release(0)
    assert al.ensure(1, 0)
    al.check()
    with pytest.raises(ValueError):
        PageAllocator(n_pages=1, page_size=4, n_slots=1, pages_per_slot=1)


def test_page_allocator_position_past_table_width():
    al = PageAllocator(n_pages=5, page_size=4, n_slots=1, pages_per_slot=2)
    assert al.ensure(0, 7)
    assert not al.ensure(0, 8)  # past the table: reports False, no IndexError
    assert al.table[0, 1] >= 0  # in-range bindings untouched
    al.check()


# ---------------------------------------------------------------------------
# engine: paged cache + flash kernel parity, eviction/resume
# ---------------------------------------------------------------------------

def _drain_and_check(cfg, params, eng, reqs):
    out = eng.run_until_drained(list(reqs))
    assert set(out) == {rid for rid, _, _ in reqs}
    for rid, prompt, n in reqs:
        assert out[rid] == _greedy_reference(cfg, params, prompt, n), rid


def test_paged_engine_matches_reference(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, n_slots=2, max_len=64, paged=True,
                      page_size=16)
    rng = np.random.RandomState(3)
    reqs = [(f"p{i}", list(rng.randint(0, cfg.vocab_size, 4 + 2 * i)), 4)
            for i in range(4)]
    _drain_and_check(cfg, params, eng, reqs)
    assert eng.allocator.used_pages == 0  # all pages returned
    eng.allocator.check()


def test_flash_engine_matches_reference(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, n_slots=2, max_len=64,
                      decode_kernel="flash")
    rng = np.random.RandomState(4)
    reqs = [(f"f{i}", list(rng.randint(0, cfg.vocab_size, 5 + i)), 4)
            for i in range(3)]
    _drain_and_check(cfg, params, eng, reqs)


def test_flash_paged_engine_hybrid_arch():
    """gemma3 mixes ring local layers (dense flash path, unbounded) with
    global attention layers (paged flash path) in one stack."""
    cfg = smoke_config("gemma3_1b")
    params = init_params(model_spec(cfg), jax.random.PRNGKey(2),
                         jnp.dtype(cfg.dtype))
    eng = ServeEngine(cfg, params, n_slots=2, max_len=64, paged=True,
                      page_size=16, decode_kernel="flash")
    rng = np.random.RandomState(5)
    reqs = [(f"g{i}", list(rng.randint(0, cfg.vocab_size, 6 + 3 * i)), 4)
            for i in range(3)]
    _drain_and_check(cfg, params, eng, reqs)


def test_flash_engine_recurrent_arch():
    """recurrentgemma: RG-LRU state must be zeroed on (lazy) admission while
    the ring KV rides the flash kernel's permuted-position path."""
    cfg = smoke_config("recurrentgemma_2b")
    params = init_params(model_spec(cfg), jax.random.PRNGKey(3),
                         jnp.dtype(cfg.dtype))
    eng = ServeEngine(cfg, params, n_slots=2, max_len=96,
                      decode_kernel="flash")
    rng = np.random.RandomState(6)
    reqs = [(f"r{i}", list(rng.randint(0, cfg.vocab_size, 5 + i)), 4)
            for i in range(4)]  # > n_slots: slot reuse must reset state
    _drain_and_check(cfg, params, eng, reqs)


def test_evict_and_resume_mid_generation(small_model):
    """Evicting a request mid-generation and re-admitting it (on a paged
    engine) must reproduce the uninterrupted greedy decode exactly."""
    cfg, params = small_model
    rng = np.random.RandomState(7)
    prompt = list(rng.randint(0, cfg.vocab_size, 6))
    other = list(rng.randint(0, cfg.vocab_size, 4))
    eng = ServeEngine(cfg, params, n_slots=2, max_len=64, paged=True,
                      page_size=16)
    assert eng.add_request("victim", prompt, max_new=8)
    assert eng.add_request("other", other, max_new=10)
    done = {}
    for _ in range(len(prompt) + 3):  # victim is 3 tokens into generation
        done.update(eng.step())
    state = eng.evict("victim")
    assert state is not None and state["prompt"] == prompt
    assert 0 < len(state["tokens"]) < 8
    eng.allocator.check()
    # slot + pages freed: a new request can take its place immediately
    assert eng.add_request("victim", state["prompt"], state["max_new"],
                          resume_tokens=state["tokens"])
    while eng._active():
        done.update(eng.step())
    assert done["victim"] == _greedy_reference(cfg, params, prompt, 8)
    assert done["other"] == _greedy_reference(cfg, params, other, 10)


@pytest.mark.parametrize("arch", ["stablelm_1_6b", "gemma3_1b",
                                  "recurrentgemma_2b"])
def test_stalled_slot_resumes_uncorrupted(arch):
    """Page-pool exhaustion stalls one slot while the other keeps stepping.
    The stalled slot still rides the jitted step as a garbage lane — its
    bound pages, ring KV, and recurrent state must not advance on it, so
    once pages free up it resumes bit-exact against the uninterrupted
    greedy decode."""
    cfg = smoke_config(arch)
    params = init_params(model_spec(cfg), jax.random.PRNGKey(5),
                         jnp.dtype(cfg.dtype))
    # 3 usable pages, two requests needing 2 pages each: the slot that
    # loses the race for the third page stalls mid-generation until the
    # winner completes and releases its pages.
    eng = ServeEngine(cfg, params, n_slots=2, max_len=64, paged=True,
                      page_size=16, n_pages=4)
    rng = np.random.RandomState(9)
    reqs = [("a", list(rng.randint(0, cfg.vocab_size, 4)), 20),
            ("b", list(rng.randint(0, cfg.vocab_size, 4)), 20)]
    for req in reqs:
        assert eng.add_request(*req)
    done, stalls = {}, 0
    for _ in range(200):
        before = {i: eng.slots[i].position for i in eng._active()}
        done.update(eng.step())
        stalls += sum(1 for i, p in before.items()
                      if not eng.slots[i].done
                      and eng.slots[i].position == p)
        if not eng._active():
            break
    assert stalls > 0  # the scenario really exercised a stall
    eng.allocator.check()
    assert eng.allocator.used_pages == 0
    for rid, prompt, n in reqs:
        assert done[rid] == _greedy_reference(cfg, params, prompt, n), rid


def test_reset_full_defers_under_inflight_step(small_model):
    """reset_full admissions landing while a step's device call is in
    flight must defer their zero to the next assembly — an eager reset
    would be clobbered by the apply phase's ``self.caches = new_caches``,
    leaking the previous occupant's state into the new request."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, n_slots=2, max_len=32,
                      admission="reset_full")
    assert eng._step_guard.acquire(blocking=False)  # simulate in-flight step
    try:
        assert eng.add_request("r0", [1, 2, 3], max_new=3)
        assert 0 in eng._pending_reset  # deferred, not eagerly applied
    finally:
        eng._step_guard.release()
    assert eng.add_request("r1", [4, 5], max_new=3)
    assert 1 not in eng._pending_reset  # no step in flight: eager baseline
    done = {}
    while eng._active():
        done.update(eng.step())
    assert done["r0"] == _greedy_reference(cfg, params, [1, 2, 3], 3)
    assert done["r1"] == _greedy_reference(cfg, params, [4, 5], 3)


def test_reset_full_rejects_paged(small_model):
    """The full-lane zero indexes pool leaves by physical page, not slot —
    the combination would wipe other requests' KV and must not construct."""
    cfg, params = small_model
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, n_slots=2, max_len=32, paged=True,
                    admission="reset_full")


def test_oversized_prompt_rejected(small_model):
    """A prompt that can never fit max_len must fail loudly at submission
    (engine and router), not walk positions past the page table in the
    driver thread."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, n_slots=1, max_len=16, paged=True,
                      page_size=8)
    with pytest.raises(ValueError):
        eng.add_request("big", list(range(16)), max_new=4)
    with pytest.raises(ValueError):
        eng.add_request("big", list(range(10)), max_new=4,
                        resume_tokens=list(range(6)))
    assert eng.add_request("fits", list(range(15)), max_new=4)
    rs = ServeReplicaSet(cfg, params, n_replicas=1,
                         engine_kw=dict(n_slots=1, max_len=16))
    with pytest.raises(ValueError):
        rs.submit("big", list(range(16)))
    assert rs.lost == 0 and rs.submitted == 0


# ---------------------------------------------------------------------------
# replica set: routing, SLO admission, accounting
# ---------------------------------------------------------------------------

def test_replica_set_completes_all_zero_lost(small_model):
    cfg, params = small_model
    reg = MetricsRegistry()
    rs = ServeReplicaSet(cfg, params, n_replicas=2, registry=reg,
                         engine_kw=dict(n_slots=2, max_len=64, paged=True,
                                        page_size=16))
    rng = np.random.RandomState(8)
    prompts = [list(rng.randint(0, cfg.vocab_size, 4 + i)) for i in range(8)]
    with rs:
        pend = [rs.submit(f"q{i}", p, max_new=5)
                for i, p in enumerate(prompts)]
        assert rs.drain(timeout=120)
    assert rs.completed == 8 and rs.lost == 0 and rs.duplicates == 0
    assert sorted({p.replica for p in pend}) == [0, 1]  # both replicas used
    for p, prompt in zip(pend, prompts):
        assert p.tokens == _greedy_reference(cfg, params, prompt, 5)
    # the engines published their token counters under distinct replica labels
    fam = register_serve_metrics(reg)["tokens"]
    vals = {key[0]: child.value for key, child in fam.items()}
    assert vals.get("r0", 0) + vals.get("r1", 0) >= 8 * 5


def test_replica_set_sheds_on_ttft_violation(small_model):
    cfg, params = small_model
    rs = ServeReplicaSet(cfg, params, n_replicas=1,
                         engine_kw=dict(n_slots=1, max_len=64,
                                        step_latency_s=0.02),
                         ttft_slo=ttft_slo(0.001), on_violation="shed")
    with rs:
        warm = rs.submit("warm", [2, 3], max_new=4)
        assert warm.wait(60)  # rate signal is live; admission is no longer
        burst = [rs.submit(f"b{i}", [2, 3], max_new=8)  # cold-optimistic
                 for i in range(8)]
        assert rs.drain(timeout=120)
    assert rs.shed > 0
    assert rs.lost == 0
    shed = [p for p in burst if p.status == "shed"]
    assert all(p.resolved and p.tokens is None for p in shed)


def test_replica_set_spills_to_callback(small_model):
    cfg, params = small_model
    spilled = []
    rs = ServeReplicaSet(cfg, params, n_replicas=1,
                         engine_kw=dict(n_slots=1, max_len=64,
                                        step_latency_s=0.02),
                         ttft_slo=ttft_slo(0.001), on_violation="spill",
                         spill_to=spilled.append)
    with rs:
        warm = rs.submit("warm", [2, 3], max_new=4)
        assert warm.wait(60)
        for i in range(8):
            rs.submit(f"b{i}", [2, 3], max_new=8)
        assert rs.drain(timeout=120)
    assert rs.spilled == len(spilled) > 0
    assert rs.lost == 0


def test_replica_set_cluster_deploy(small_model):
    """Replica drivers as long-lived tasks on a serve-tainted pool, load
    driven by serve_loadgen tasks on the plain cpu pool."""
    from repro.cluster import KsaCluster
    from repro.core.scheduling import ResourceClassPolicy
    from repro.serve import ServeLoadGenComputing

    cfg, params = small_model
    rs = ServeReplicaSet(cfg, params, n_replicas=2,
                         engine_kw=dict(n_slots=2, max_len=64))
    cluster = KsaCluster(workers=1, prefix="tserve",
                         placement=ResourceClassPolicy(
                             extra_classes=("serve",)))
    with cluster:
        ids = rs.deploy(cluster, taint="serve")
        ServeLoadGenComputing.replica_set = rs
        gen = [cluster.submit("serve_loadgen",
                              params={"client": f"c{i}", "n_requests": 3,
                                      "prompt_len": 4, "max_new": 5,
                                      "vocab_size": cfg.vocab_size})
               for i in range(2)]
        assert cluster.wait_all(gen, timeout=120)
        results = [cluster.result(t) for t in gen]
        rs.stop()
        for t in ids:  # driver tasks completed cleanly with engine stats
            entry = cluster.task(t)
            assert entry.status == "DONE" and entry.result["steps"] >= 0
    assert all(r["completed"] == 3 and r["timed_out"] == 0 for r in results)
    assert rs.submitted == 6 and rs.lost == 0 and rs.duplicates == 0


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_serve_metrics_registered_and_exported(small_model):
    cfg, params = small_model
    reg = MetricsRegistry()
    fams = register_serve_metrics(reg)
    assert set(fams) == {"queue_wait", "ttft", "step", "tokens", "requests",
                         "slots_active", "slots_total", "pages_used",
                         "pages_total"}
    assert register_serve_metrics(reg) is not None  # idempotent
    eng = ServeEngine(cfg, params, n_slots=2, max_len=64, paged=True,
                      page_size=16, registry=reg, replica="r9")
    out = eng.run_until_drained([("m0", [1, 2, 3], 3)])
    assert out["m0"]
    text = reg.render()
    for name in ("ksa_serve_queue_wait_seconds", "ksa_serve_ttft_seconds",
                 "ksa_serve_step_seconds", "ksa_serve_tokens_total",
                 "ksa_serve_requests_total", "ksa_serve_slots_active",
                 "ksa_serve_slots_total", "ksa_serve_pages_used",
                 "ksa_serve_pages_total"):
        assert f"# TYPE {name}" in text, name
    assert 'ksa_serve_tokens_total{replica="r9"} 3' in text
    assert 'event="admitted"' in text and 'event="completed"' in text
