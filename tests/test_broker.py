"""Unit + property tests for the embedded durable log (repro.core.broker)."""
import threading
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic fallback
    from _mini_hypothesis import given, settings, strategies as st

from repro.core.broker import (Broker, Consumer, FencedError, Producer,
                               TopicPartition)


def test_produce_fetch_ordering():
    b = Broker()
    b.create_topic("t", partitions=1)
    for i in range(10):
        b.produce("t", {"i": i})
    recs = b.fetch(TopicPartition("t", 0), 0, 100)
    assert [r.value["i"] for r in recs] == list(range(10))
    assert [r.offset for r in recs] == list(range(10))


def test_keyed_records_stable_partition():
    b = Broker(default_partitions=4)
    b.create_topic("t", partitions=4)
    parts = {b.produce("t", {"n": i}, key="same-key").partition
             for i in range(20)}
    assert len(parts) == 1


def test_unkeyed_records_balance():
    b = Broker()
    b.create_topic("t", partitions=4)
    for i in range(40):
        b.produce("t", {"n": i})
    ends = [b.end_offset(TopicPartition("t", p)) for p in range(4)]
    assert ends == [10, 10, 10, 10]


def test_consumer_group_load_balance():
    b = Broker()
    b.create_topic("t", partitions=4)
    c1 = Consumer(b, ["t"], group_id="g")
    c2 = Consumer(b, ["t"], group_id="g")
    a1 = set(map(tuple, ((tp.topic, tp.partition) for tp in c1.assignment())))
    a2 = set(map(tuple, ((tp.topic, tp.partition) for tp in c2.assignment())))
    assert a1.isdisjoint(a2)
    assert len(a1) + len(a2) == 4


def test_two_groups_broadcast():
    """The paper's multiple-MonitorAgents-each-get-a-copy setup."""
    b = Broker()
    b.create_topic("t", partitions=2)
    for i in range(6):
        b.produce("t", {"i": i})
    g1 = Consumer(b, ["t"], group_id="mon1")
    g2 = Consumer(b, ["t"], group_id="mon2")
    seen1 = sorted(r.value["i"] for recs in g1.poll(0.2).values() for r in recs)
    seen2 = sorted(r.value["i"] for recs in g2.poll(0.2).values() for r in recs)
    assert seen1 == seen2 == list(range(6))


def test_commit_and_redelivery_after_crash():
    """At-least-once: uncommitted records are redelivered to the next owner."""
    b = Broker(session_timeout_s=0.2)
    b.create_topic("t", partitions=1)
    for i in range(5):
        b.produce("t", {"i": i})
    c1 = Consumer(b, ["t"], group_id="g")
    got = [r.value["i"] for recs in c1.poll(0.2).values() for r in recs]
    assert got == [0, 1, 2, 3, 4]
    # c1 "crashes" without committing; session expires; c2 takes over
    time.sleep(0.25)
    b.evict_expired_members()
    c2 = Consumer(b, ["t"], group_id="g")
    got2 = [r.value["i"] for recs in c2.poll(0.2).values() for r in recs]
    assert got2 == [0, 1, 2, 3, 4]  # full redelivery
    c2.commit()
    c3 = Consumer(b, ["t"], group_id="g", member_id="m3")
    b.leave_group("g", c2.member_id)
    assert c3.poll(0.05) == {}  # committed: nothing to redeliver


def test_rebalance_generation_fencing():
    b = Broker()
    b.create_topic("t", partitions=2)
    c1 = Consumer(b, ["t"], group_id="g")
    gen0 = b.generation("g")
    c2 = Consumer(b, ["t"], group_id="g")
    assert b.generation("g") == gen0 + 1
    with pytest.raises(FencedError):
        b.commit("g", {TopicPartition("t", 0): 1}, generation=gen0)


def test_exactly_once_transaction_no_double_output():
    b = Broker()
    b.create_topic("in", partitions=1)
    b.create_topic("out", partitions=1)
    b.produce("in", {"x": 1})
    c = Consumer(b, ["in"], group_id="g", semantics="exactly_once")

    n = c.process_transactionally(
        lambda recs: [("out", {"y": r.value["x"] * 2}, None) for r in recs],
        timeout=0.2)
    assert n == 1
    # replay from committed offset: nothing left, output exactly once
    assert c.process_transactionally(lambda recs: [], timeout=0.05) == 0
    out = b.fetch(TopicPartition("out", 0), 0, 10)
    assert [r.value["y"] for r in out] == [2]


def test_exactly_once_handler_failure_redelivers_without_output():
    b = Broker()
    b.create_topic("in", partitions=1)
    b.produce("in", {"x": 1})
    c = Consumer(b, ["in"], group_id="g", semantics="exactly_once")

    def boom(recs):
        raise RuntimeError("handler died")

    with pytest.raises(RuntimeError):
        c.process_transactionally(boom, timeout=0.2)
    # offsets were not committed -> a fresh consumer sees the record again
    c.close()
    c2 = Consumer(b, ["in"], group_id="g", semantics="exactly_once")
    seen = []
    c2.process_transactionally(
        lambda recs: (seen.extend(r.value["x"] for r in recs), [])[1],
        timeout=0.2)
    assert seen == [1]


def test_durability_replay(tmp_path):
    d = str(tmp_path / "log")
    b = Broker(log_dir=d)
    b.create_topic("t", partitions=2)
    for i in range(8):
        b.produce("t", {"i": i}, key=str(i))
    c = Consumer(b, ["t"], group_id="g")
    c.poll(0.2)
    c.commit()
    b.close()
    # restart: records and committed offsets must survive
    b2 = Broker(log_dir=d)
    b2.create_topic("t", partitions=2)
    total = sum(b2.end_offset(TopicPartition("t", p)) for p in range(2))
    assert total == 8
    c2 = Consumer(b2, ["t"], group_id="g")
    assert c2.poll(0.05) == {}  # offsets survived -> no redelivery


def test_retention_trims_but_keeps_offsets():
    b = Broker(retention_records=5)
    b.create_topic("t", partitions=1)
    for i in range(12):
        b.produce("t", {"i": i})
    tp = TopicPartition("t", 0)
    assert b.end_offset(tp) == 12
    recs = b.fetch(tp, 0, 100)
    assert [r.value["i"] for r in recs] == [7, 8, 9, 10, 11]
    assert recs[0].offset == 7


def test_blocking_poll_wakes_on_produce():
    b = Broker()
    b.create_topic("t", partitions=1)
    c = Consumer(b, ["t"], group_id="g")
    out = []

    def consume():
        out.extend(r.value["i"] for recs in c.poll(timeout=2.0).values()
                   for r in recs)

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.05)
    t0 = time.time()
    b.produce("t", {"i": 42})
    t.join(timeout=2.0)
    assert out == [42]
    assert time.time() - t0 < 1.0  # woke via condition var, not timeout


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    n_records=st.integers(1, 40),
    n_partitions=st.integers(1, 5),
    n_consumers=st.integers(1, 4),
    commit_every=st.integers(1, 7),
)
def test_property_every_record_seen_at_least_once(n_records, n_partitions,
                                                  n_consumers, commit_every):
    """Across arbitrary group sizes/commit cadences, the union of consumed
    records covers the log (at-least-once, no loss)."""
    b = Broker()
    b.create_topic("t", partitions=n_partitions)
    for i in range(n_records):
        b.produce("t", {"i": i}, key=str(i % 7))
    consumers = [Consumer(b, ["t"], group_id="g") for _ in range(n_consumers)]
    seen: set[int] = set()
    for _ in range(n_records * 2):
        for k, c in enumerate(consumers):
            batches = c.poll(0.0)
            cnt = 0
            for recs in batches.values():
                for r in recs:
                    seen.add(r.value["i"])
                    cnt += 1
            if cnt and (cnt % commit_every == 0):
                c.commit()
        if len(seen) == n_records:
            break
    assert seen == set(range(n_records))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.sampled_from(["produce", "crash", "consume"]),
                min_size=1, max_size=30))
def test_property_crash_consume_schedule_no_loss(schedule):
    """Random interleavings of produce / consumer-crash / consume never lose
    an uncommitted record (exactly-once effect is layered above by fencing)."""
    b = Broker(session_timeout_s=1e9)  # manual eviction only
    b.create_topic("t", partitions=2)
    produced = 0
    processed: set[int] = set()
    consumer = Consumer(b, ["t"], group_id="g")
    for action in schedule:
        if action == "produce":
            b.produce("t", {"i": produced})
            produced += 1
        elif action == "crash":
            # abandon without commit; evict; new consumer takes over
            b.leave_group("g", consumer.member_id)
            consumer = Consumer(b, ["t"], group_id="g")
        else:
            for recs in consumer.poll(0.0).values():
                for r in recs:
                    processed.add(r.value["i"])
            consumer.commit()
    # final drain
    for _ in range(3):
        for recs in consumer.poll(0.0).values():
            for r in recs:
                processed.add(r.value["i"])
        consumer.commit()
    assert processed == set(range(produced))


# ---------------------------------------------------------------------------
# Sharded data plane: concurrency, lock ordering, starvation, legacy mode
# ---------------------------------------------------------------------------

def test_lock_order_violation_raises():
    """debug_locks catches acquiring a group lock (rank 0) while holding a
    partition lock (rank 2), and partition locks taken out of key order."""
    from repro.core.broker import (LockOrderError, _RANK_GROUP,
                                   _RANK_PARTITION, _OrderedLock)
    grp = _OrderedLock(_RANK_GROUP, ("group", "g"))
    p0 = _OrderedLock(_RANK_PARTITION, ("partition", "t", 0))
    p1 = _OrderedLock(_RANK_PARTITION, ("partition", "t", 1))
    # descending rank: partition -> group is illegal
    with p0:
        with pytest.raises(LockOrderError):
            with grp:
                pass
    # same rank, descending key is illegal; ascending is fine
    with p0:
        with p1:
            pass
    with p1:
        with pytest.raises(LockOrderError):
            with p0:
                pass
    # legal order group -> partition, and re-entrancy
    with grp:
        with p0:
            with p0:
                pass


def test_lease_rotation_prevents_partition_starvation():
    """With max_records=1, successive lease calls rotate the start partition
    so every partition's records are eventually granted (satellite a)."""
    b = Broker(default_partitions=4)
    for i in range(4):
        b.produce("work", {"task_id": f"t{i}", "payload": i},
                  key=f"t{i}", partition=i)
    c = Consumer(b, ["work"], group_id="g")
    seen_partitions = set()
    for _ in range(4):
        recs = b.lease_records("g", c.member_id, max_records=1)
        assert len(recs) == 1
        seen_partitions.add(recs[0].partition)
        tid = recs[0].value["task_id"]
        assert b.claim_start(tid, c.member_id, 0, threading.Event())
        assert b.complete_lease(tid, c.member_id)
    assert seen_partitions == {0, 1, 2, 3}


def test_fetch_returns_snapshot_not_live_slice():
    """Partition.fetch must hand back a copy: mutating broker state after the
    fetch (truncation, more appends) must not alter the returned batch
    (satellite c)."""
    b = Broker(default_partitions=1)
    for i in range(10):
        b.produce("t", {"i": i})
    tp = TopicPartition("t", 0)
    batch = b.fetch(tp, 0, 100)
    vals = [r.value["i"] for r in batch]
    b.truncate_before(tp, 8)
    for i in range(10, 15):
        b.produce("t", {"i": i})
    assert [r.value["i"] for r in batch] == vals == list(range(10))


def test_single_lock_mode_smoke():
    """single_lock=True restores the serialized legacy data plane but keeps
    the same external behaviour (satellite e)."""
    b = Broker(default_partitions=2, single_lock=True)
    assert b.single_lock and b._master is not None
    for i in range(6):
        b.produce("work", {"task_id": f"s{i}", "payload": i}, key=f"s{i}")
    c = Consumer(b, ["work"], group_id="g")
    done = set()
    for _ in range(10):
        for r in b.lease_records("g", c.member_id, max_records=4):
            tid = r.value["task_id"]
            assert b.claim_start(tid, c.member_id, 0, threading.Event())
            assert b.complete_lease(tid, c.member_id)
            done.add(tid)
        if len(done) == 6:
            break
    assert done == {f"s{i}" for i in range(6)}
    st_ = b.lease_stats()
    assert st_["granted"] == 6 and st_["completed"] == 6


def test_stress_concurrent_producers_agents_revoker():
    """N producers + M leasing agents + a revoker thread under debug_locks:
    every task completes exactly once, no double grants, no lost tasks,
    offsets stay monotone and queue/lease stats stay consistent."""
    import random
    b = Broker(default_partitions=8, debug_locks=True, session_timeout_s=1e9)
    b.create_topic("work", partitions=8)
    n_producers, per_producer, n_agents = 3, 150, 3
    total = n_producers * per_producer
    errors: list = []
    completions: dict[str, int] = {}
    comp_lock = threading.Lock()
    stop = threading.Event()

    def producer(pid: int) -> None:
        try:
            for i in range(per_producer):
                b.produce("work", {"task_id": f"p{pid}-{i}", "payload": i},
                          key=f"p{pid}-{i}")
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    def agent(aid: int) -> None:
        try:
            c = Consumer(b, ["work"], group_id="g")
            idle = 0
            while not stop.is_set():
                recs = b.lease_records("g", c.member_id, max_records=16)
                if not recs:
                    idle += 1
                    if idle > 200:
                        break
                    time.sleep(0.002)
                    continue
                idle = 0
                for r in recs:
                    tid = r.value["task_id"]
                    if not b.claim_start(tid, c.member_id,
                                         r.value.get("attempt", 0),
                                         threading.Event()):
                        continue  # revoked between grant and claim
                    if b.complete_lease(tid, c.member_id):
                        with comp_lock:
                            completions[tid] = completions.get(tid, 0) + 1
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    def revoker() -> None:
        try:
            rng = random.Random(42)
            n_revoked = 0
            while not stop.is_set() and n_revoked < 40:
                live = b.live_leases()
                if live:
                    victim = rng.choice(live)
                    if b.revoke_lease(victim["task_id"], reason="preempt"):
                        n_revoked += 1
                time.sleep(0.003)
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    threads = ([threading.Thread(target=producer, args=(p,))
                for p in range(n_producers)]
               + [threading.Thread(target=agent, args=(a,))
                  for a in range(n_agents)]
               + [threading.Thread(target=revoker)])
    for t in threads:
        t.start()
    deadline = time.monotonic() + 60.0
    while time.monotonic() < deadline:
        with comp_lock:
            if len(completions) == total:
                break
        time.sleep(0.05)
    stop.set()
    for t in threads:
        t.join(timeout=30.0)
    assert not errors, errors
    # exactly-once: every task completed, none more than once
    assert len(completions) == total
    assert all(v == 1 for v in completions.values()), \
        {k: v for k, v in completions.items() if v != 1}
    st_ = b.lease_stats()
    assert st_["active"] == 0
    assert st_["completed"] == total  # tombstones block double commits
    qs = b.queue_stats("g", ["work"])["work"]
    assert qs["produced"] >= total  # revoked tasks were re-produced
    assert qs["consumed"] == qs["produced"]  # fully drained
    assert qs["depth"] == 0
    # offsets monotone and within the log
    for p in range(8):
        tp = TopicPartition("work", p)
        assert 0 <= b.committed("g", tp) <= b.end_offset(tp)
