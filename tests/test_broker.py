"""Unit + property tests for the embedded durable log (repro.core.broker)."""
import threading
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic fallback
    from _mini_hypothesis import given, settings, strategies as st

from repro.core.broker import (Broker, Consumer, FencedError, Producer,
                               TopicPartition)


def test_produce_fetch_ordering():
    b = Broker()
    b.create_topic("t", partitions=1)
    for i in range(10):
        b.produce("t", {"i": i})
    recs = b.fetch(TopicPartition("t", 0), 0, 100)
    assert [r.value["i"] for r in recs] == list(range(10))
    assert [r.offset for r in recs] == list(range(10))


def test_keyed_records_stable_partition():
    b = Broker(default_partitions=4)
    b.create_topic("t", partitions=4)
    parts = {b.produce("t", {"n": i}, key="same-key").partition
             for i in range(20)}
    assert len(parts) == 1


def test_unkeyed_records_balance():
    b = Broker()
    b.create_topic("t", partitions=4)
    for i in range(40):
        b.produce("t", {"n": i})
    ends = [b.end_offset(TopicPartition("t", p)) for p in range(4)]
    assert ends == [10, 10, 10, 10]


def test_consumer_group_load_balance():
    b = Broker()
    b.create_topic("t", partitions=4)
    c1 = Consumer(b, ["t"], group_id="g")
    c2 = Consumer(b, ["t"], group_id="g")
    a1 = set(map(tuple, ((tp.topic, tp.partition) for tp in c1.assignment())))
    a2 = set(map(tuple, ((tp.topic, tp.partition) for tp in c2.assignment())))
    assert a1.isdisjoint(a2)
    assert len(a1) + len(a2) == 4


def test_two_groups_broadcast():
    """The paper's multiple-MonitorAgents-each-get-a-copy setup."""
    b = Broker()
    b.create_topic("t", partitions=2)
    for i in range(6):
        b.produce("t", {"i": i})
    g1 = Consumer(b, ["t"], group_id="mon1")
    g2 = Consumer(b, ["t"], group_id="mon2")
    seen1 = sorted(r.value["i"] for recs in g1.poll(0.2).values() for r in recs)
    seen2 = sorted(r.value["i"] for recs in g2.poll(0.2).values() for r in recs)
    assert seen1 == seen2 == list(range(6))


def test_commit_and_redelivery_after_crash():
    """At-least-once: uncommitted records are redelivered to the next owner."""
    b = Broker(session_timeout_s=0.2)
    b.create_topic("t", partitions=1)
    for i in range(5):
        b.produce("t", {"i": i})
    c1 = Consumer(b, ["t"], group_id="g")
    got = [r.value["i"] for recs in c1.poll(0.2).values() for r in recs]
    assert got == [0, 1, 2, 3, 4]
    # c1 "crashes" without committing; session expires; c2 takes over
    time.sleep(0.25)
    b.evict_expired_members()
    c2 = Consumer(b, ["t"], group_id="g")
    got2 = [r.value["i"] for recs in c2.poll(0.2).values() for r in recs]
    assert got2 == [0, 1, 2, 3, 4]  # full redelivery
    c2.commit()
    c3 = Consumer(b, ["t"], group_id="g", member_id="m3")
    b.leave_group("g", c2.member_id)
    assert c3.poll(0.05) == {}  # committed: nothing to redeliver


def test_rebalance_generation_fencing():
    b = Broker()
    b.create_topic("t", partitions=2)
    c1 = Consumer(b, ["t"], group_id="g")
    gen0 = b.generation("g")
    c2 = Consumer(b, ["t"], group_id="g")
    assert b.generation("g") == gen0 + 1
    with pytest.raises(FencedError):
        b.commit("g", {TopicPartition("t", 0): 1}, generation=gen0)


def test_exactly_once_transaction_no_double_output():
    b = Broker()
    b.create_topic("in", partitions=1)
    b.create_topic("out", partitions=1)
    b.produce("in", {"x": 1})
    c = Consumer(b, ["in"], group_id="g", semantics="exactly_once")

    n = c.process_transactionally(
        lambda recs: [("out", {"y": r.value["x"] * 2}, None) for r in recs],
        timeout=0.2)
    assert n == 1
    # replay from committed offset: nothing left, output exactly once
    assert c.process_transactionally(lambda recs: [], timeout=0.05) == 0
    out = b.fetch(TopicPartition("out", 0), 0, 10)
    assert [r.value["y"] for r in out] == [2]


def test_exactly_once_handler_failure_redelivers_without_output():
    b = Broker()
    b.create_topic("in", partitions=1)
    b.produce("in", {"x": 1})
    c = Consumer(b, ["in"], group_id="g", semantics="exactly_once")

    def boom(recs):
        raise RuntimeError("handler died")

    with pytest.raises(RuntimeError):
        c.process_transactionally(boom, timeout=0.2)
    # offsets were not committed -> a fresh consumer sees the record again
    c.close()
    c2 = Consumer(b, ["in"], group_id="g", semantics="exactly_once")
    seen = []
    c2.process_transactionally(
        lambda recs: (seen.extend(r.value["x"] for r in recs), [])[1],
        timeout=0.2)
    assert seen == [1]


def test_durability_replay(tmp_path):
    d = str(tmp_path / "log")
    b = Broker(log_dir=d)
    b.create_topic("t", partitions=2)
    for i in range(8):
        b.produce("t", {"i": i}, key=str(i))
    c = Consumer(b, ["t"], group_id="g")
    c.poll(0.2)
    c.commit()
    b.close()
    # restart: records and committed offsets must survive
    b2 = Broker(log_dir=d)
    b2.create_topic("t", partitions=2)
    total = sum(b2.end_offset(TopicPartition("t", p)) for p in range(2))
    assert total == 8
    c2 = Consumer(b2, ["t"], group_id="g")
    assert c2.poll(0.05) == {}  # offsets survived -> no redelivery


def test_retention_trims_but_keeps_offsets():
    b = Broker(retention_records=5)
    b.create_topic("t", partitions=1)
    for i in range(12):
        b.produce("t", {"i": i})
    tp = TopicPartition("t", 0)
    assert b.end_offset(tp) == 12
    recs = b.fetch(tp, 0, 100)
    assert [r.value["i"] for r in recs] == [7, 8, 9, 10, 11]
    assert recs[0].offset == 7


def test_blocking_poll_wakes_on_produce():
    b = Broker()
    b.create_topic("t", partitions=1)
    c = Consumer(b, ["t"], group_id="g")
    out = []

    def consume():
        out.extend(r.value["i"] for recs in c.poll(timeout=2.0).values()
                   for r in recs)

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.05)
    t0 = time.time()
    b.produce("t", {"i": 42})
    t.join(timeout=2.0)
    assert out == [42]
    assert time.time() - t0 < 1.0  # woke via condition var, not timeout


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    n_records=st.integers(1, 40),
    n_partitions=st.integers(1, 5),
    n_consumers=st.integers(1, 4),
    commit_every=st.integers(1, 7),
)
def test_property_every_record_seen_at_least_once(n_records, n_partitions,
                                                  n_consumers, commit_every):
    """Across arbitrary group sizes/commit cadences, the union of consumed
    records covers the log (at-least-once, no loss)."""
    b = Broker()
    b.create_topic("t", partitions=n_partitions)
    for i in range(n_records):
        b.produce("t", {"i": i}, key=str(i % 7))
    consumers = [Consumer(b, ["t"], group_id="g") for _ in range(n_consumers)]
    seen: set[int] = set()
    for _ in range(n_records * 2):
        for k, c in enumerate(consumers):
            batches = c.poll(0.0)
            cnt = 0
            for recs in batches.values():
                for r in recs:
                    seen.add(r.value["i"])
                    cnt += 1
            if cnt and (cnt % commit_every == 0):
                c.commit()
        if len(seen) == n_records:
            break
    assert seen == set(range(n_records))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.sampled_from(["produce", "crash", "consume"]),
                min_size=1, max_size=30))
def test_property_crash_consume_schedule_no_loss(schedule):
    """Random interleavings of produce / consumer-crash / consume never lose
    an uncommitted record (exactly-once effect is layered above by fencing)."""
    b = Broker(session_timeout_s=1e9)  # manual eviction only
    b.create_topic("t", partitions=2)
    produced = 0
    processed: set[int] = set()
    consumer = Consumer(b, ["t"], group_id="g")
    for action in schedule:
        if action == "produce":
            b.produce("t", {"i": produced})
            produced += 1
        elif action == "crash":
            # abandon without commit; evict; new consumer takes over
            b.leave_group("g", consumer.member_id)
            consumer = Consumer(b, ["t"], group_id="g")
        else:
            for recs in consumer.poll(0.0).values():
                for r in recs:
                    processed.add(r.value["i"])
            consumer.commit()
    # final drain
    for _ in range(3):
        for recs in consumer.poll(0.0).values():
            for r in recs:
                processed.add(r.value["i"])
        consumer.commit()
    assert processed == set(range(produced))
