"""Subprocess helper: verifies the sharded program (GSPMD + MoE island +
vocab-parallel CE) matches the single-device path numerically on an 8-device
host mesh. Run via tests/test_distributed.py; exits nonzero on mismatch."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import init_params, model_spec
from repro.optim import OptimizerConfig
from repro.sharding import DistContext, state_axes
from repro.train import init_train_state, make_train_step
from repro.train.step import train_state_shapes


def check_arch(arch: str, mesh) -> float:
    cfg = smoke_config(arch)
    ocfg = OptimizerConfig(lr=1e-2, warmup_steps=0, schedule="constant",
                           weight_decay=0.0)
    rng = np.random.RandomState(0)
    b, s = 4, 32
    if cfg.frontend is not None and cfg.frontend.kind == "audio_frames":
        batch = {"embeds": jnp.asarray(rng.randn(b, s, cfg.frontend.input_dim),
                                       jnp.float32),
                 "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)),
                                       jnp.int32)}
    elif cfg.frontend is not None:
        batch = {"embeds": jnp.asarray(
                     rng.randn(b, cfg.frontend.n_positions,
                               cfg.frontend.input_dim), jnp.float32),
                 "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)),
                                       jnp.int32)}
    else:
        batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)),
                                       jnp.int32)}

    state = init_train_state(cfg, ocfg, jax.random.PRNGKey(0))

    # single-device reference
    step_ref = jax.jit(make_train_step(cfg, ocfg))
    _, m_ref = step_ref(jax.tree.map(jnp.copy, state), batch)

    # sharded
    dist = DistContext(mesh)
    st_axes = state_axes(cfg, ocfg)
    state_sh = dist.param_shardings(train_state_shapes(cfg, ocfg), st_axes)
    batch_sh = {k: dist.named(dist.batch_pspec(v.ndim, b))
                for k, v in batch.items()}
    state_d = jax.device_put(state, state_sh)
    batch_d = jax.device_put(batch, batch_sh)
    with mesh:
        step_sh = jax.jit(make_train_step(cfg, ocfg, dist=dist),
                          in_shardings=(state_sh, batch_sh),
                          out_shardings=(state_sh, None))
        new_state, m_sh = step_sh(state_d, batch_d)
        jax.block_until_ready(new_state.params)

    err = abs(float(m_ref["loss"]) - float(m_sh["loss"]))
    rel = err / max(abs(float(m_ref["loss"])), 1e-9)
    print(f"{arch}: ref={float(m_ref['loss']):.6f} "
          f"sharded={float(m_sh['loss']):.6f} rel={rel:.2e}", flush=True)
    return rel


def main():
    archs = sys.argv[1:] or ["moonshot_v1_16b_a3b", "gemma3_1b",
                             "mamba2_130m", "recurrentgemma_2b",
                             "deepseek_v3_671b", "hubert_xlarge",
                             "internvl2_1b", "stablelm_1_6b"]
    from repro.launch.mesh import make_smoke_mesh
    mesh = make_smoke_mesh(n_data=2, n_model=2, pods=2)  # (2,2,2) = 8 devices
    worst = 0.0
    for a in archs:
        worst = max(worst, check_arch(a, mesh))
    if worst > 2e-3:
        print(f"FAIL: worst rel err {worst}")
        sys.exit(1)
    print(f"OK worst rel err {worst:.2e}")


if __name__ == "__main__":
    main()
