"""End-to-end fault tolerance: checkpoint/restart + KSA redelivery.

The flagship test kills an agent mid-training-chunk and verifies the campaign
completes on a surviving agent with the SAME final loss as an uninterrupted
run (bit-reproducible recovery — the paper's at-least-once semantics applied
to training)."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_checkpoint, \
    save_checkpoint
from repro.core import Broker, MonitorAgent, Submitter, WorkerAgent
from repro.data import batch_at
from repro.optim import OptimizerConfig
from repro.train import init_train_state
from repro.train.trainer import TrainCampaign
from repro.configs import smoke_config


def test_checkpoint_roundtrip_and_checksum(tmp_path):
    cfg = smoke_config("stablelm_1_6b")
    ocfg = OptimizerConfig()
    state = init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
    path = save_checkpoint(tmp_path, 7, state, extra={"loss": 1.25})
    like = jax.eval_shape(lambda: state)
    restored, extra = restore_checkpoint(path, like)
    assert extra == {"loss": 1.25}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_corruption_detected(tmp_path):
    cfg = smoke_config("mamba2_130m")
    state = init_train_state(cfg, OptimizerConfig(), jax.random.PRNGKey(0))
    path = save_checkpoint(tmp_path, 1, state)
    shard = next(iter(sorted((tmp_path / "ckpt_00000001").glob("*.zst"))))
    raw = bytearray(shard.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    shard.write_bytes(bytes(raw))
    with pytest.raises(Exception):
        restore_checkpoint(path, jax.eval_shape(lambda: state))


def test_manager_retention_and_latest(tmp_path):
    cfg = smoke_config("mamba2_130m")
    state = init_train_state(cfg, OptimizerConfig(), jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.steps() == [3, 4]
    assert mgr.latest()[0] == 4


def test_async_save_overlaps(tmp_path):
    cfg = smoke_config("mamba2_130m")
    state = init_train_state(cfg, OptimizerConfig(), jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path, keep=2)
    h = mgr.async_save(11, state)
    p = h.result(timeout=60)
    assert mgr.latest()[0] == 11
    restored, _ = restore_checkpoint(p, jax.eval_shape(lambda: state))
    np.testing.assert_array_equal(np.asarray(restored.step),
                                  np.asarray(state.step))


def test_deterministic_data_is_offset_addressable():
    cfg = smoke_config("stablelm_1_6b")
    b1 = batch_at(cfg, seed=3, step=17, batch=4, seq=32)
    b2 = batch_at(cfg, seed=3, step=17, batch=4, seq=32)
    b3 = batch_at(cfg, seed=3, step=18, batch=4, seq=32)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])


@pytest.fixture
def ksa(tmp_path):
    broker = Broker(default_partitions=2, session_timeout_s=1.0)
    sub = Submitter(broker, "tr")
    mon = MonitorAgent(broker, "tr", task_timeout_s=4.0,
                       poll_interval_s=0.01, max_attempts=4).start()
    agents = []

    def add_agent(**kw):
        a = WorkerAgent(broker, "tr", poll_interval_s=0.01, slots=1,
                        heartbeat_interval_s=0.2, **kw).start()
        agents.append(a)
        return a

    yield broker, sub, mon, add_agent
    for a in agents:
        a.stop()
    mon.stop()
    broker.close()


def _run_campaign(tmp_path, sub, mon, total=12, chunk=4):
    return TrainCampaign(
        None, sub, mon, arch="mamba2_130m",
        ckpt_dir=str(tmp_path / "ckpts"), total_steps=total,
        chunk_steps=chunk, batch=4, seq=32, timeout_s=90.0).run(
            wait_timeout=240.0)


def test_training_campaign_completes(ksa, tmp_path):
    broker, sub, mon, add_agent = ksa
    add_agent()
    out = _run_campaign(tmp_path, sub, mon)
    assert out["final_step"] == 12
    assert np.isfinite(out["final_loss"])
    mgr = CheckpointManager(tmp_path / "ckpts")
    assert mgr.latest()[0] == 12


def test_agent_crash_midchunk_campaign_recovers(ksa, tmp_path):
    """Kill the only agent during chunk 2; bring up a replacement; the
    monitor's watchdog resubmits and the campaign finishes with the exact
    same loss as an uninterrupted control run."""
    broker, sub, mon, add_agent = ksa
    a1 = add_agent()

    result_box = {}

    def drive():
        result_box["out"] = _run_campaign(tmp_path, sub, mon)

    t = threading.Thread(target=drive, daemon=True)
    t.start()
    # wait for the second chunk to start running, then kill the agent
    deadline = time.time() + 120
    while time.time() < deadline:
        e = mon.task("train-mamba2_130m-s000004")
        if e is not None and e.status == "RUNNING":
            break
        time.sleep(0.02)
    assert e is not None, "second chunk never started"
    a1.crash()
    a2 = add_agent()
    t.join(timeout=300)
    assert "out" in result_box, "campaign did not finish after recovery"
    out = result_box["out"]
    assert out["final_step"] == 12

    # control: clean run in a fresh directory must agree exactly
    ctl_dir = tmp_path / "control"
    out_ctl = TrainCampaign(
        None, sub, mon, arch="mamba2_130m", ckpt_dir=str(ctl_dir / "ckpts"),
        total_steps=12, chunk_steps=4, batch=4, seq=32,
        timeout_s=90.0).run(wait_timeout=240.0)
    assert out_ctl["final_step"] == 12
    np.testing.assert_allclose(out["final_loss"], out_ctl["final_loss"],
                               rtol=1e-5)
    assert mon.resubmissions >= 1
