"""Subprocess helper: the OPTIMIZED sharded paths (flash_decode, chunked_ce,
fp8_gather) must match the single-device reference / baseline sharded path."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import smoke_config
from repro.models import init_params, model_spec
from repro.models.transformer import forward, init_caches
from repro.optim import OptimizerConfig
from repro.sharding import DistContext, state_axes
from repro.train import init_train_state, make_train_step, make_serve_step
from repro.train.step import train_state_shapes
from repro.launch.mesh import make_smoke_mesh


def check_train_chunked_ce(mesh, arch="gemma3_1b"):
    cfg = smoke_config(arch)
    ocfg = OptimizerConfig(lr=1e-2, warmup_steps=0, schedule="constant",
                           weight_decay=0.0)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 32)), jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 32)), jnp.int32)}
    state = init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
    _, m_ref = jax.jit(make_train_step(cfg, ocfg))(jax.tree.map(jnp.copy, state), batch)

    dist = DistContext(mesh, flags=frozenset({"chunked_ce", "fp8_gather"}))
    st_sh = dist.param_shardings(train_state_shapes(cfg, ocfg), state_axes(cfg, ocfg))
    b_sh = {k: dist.named(dist.batch_pspec(v.ndim, 4)) for k, v in batch.items()}
    with mesh:
        step = jax.jit(make_train_step(cfg, ocfg, dist=dist),
                       in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None))
        _, m_opt = step(jax.device_put(state, st_sh), jax.device_put(batch, b_sh))
    rel = abs(float(m_ref["loss"]) - float(m_opt["loss"])) / abs(float(m_ref["loss"]))
    print(f"chunked_ce {arch}: ref={float(m_ref['loss']):.6f} opt={float(m_opt['loss']):.6f} rel={rel:.2e}")
    assert rel < 2e-3, rel


def check_fp8_gather_moe(mesh):
    cfg = smoke_config("moonshot_v1_16b_a3b")
    ocfg = OptimizerConfig(lr=1e-2, warmup_steps=0, schedule="constant", weight_decay=0.0)
    rng = np.random.RandomState(1)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 32)), jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 32)), jnp.int32)}
    state = init_train_state(cfg, ocfg, jax.random.PRNGKey(1))
    _, m_ref = jax.jit(make_train_step(cfg, ocfg))(jax.tree.map(jnp.copy, state), batch)
    dist = DistContext(mesh, flags=frozenset({"fp8_gather"}))
    st_sh = dist.param_shardings(train_state_shapes(cfg, ocfg), state_axes(cfg, ocfg))
    b_sh = {k: dist.named(dist.batch_pspec(v.ndim, 4)) for k, v in batch.items()}
    with mesh:
        step = jax.jit(make_train_step(cfg, ocfg, dist=dist),
                       in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None))
        _, m_opt = step(jax.device_put(state, st_sh), jax.device_put(batch, b_sh))
    rel = abs(float(m_ref["loss"]) - float(m_opt["loss"])) / abs(float(m_ref["loss"]))
    print(f"fp8_gather moe: ref={float(m_ref['loss']):.6f} opt={float(m_opt['loss']):.6f} rel={rel:.2e}")
    assert rel < 2e-2, rel  # fp8 forward-quantization tolerance


def check_flash_decode(mesh, arch):
    cfg = smoke_config(arch)
    params = init_params(model_spec(cfg), jax.random.PRNGKey(2), jnp.dtype(cfg.dtype))
    rng = np.random.RandomState(2)
    seq = 32
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (4, seq)), jnp.int32)
    ref_logits, _, _ = forward(params, cfg, {"tokens": tokens})

    dist = DistContext(mesh, flags=frozenset({"flash_decode"}))
    from repro.models.params import param_shapes as pshapes
    from repro.sharding.state import params_axes
    p_sh = dist.param_shardings(pshapes(model_spec(cfg), jnp.dtype(cfg.dtype)),
                                params_axes(cfg))
    from repro.launch.specs import cache_sharding_tree, decode_cache_shapes
    caches = init_caches(cfg, 4, seq, jnp.dtype(cfg.dtype))
    c_sh = cache_sharding_tree(dist, cfg, jax.eval_shape(lambda: caches), 4)
    caches = jax.device_put(caches, c_sh)
    params_d = jax.device_put(params, p_sh)
    with mesh:
        serve = jax.jit(make_serve_step(cfg, dist=dist),
                        in_shardings=(p_sh, dist.named(P("data", None)),
                                      c_sh, dist.named(P())),
                        out_shardings=(None, None, c_sh))
        errs = []
        for t in range(seq):
            logits, _, caches = serve(params_d, tokens[:, t:t+1], caches,
                                      jnp.asarray(t, jnp.int32))
            errs.append(float(jnp.abs(logits[:, :cfg.padded_vocab] - ref_logits[:, t]).max()))
    print(f"flash_decode {arch}: max err {max(errs):.2e}")
    assert max(errs) < 5e-2, max(errs)


def main():
    mesh = make_smoke_mesh(n_data=2, n_model=4)
    check_train_chunked_ce(mesh, "gemma3_1b")
    check_train_chunked_ce(mesh, "stablelm_1_6b")
    check_fp8_gather_moe(mesh)
    check_flash_decode(mesh, "stablelm_1_6b")
    check_flash_decode(mesh, "deepseek_v3_671b")
    check_ws_decode(mesh)
    print("OPT OK")


if __name__ == "__main__":
    main()


def check_ws_decode(mesh):
    """weight_stationary MoE decode must match the baseline decode exactly."""
    from repro.launch.specs import cache_sharding_tree
    cfg = smoke_config("deepseek_v3_671b")
    params = init_params(model_spec(cfg), jax.random.PRNGKey(5), jnp.dtype(cfg.dtype))
    rng = np.random.RandomState(5)
    seq = 24
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (4, seq)), jnp.int32)
    ref_logits, _, _ = forward(params, cfg, {"tokens": tokens})
    dist = DistContext(mesh, flags=frozenset({"flash_decode",
                                              "weight_stationary"}))
    from repro.models.params import param_shapes as pshapes
    from repro.sharding.state import params_axes
    p_sh = dist.param_shardings(pshapes(model_spec(cfg), jnp.dtype(cfg.dtype)),
                                params_axes(cfg))
    caches = init_caches(cfg, 4, seq, jnp.dtype(cfg.dtype))
    c_sh = cache_sharding_tree(dist, cfg, jax.eval_shape(lambda: caches), 4)
    caches = jax.device_put(caches, c_sh)
    params_d = jax.device_put(params, p_sh)
    with mesh:
        serve = jax.jit(make_serve_step(cfg, dist=dist),
                        in_shardings=(p_sh, dist.named(P("data", None)),
                                      c_sh, dist.named(P())),
                        out_shardings=(None, None, c_sh))
        errs = []
        for t in range(seq):
            logits, _, caches = serve(params_d, tokens[:, t:t+1], caches,
                                      jnp.asarray(t, jnp.int32))
            errs.append(float(jnp.abs(logits - ref_logits[:, t]).max()))
    print(f"weight_stationary decode: max err {max(errs):.2e}")
    assert max(errs) < 5e-2, max(errs)
