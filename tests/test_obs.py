"""Observability layer (repro.obs): the metrics registry (counters, gauges,
per-class latency histograms with exact quantiles, Prometheus text render),
the bounded span store with linked per-attempt chains (submit → grant →
claim → run → commit / revoke), the monitor's /metrics and /trace/<id>
endpoints, KsaCluster.trace / campaign_report, real-RSS mem policing, and
the schema-stability guarantees for the legacy stats()/status()/summary()
views that now read through the registry."""
import json
import re
import time
import urllib.error
import urllib.request

import pytest

from repro.cluster import KsaCluster
from repro.core import (Broker, ClusterComputing, Consumer, FairShare,
                        RevokeReason, Submitter)
from repro.core.monitor import ROUTES
from repro.obs import (DEFAULT_BUCKETS, MetricsRegistry, NullSpanStore,
                       SpanStore, sample_rss_mb, topic_class)
from repro.pipeline import PipelineAgent, PipelineSpec, RetryPolicy, Stage


def _wait(cond, timeout=10.0, interval=0.01):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return False


def _get(port, path):
    with urllib.request.urlopen(f"http://127.0.0.1:{port}{path}") as r:
        body = r.read()
        ctype = r.headers.get("Content-Type", "")
    return body, ctype


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_and_label_interning():
    reg = MetricsRegistry()
    c = reg.counter("t_total", "help", labels=("event",))
    c.labels(event="a").inc()
    c.labels(event="a").inc(2)
    c.labels(event="b").inc()
    assert c.labels(event="a").value == 3
    assert c.labels(event="b").value == 1
    assert c.labels(event="a") is c.labels(event="a")  # interned child
    g = reg.gauge("t_gauge")
    g.set(5)
    g.dec(2)
    assert g.value == 3.0
    # registering the same name again returns the same family; a type or
    # label mismatch is a programming error
    assert reg.counter("t_total", labels=("event",)) is c
    with pytest.raises(ValueError):
        reg.gauge("t_total")
    with pytest.raises(ValueError):
        reg.counter("t_total", labels=("other",))
    with pytest.raises(ValueError):
        c.labels(wrong="a")


def test_histogram_buckets_and_exact_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("t_seconds", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    assert h.count == 5
    assert h.sum == pytest.approx(56.05)
    snap = h._default().snapshot()
    # cumulative buckets: le=0.1 -> 1, le=1.0 -> 3, le=10.0 -> 4, +Inf -> 5
    assert snap["buckets"] == {0.1: 1, 1.0: 3, 10.0: 4}
    assert snap["inf"] == 5
    assert h.quantile(0.5) == 0.5
    p = h.percentiles()
    assert p["p50"] == 0.5 and p["p99"] == 50.0
    # exactness comes from the sample ring, not bucket interpolation
    assert h.quantile(0.0) == 0.05 and h.quantile(1.0) == 50.0


def test_prometheus_render_format():
    reg = MetricsRegistry()
    reg.counter("ksa_x_total", "things", labels=("cls",)).labels(
        cls="gpu").inc(4)
    reg.histogram("ksa_y_seconds", "lat", buckets=(1.0,)).observe(0.5)
    reg.register_callback("ksa_live", lambda: 7.0, "live")
    text = reg.render()
    assert "# HELP ksa_x_total things" in text
    assert "# TYPE ksa_x_total counter" in text
    assert 'ksa_x_total{cls="gpu"} 4' in text
    assert "# TYPE ksa_y_seconds histogram" in text
    assert 'ksa_y_seconds_bucket{le="1"} 1' in text
    assert 'ksa_y_seconds_bucket{le="+Inf"} 1' in text
    assert "ksa_y_seconds_sum 0.5" in text
    assert "ksa_y_seconds_count 1" in text
    assert "ksa_live 7" in text
    assert text.endswith("\n")


def test_topic_class_label():
    assert topic_class("t-new.gpu") == "gpu"
    assert topic_class("t-new.bigmem") == "bigmem"
    assert topic_class("t-new") == "flat"
    assert topic_class("t-done") == "flat"


def test_span_store_is_bounded_lru():
    store = SpanStore(max_tasks=3, max_spans_per_task=2)
    for i in range(5):
        store.add(f"t{i}", "submit", float(i))
    assert store.tasks() == ["t2", "t3", "t4"]  # t0, t1 LRU-evicted
    assert store.stats()["evicted_tasks"] == 2
    store.add("t4", "grant", 10.0)
    store.add("t4", "run", 11.0)  # over per-task cap: dropped, counted
    assert [s["name"] for s in store.trace("t4")] == ["submit", "grant"]
    assert store.stats()["dropped_spans"] == 1
    assert store.trace("unknown") == []
    # sorted by start, seq breaks ties; returned spans are copies
    store.add("tie", "b", 1.0)
    store.add("tie", "a", 1.0)
    chain = store.trace("tie")
    assert [s["name"] for s in chain] == ["b", "a"]
    chain[0]["name"] = "mutated"
    assert store.trace("tie")[0]["name"] == "b"


# ---------------------------------------------------------------------------
# span chains through the control plane
# ---------------------------------------------------------------------------

def test_flat_task_span_chain_and_http_surface():
    with KsaCluster(prefix="obs1", workers=1, worker_slots=2, http=True,
                    poll_interval_s=0.005) as c:
        tids = [c.submit("sleep", params={"duration": 0.01})
                for _ in range(3)]
        assert c.wait_all(tids, timeout=10.0)
        for tid in tids:
            names = [s["name"] for s in c.trace(tid)]
            assert names == ["submit", "grant", "claim", "run", "commit"]
            run = [s for s in c.trace(tid) if s["name"] == "run"][0]
            assert run["ok"] is True and run["attempt"] == 0
            assert run["dur_s"] >= 0.0
        port = c.http_port

        # GET / lists every route (the index is lint-checked below)
        body, _ = _get(port, "/")
        assert json.loads(body)["endpoints"] == list(ROUTES)

        # GET /metrics serves Prometheus text with per-class histograms
        body, ctype = _get(port, "/metrics")
        text = body.decode()
        assert ctype.startswith("text/plain")
        assert "0.0.4" in ctype
        assert re.search(
            r'ksa_task_queue_wait_seconds_bucket\{cls="cpu",le="\+Inf"\} 3',
            text)
        assert re.search(r'ksa_task_run_seconds_count\{cls="cpu"\} 3', text)
        assert re.search(r'ksa_result_commit_seconds_count\{cls="cpu"\} 3',
                         text)
        assert 'event="completed"' in text

        # GET /trace/<id> returns the chain; unknown ids are a 404
        body, _ = _get(port, f"/trace/{tids[0]}")
        payload = json.loads(body)
        assert payload["task_id"] == tids[0]
        assert [s["name"] for s in payload["spans"]] == \
            ["submit", "grant", "claim", "run", "commit"]
        with pytest.raises(urllib.error.HTTPError) as err:
            _get(port, "/trace/no-such-task")
        assert err.value.code == 404


def test_preempted_and_retried_task_has_one_linked_chain():
    """ISSUE acceptance: KsaCluster.trace(task_id) returns the complete
    submit→terminal span chain for a preempted-and-retried task — attempt 0
    ends in a revoke(preempt) span, attempt 1 in run+commit, all under one
    task id and one trace_id."""
    big = PipelineSpec("obs-big", [
        Stage("work", "sleep", fan_out=1, params={"duration": 0.8},
              retry=RetryPolicy(max_attempts=3, timeout_s=60.0,
                                max_preemptions=6)),
    ])
    small = PipelineSpec("obs-small", [
        Stage("work", "sleep", fan_out=1, params={"duration": 0.05},
              retry=RetryPolicy(max_attempts=3, timeout_s=60.0)),
    ])
    with KsaCluster(prefix="obs2", workers=1, worker_slots=2,
                    poll_interval_s=0.005, lease=FairShare(preempt_factor=1.5),
                    max_in_flight_total=2) as c:
        bid = c.submit_campaign(big, list(range(6)), weight=1.0)
        time.sleep(0.3)
        sid = c.submit_campaign(small, list(range(2)), weight=4.0)
        assert c.wait_campaign(sid, timeout=60.0).state == "COMPLETED"
        assert c.wait_campaign(bid, timeout=120.0).state == "COMPLETED"
        assert c.pipeline.preemptions >= 1

        preempted = []
        for _stage, tids in c.pipeline.stage_tasks(bid):
            for tid in tids:
                if any(s["name"] == "revoke"
                       and s.get("reason") == RevokeReason.PREEMPT
                       for s in c.trace(tid)):
                    preempted.append(tid)
        assert preempted, "no preempted task left a revoke span"

        spans = c.trace(preempted[0])
        names = [(s["name"], s.get("attempt")) for s in spans]
        revoked_attempt = next(s["attempt"] for s in spans
                               if s["name"] == "revoke")
        # attempt n was granted then revoked for preemption ...
        assert ("grant", revoked_attempt) in names
        assert ("revoke", revoked_attempt) in names
        # ... and a later attempt of the SAME task id ran to commit
        terminal = [s for s in spans if s["name"] == "run" and s["ok"]]
        assert terminal and terminal[-1]["attempt"] > revoked_attempt
        # the terminal attempt reached a durable commit record: either the
        # monitor's commit span or the pipeline's journaled TaskDone
        assert any(s["name"] == "commit" or
                   (s["name"] == "journal" and s.get("event") == "TaskDone")
                   for s in spans)
        # every span that carries a trace id agrees on it
        tid0 = preempted[0]
        trace_ids = {s["trace_id"] for s in spans if "trace_id" in s}
        assert trace_ids == {tid0}
        # the registry agrees with the span story
        snap = c.broker.metrics.snapshot()
        revoked = snap["ksa_leases_revoked_total"]["series"]
        assert revoked[(RevokeReason.PREEMPT,)] == \
            c.broker.lease_stats()["revoked"]["preempt"] >= 1


def test_every_revoke_reason_is_counted_and_spanned():
    broker = Broker(default_partitions=2)
    try:
        sub = Submitter(broker, "rv")
        submitted = [sub.submit("sleep", params={"duration": 0.01})
                     for _ in RevokeReason.ALL]
        cons = Consumer(broker, ["rv-new.cpu"], group_id="rv-agents",
                        member_id="rv-m1")
        leased: list = []
        deadline = time.time() + 5.0
        while len(leased) < len(submitted) and time.time() < deadline:
            leased += [r.key for r in cons.lease(max_records=8, timeout=0.5)]
        assert sorted(leased) == sorted(submitted)
        tids = []
        for tid, reason in zip(leased, RevokeReason.ALL):
            assert broker.revoke_lease(tid, reason, requeue=False)
            tids.append((tid, reason))
        stats = broker.lease_stats()["revoked"]
        snap = broker.metrics.snapshot()
        series = snap["ksa_leases_revoked_total"]["series"]
        for tid, reason in tids:
            assert stats[reason] == 1
            assert series[(reason,)] == 1
            revokes = [s for s in broker.spans.trace(tid)
                       if s["name"] == "revoke"]
            assert len(revokes) == 1
            assert revokes[0]["reason"] == reason
            assert revokes[0]["requeued"] is False
    finally:
        broker.close()


def test_drain_keeps_counters_consistent():
    """Churn (graceful drain mid-burst) must not lose decrements: after the
    dust settles active leases are zero, grants == completions, and every
    task has exactly one ok run span."""
    with KsaCluster(prefix="obs3", workers=1, worker_slots=2,
                    poll_interval_s=0.005) as c:
        tids = [c.submit("sleep", params={"duration": 0.05})
                for _ in range(8)]
        w2 = c.add_worker(slots=2)
        time.sleep(0.1)
        assert c.drain_worker(w2, timeout_s=20.0)
        assert c.wait_all(tids, timeout=20.0)
        assert _wait(lambda: c.broker.lease_stats()["active"] == 0)
        stats = c.broker.lease_stats()
        assert stats["completed"] == len(tids)
        # every grant reached exactly one terminal: committed or revoked
        assert stats["granted"] == stats["completed"] + stats["failed"] + \
            stats["revoked_total"]
        for tid in tids:
            runs = [s for s in c.trace(tid) if s["name"] == "run" and s["ok"]]
            assert len(runs) == 1, f"{tid}: {runs}"
        # render-time callback gauge reflects the drained state
        assert "ksa_leases_active 0" in c.metrics_text()


def test_recover_refolds_journal_and_times_it():
    """Orchestrator crash + recover(): the journal fold shows up in the
    ksa_journal_fold_seconds histogram, journal counters keep counting on
    the successor, and finished tasks still have complete span chains."""
    broker = Broker(default_partitions=2)
    spec = PipelineSpec("obs-rec", [
        Stage("work", "sleep", fan_out=1, params={"duration": 0.05},
              retry=RetryPolicy(max_attempts=3, timeout_s=30.0)),
    ])
    try:
        from repro.core import WorkerAgent
        w = WorkerAgent(broker, "rc", slots=2, poll_interval_s=0.005).start()
        pipe1 = PipelineAgent(broker, "rc", poll_interval_s=0.005).start()
        cid = pipe1.submit_campaign(spec, list(range(6)))
        assert _wait(lambda: pipe1.status(cid).stages["work"].done >= 1,
                     timeout=30.0)
        pipe1.crash()

        pipe2 = PipelineAgent(broker, "rc", agent_id="rec2",
                              poll_interval_s=0.005).start()
        assert pipe2.recover([spec]) == [cid]
        st = pipe2.wait(cid, timeout=60.0)
        assert st.state == "COMPLETED", st.failure
        snap = broker.metrics.snapshot()
        fold = snap["ksa_journal_fold_seconds"]["series"][()]
        assert fold["count"] >= 1
        assert pipe2.events_journaled > 0
        # both agents fed the same per-agent journal counter family
        journal = snap["ksa_journal_events_total"]["series"]
        assert sum(journal.values()) >= pipe2.events_journaled
        for _stage, tids in pipe2.stage_tasks(cid):
            for tid in tids:
                names = [s["name"] for s in broker.spans.trace(tid)]
                assert "run" in names and "journal" in names
        pipe2.stop()
        w.stop()
    finally:
        broker.close()


# ---------------------------------------------------------------------------
# legacy views / schema stability
# ---------------------------------------------------------------------------

def test_legacy_stats_schemas_are_views_over_registry():
    cfg = None
    with KsaCluster(prefix="obs4", workers=1, worker_slots=2, http=True,
                    poll_interval_s=0.005) as c:
        tids = [c.submit("sleep", params={"duration": 0.01})
                for _ in range(4)]
        assert c.wait_all(tids, timeout=10.0)
        w = c.agents[0]
        s = w.stats()
        # pre-obs stats() keys unchanged (these are asserted across the
        # existing suite too — this is the canary)
        for key in ("agent_id", "kind", "state", "in_flight", "slots",
                    "completed", "failed", "rerouted", "deferred",
                    "requeued", "revoked", "dropped_revoked", "mem_revoked",
                    "heartbeat_failures"):
            assert key in s, key
        assert s["completed"] == w.tasks_completed == 4
        assert isinstance(w.tasks_completed, int)
        # the same number read through the registry
        snap = c.broker.metrics.snapshot()
        events = snap["ksa_agent_events_total"]["series"]
        assert events[(w.agent_id, "completed")] == 4
        summary = c.monitor.summary()
        for key in ("tasks", "done", "by_status", "results_handled",
                    "resubmissions", "revocations", "compactions",
                    "legacy_forwards", "duplicates_fenced"):
            assert key in summary, key
        assert summary["results_handled"] == \
            snap["ksa_monitor_events_total"]["series"][
                (c.monitor.monitor_id, "results_handled")]
        lease = c.broker.lease_stats()
        for key in ("granted", "completed", "failed", "requeued", "active",
                    "revoked", "revoked_total", "stale_drops"):
            assert key in lease, key
        assert set(lease["revoked"]) == set(RevokeReason.ALL)
        port = c.http_port
        body, _ = _get(port, "/summary")
        assert json.loads(body)["done"] == 4
        cfg = c.status()
    for key in ("prefix", "started", "agents", "broker", "leases", "monitor"):
        assert key in cfg, key


def test_monitor_route_index_lint():
    """Repo lint (pytest-collected): every literal route dispatched in
    MonitorAgent's do_GET must be listed in ROUTES (served by GET /), and
    vice versa — so the index payload can't silently rot."""
    import inspect

    import repro.core.monitor as monitor_mod
    src = inspect.getsource(monitor_mod)
    m = re.search(r"def do_GET\(self\).*", src, re.S)
    assert m, "could not locate do_GET dispatch block"
    body = m.group(0)
    dispatched = set(re.findall(r'parts == \["(\w+)"\]', body))
    dispatched |= set(re.findall(r'parts\[0\] == "(\w+)"', body))
    dispatched.add("")  # the `if not parts:` index route
    indexed = {r.strip("/").split("/")[0] for r in ROUTES}
    assert dispatched == indexed, (
        f"monitor routes drifted: dispatched={sorted(dispatched)} "
        f"vs ROUTES={sorted(indexed)}")


# ---------------------------------------------------------------------------
# RSS sampling (mem-overage policing measures, not trusts)
# ---------------------------------------------------------------------------

def test_sample_rss_mb_reads_kernel_accounting():
    rss = sample_rss_mb(cached=False)
    assert rss > 1.0  # a live CPython interpreter is many MB resident
    assert sample_rss_mb() == pytest.approx(sample_rss_mb(), rel=0.5)


def test_mem_used_is_measured_with_report_override():
    from repro.core.messages import TaskMessage

    class _Quiet(ClusterComputing):
        def run(self):
            return {}

    broker = Broker(default_partitions=1)
    from repro.core import Producer
    t = _Quiet(TaskMessage(task_id="m1", script="quiet"),
               Producer(broker), "mm", "agent-x")
    broker.close()
    # kernel-measured delta vs construction-time baseline: near zero for a
    # task that allocated nothing, never negative
    assert 0.0 <= t.mem_used_mb < 64.0
    # the legacy self-reporting hook remains as an explicit override
    t.report_mem(512.0)
    assert t.mem_used_mb == 512.0
    t.mem_used_mb = 1024.0
    assert t.mem_used_mb == 1024.0


# ---------------------------------------------------------------------------
# the obs switch and overhead posture
# ---------------------------------------------------------------------------

def test_obs_disabled_keeps_counters_but_drops_traces():
    reg = MetricsRegistry(enabled=False)
    h = reg.histogram("off_seconds")
    h.observe(1.0)
    assert h.count == 0 and h.quantile(0.5) is None
    c = reg.counter("off_total")
    c.inc()
    assert c.value == 1  # counters never turn off

    with KsaCluster(prefix="obs5", workers=1, worker_slots=2, obs=False,
                    poll_interval_s=0.005) as c:
        assert isinstance(c.broker.spans, NullSpanStore)
        tids = [c.submit("sleep", params={"duration": 0.0})
                for _ in range(3)]
        assert c.wait_all(tids, timeout=10.0)
        assert c.trace(tids[0]) == []
        # the legacy views still work: counters stay live
        assert c.broker.lease_stats()["completed"] == 3
        assert c.agents[0].tasks_completed == 3
        text = c.metrics_text()  # /metrics still serves, minus histogram data
        assert "ksa_leases_granted_total 3" in text
        # histogram series render but record nothing (null observations)
        assert 'ksa_task_run_seconds_count{cls="cpu"} 0' in text


def test_campaign_report_splits_queue_run_retry():
    spec = PipelineSpec("obs-rep", [
        Stage("a", "sleep", fan_out=1, params={"duration": 0.05}),
        Stage("b", "sleep", depends_on=("a",), params={"duration": 0.02}),
    ])
    with KsaCluster(prefix="obs6", workers=1, worker_slots=2,
                    poll_interval_s=0.005) as c:
        cid = c.submit_campaign(spec, list(range(3)))
        assert c.wait_campaign(cid, timeout=30.0).state == "COMPLETED"
        rep = c.campaign_report(cid)
        assert rep["campaign_id"] == cid and rep["state"] == "COMPLETED"
        assert list(rep["stages"]) == ["a", "b"]  # topological order
        a = rep["stages"]["a"]
        assert a["tasks"] == a["traced"] == 3
        assert a["run_s"] >= 3 * 0.04  # three 50 ms tasks actually ran
        assert a["queue_s"] >= 0.0 and a["retry_s"] == 0.0
        assert a["wall_s"] > 0.0
        assert rep["dominant_stage"] in ("a", "b")
        assert rep["wall_s"] >= max(s["run_s"] for s in
                                    rep["stages"].values()) / 2
