"""Observability layer (repro.obs): the metrics registry (counters, gauges,
per-class latency histograms with exact quantiles, Prometheus text render),
the bounded span store with linked per-attempt chains (submit → grant →
claim → run → commit / revoke), the monitor's /metrics and /trace/<id>
endpoints, KsaCluster.trace / campaign_report, real-RSS mem policing, and
the schema-stability guarantees for the legacy stats()/status()/summary()
views that now read through the registry."""
import json
import re
import time
import urllib.error
import urllib.request

import pytest

from repro.cluster import KsaCluster
from repro.core import (Broker, ClusterComputing, Consumer, FairShare,
                        RevokeReason, Submitter)
from repro.core.messages import topic_names
from repro.core.monitor import ROUTES
from repro.obs import (DEFAULT_BUCKETS, AlertEngine, AlertRule,
                       FlightRecorder, MetricsRegistry, NullSpanStore,
                       SloSpec, SpanStore, TelemetryCollector,
                       TimeSeriesStore, merge_renders, sample_rss_mb,
                       topic_class)
from repro.pipeline import PipelineAgent, PipelineSpec, RetryPolicy, Stage


def _wait(cond, timeout=10.0, interval=0.01):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return False


def _get(port, path):
    with urllib.request.urlopen(f"http://127.0.0.1:{port}{path}") as r:
        body = r.read()
        ctype = r.headers.get("Content-Type", "")
    return body, ctype


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_and_label_interning():
    reg = MetricsRegistry()
    c = reg.counter("t_total", "help", labels=("event",))
    c.labels(event="a").inc()
    c.labels(event="a").inc(2)
    c.labels(event="b").inc()
    assert c.labels(event="a").value == 3
    assert c.labels(event="b").value == 1
    assert c.labels(event="a") is c.labels(event="a")  # interned child
    g = reg.gauge("t_gauge")
    g.set(5)
    g.dec(2)
    assert g.value == 3.0
    # registering the same name again returns the same family; a type or
    # label mismatch is a programming error
    assert reg.counter("t_total", labels=("event",)) is c
    with pytest.raises(ValueError):
        reg.gauge("t_total")
    with pytest.raises(ValueError):
        reg.counter("t_total", labels=("other",))
    with pytest.raises(ValueError):
        c.labels(wrong="a")


def test_histogram_buckets_and_exact_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("t_seconds", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    assert h.count == 5
    assert h.sum == pytest.approx(56.05)
    snap = h._default().snapshot()
    # cumulative buckets: le=0.1 -> 1, le=1.0 -> 3, le=10.0 -> 4, +Inf -> 5
    assert snap["buckets"] == {0.1: 1, 1.0: 3, 10.0: 4}
    assert snap["inf"] == 5
    assert h.quantile(0.5) == 0.5
    p = h.percentiles()
    assert p["p50"] == 0.5 and p["p99"] == 50.0
    # exactness comes from the sample ring, not bucket interpolation
    assert h.quantile(0.0) == 0.05 and h.quantile(1.0) == 50.0


def test_prometheus_render_format():
    reg = MetricsRegistry()
    reg.counter("ksa_x_total", "things", labels=("cls",)).labels(
        cls="gpu").inc(4)
    reg.histogram("ksa_y_seconds", "lat", buckets=(1.0,)).observe(0.5)
    reg.register_callback("ksa_live", lambda: 7.0, "live")
    text = reg.render()
    assert "# HELP ksa_x_total things" in text
    assert "# TYPE ksa_x_total counter" in text
    assert 'ksa_x_total{cls="gpu"} 4' in text
    assert "# TYPE ksa_y_seconds histogram" in text
    assert 'ksa_y_seconds_bucket{le="1"} 1' in text
    assert 'ksa_y_seconds_bucket{le="+Inf"} 1' in text
    assert "ksa_y_seconds_sum 0.5" in text
    assert "ksa_y_seconds_count 1" in text
    assert "ksa_live 7" in text
    assert text.endswith("\n")


def test_topic_class_label():
    assert topic_class("t-new.gpu") == "gpu"
    assert topic_class("t-new.bigmem") == "bigmem"
    assert topic_class("t-new") == "flat"
    assert topic_class("t-done") == "flat"


def test_span_store_is_bounded_lru():
    store = SpanStore(max_tasks=3, max_spans_per_task=2)
    for i in range(5):
        store.add(f"t{i}", "submit", float(i))
    assert store.tasks() == ["t2", "t3", "t4"]  # t0, t1 LRU-evicted
    assert store.stats()["evicted_tasks"] == 2
    store.add("t4", "grant", 10.0)
    store.add("t4", "run", 11.0)  # over per-task cap: dropped, counted
    assert [s["name"] for s in store.trace("t4")] == ["submit", "grant"]
    assert store.stats()["dropped_spans"] == 1
    assert store.trace("unknown") == []
    # sorted by start, seq breaks ties; returned spans are copies
    store.add("tie", "b", 1.0)
    store.add("tie", "a", 1.0)
    chain = store.trace("tie")
    assert [s["name"] for s in chain] == ["b", "a"]
    chain[0]["name"] = "mutated"
    assert store.trace("tie")[0]["name"] == "b"


# ---------------------------------------------------------------------------
# span chains through the control plane
# ---------------------------------------------------------------------------

def test_flat_task_span_chain_and_http_surface():
    with KsaCluster(prefix="obs1", workers=1, worker_slots=2, http=True,
                    poll_interval_s=0.005) as c:
        tids = [c.submit("sleep", params={"duration": 0.01})
                for _ in range(3)]
        assert c.wait_all(tids, timeout=10.0)
        for tid in tids:
            names = [s["name"] for s in c.trace(tid)]
            assert names == ["submit", "grant", "claim", "run", "commit"]
            run = [s for s in c.trace(tid) if s["name"] == "run"][0]
            assert run["ok"] is True and run["attempt"] == 0
            assert run["dur_s"] >= 0.0
        port = c.http_port

        # GET / lists every route (the index is lint-checked below)
        body, _ = _get(port, "/")
        assert json.loads(body)["endpoints"] == list(ROUTES)

        # GET /metrics serves Prometheus text with per-class histograms
        body, ctype = _get(port, "/metrics")
        text = body.decode()
        assert ctype.startswith("text/plain")
        assert "0.0.4" in ctype
        assert re.search(
            r'ksa_task_queue_wait_seconds_bucket\{cls="cpu",le="\+Inf"\} 3',
            text)
        assert re.search(r'ksa_task_run_seconds_count\{cls="cpu"\} 3', text)
        assert re.search(r'ksa_result_commit_seconds_count\{cls="cpu"\} 3',
                         text)
        assert 'event="completed"' in text

        # GET /trace/<id> returns the chain; unknown ids are a 404
        body, _ = _get(port, f"/trace/{tids[0]}")
        payload = json.loads(body)
        assert payload["task_id"] == tids[0]
        assert [s["name"] for s in payload["spans"]] == \
            ["submit", "grant", "claim", "run", "commit"]
        with pytest.raises(urllib.error.HTTPError) as err:
            _get(port, "/trace/no-such-task")
        assert err.value.code == 404


def test_preempted_and_retried_task_has_one_linked_chain():
    """ISSUE acceptance: KsaCluster.trace(task_id) returns the complete
    submit→terminal span chain for a preempted-and-retried task — attempt 0
    ends in a revoke(preempt) span, attempt 1 in run+commit, all under one
    task id and one trace_id."""
    big = PipelineSpec("obs-big", [
        Stage("work", "sleep", fan_out=1, params={"duration": 0.8},
              retry=RetryPolicy(max_attempts=3, timeout_s=60.0,
                                max_preemptions=6)),
    ])
    small = PipelineSpec("obs-small", [
        Stage("work", "sleep", fan_out=1, params={"duration": 0.05},
              retry=RetryPolicy(max_attempts=3, timeout_s=60.0)),
    ])
    with KsaCluster(prefix="obs2", workers=1, worker_slots=2,
                    poll_interval_s=0.005, lease=FairShare(preempt_factor=1.5),
                    max_in_flight_total=2) as c:
        bid = c.submit_campaign(big, list(range(6)), weight=1.0)
        time.sleep(0.3)
        sid = c.submit_campaign(small, list(range(2)), weight=4.0)
        assert c.wait_campaign(sid, timeout=60.0).state == "COMPLETED"
        assert c.wait_campaign(bid, timeout=120.0).state == "COMPLETED"
        assert c.pipeline.preemptions >= 1

        preempted = []
        for _stage, tids in c.pipeline.stage_tasks(bid):
            for tid in tids:
                if any(s["name"] == "revoke"
                       and s.get("reason") == RevokeReason.PREEMPT
                       for s in c.trace(tid)):
                    preempted.append(tid)
        assert preempted, "no preempted task left a revoke span"

        spans = c.trace(preempted[0])
        names = [(s["name"], s.get("attempt")) for s in spans]
        revoked_attempt = next(s["attempt"] for s in spans
                               if s["name"] == "revoke")
        # attempt n was granted then revoked for preemption ...
        assert ("grant", revoked_attempt) in names
        assert ("revoke", revoked_attempt) in names
        # ... and a later attempt of the SAME task id ran to commit
        terminal = [s for s in spans if s["name"] == "run" and s["ok"]]
        assert terminal and terminal[-1]["attempt"] > revoked_attempt
        # the terminal attempt reached a durable commit record: either the
        # monitor's commit span or the pipeline's journaled TaskDone
        assert any(s["name"] == "commit" or
                   (s["name"] == "journal" and s.get("event") == "TaskDone")
                   for s in spans)
        # every span that carries a trace id agrees on it
        tid0 = preempted[0]
        trace_ids = {s["trace_id"] for s in spans if "trace_id" in s}
        assert trace_ids == {tid0}
        # the registry agrees with the span story
        snap = c.broker.metrics.snapshot()
        revoked = snap["ksa_leases_revoked_total"]["series"]
        assert revoked[(RevokeReason.PREEMPT,)] == \
            c.broker.lease_stats()["revoked"]["preempt"] >= 1


def test_every_revoke_reason_is_counted_and_spanned():
    broker = Broker(default_partitions=2)
    try:
        sub = Submitter(broker, "rv")
        submitted = [sub.submit("sleep", params={"duration": 0.01})
                     for _ in RevokeReason.ALL]
        cons = Consumer(broker, ["rv-new.cpu"], group_id="rv-agents",
                        member_id="rv-m1")
        leased: list = []
        deadline = time.time() + 5.0
        while len(leased) < len(submitted) and time.time() < deadline:
            leased += [r.key for r in cons.lease(max_records=8, timeout=0.5)]
        assert sorted(leased) == sorted(submitted)
        tids = []
        for tid, reason in zip(leased, RevokeReason.ALL):
            assert broker.revoke_lease(tid, reason, requeue=False)
            tids.append((tid, reason))
        stats = broker.lease_stats()["revoked"]
        snap = broker.metrics.snapshot()
        series = snap["ksa_leases_revoked_total"]["series"]
        for tid, reason in tids:
            assert stats[reason] == 1
            assert series[(reason,)] == 1
            revokes = [s for s in broker.spans.trace(tid)
                       if s["name"] == "revoke"]
            assert len(revokes) == 1
            assert revokes[0]["reason"] == reason
            assert revokes[0]["requeued"] is False
    finally:
        broker.close()


def test_drain_keeps_counters_consistent():
    """Churn (graceful drain mid-burst) must not lose decrements: after the
    dust settles active leases are zero, grants == completions, and every
    task has exactly one ok run span."""
    with KsaCluster(prefix="obs3", workers=1, worker_slots=2,
                    poll_interval_s=0.005) as c:
        tids = [c.submit("sleep", params={"duration": 0.05})
                for _ in range(8)]
        w2 = c.add_worker(slots=2)
        time.sleep(0.1)
        assert c.drain_worker(w2, timeout_s=20.0)
        assert c.wait_all(tids, timeout=20.0)
        assert _wait(lambda: c.broker.lease_stats()["active"] == 0)
        stats = c.broker.lease_stats()
        assert stats["completed"] == len(tids)
        # every grant reached exactly one terminal: committed or revoked
        assert stats["granted"] == stats["completed"] + stats["failed"] + \
            stats["revoked_total"]
        for tid in tids:
            runs = [s for s in c.trace(tid) if s["name"] == "run" and s["ok"]]
            assert len(runs) == 1, f"{tid}: {runs}"
        # render-time callback gauge reflects the drained state
        assert "ksa_leases_active 0" in c.metrics_text()


def test_recover_refolds_journal_and_times_it():
    """Orchestrator crash + recover(): the journal fold shows up in the
    ksa_journal_fold_seconds histogram, journal counters keep counting on
    the successor, and finished tasks still have complete span chains."""
    broker = Broker(default_partitions=2)
    spec = PipelineSpec("obs-rec", [
        Stage("work", "sleep", fan_out=1, params={"duration": 0.05},
              retry=RetryPolicy(max_attempts=3, timeout_s=30.0)),
    ])
    try:
        from repro.core import WorkerAgent
        w = WorkerAgent(broker, "rc", slots=2, poll_interval_s=0.005).start()
        pipe1 = PipelineAgent(broker, "rc", poll_interval_s=0.005).start()
        cid = pipe1.submit_campaign(spec, list(range(6)))
        assert _wait(lambda: pipe1.status(cid).stages["work"].done >= 1,
                     timeout=30.0)
        pipe1.crash()

        pipe2 = PipelineAgent(broker, "rc", agent_id="rec2",
                              poll_interval_s=0.005).start()
        assert pipe2.recover([spec]) == [cid]
        st = pipe2.wait(cid, timeout=60.0)
        assert st.state == "COMPLETED", st.failure
        snap = broker.metrics.snapshot()
        fold = snap["ksa_journal_fold_seconds"]["series"][()]
        assert fold["count"] >= 1
        assert pipe2.events_journaled > 0
        # both agents fed the same per-agent journal counter family
        journal = snap["ksa_journal_events_total"]["series"]
        assert sum(journal.values()) >= pipe2.events_journaled
        for _stage, tids in pipe2.stage_tasks(cid):
            for tid in tids:
                names = [s["name"] for s in broker.spans.trace(tid)]
                assert "run" in names and "journal" in names
        pipe2.stop()
        w.stop()
    finally:
        broker.close()


# ---------------------------------------------------------------------------
# legacy views / schema stability
# ---------------------------------------------------------------------------

def test_legacy_stats_schemas_are_views_over_registry():
    cfg = None
    with KsaCluster(prefix="obs4", workers=1, worker_slots=2, http=True,
                    poll_interval_s=0.005) as c:
        tids = [c.submit("sleep", params={"duration": 0.01})
                for _ in range(4)]
        assert c.wait_all(tids, timeout=10.0)
        w = c.agents[0]
        s = w.stats()
        # pre-obs stats() keys unchanged (these are asserted across the
        # existing suite too — this is the canary)
        for key in ("agent_id", "kind", "state", "in_flight", "slots",
                    "completed", "failed", "rerouted", "deferred",
                    "requeued", "revoked", "dropped_revoked", "mem_revoked",
                    "heartbeat_failures"):
            assert key in s, key
        assert s["completed"] == w.tasks_completed == 4
        assert isinstance(w.tasks_completed, int)
        # the same number read through the registry
        snap = c.broker.metrics.snapshot()
        events = snap["ksa_agent_events_total"]["series"]
        assert events[(w.agent_id, "completed")] == 4
        summary = c.monitor.summary()
        for key in ("tasks", "done", "by_status", "results_handled",
                    "resubmissions", "revocations", "compactions",
                    "legacy_forwards", "duplicates_fenced"):
            assert key in summary, key
        assert summary["results_handled"] == \
            snap["ksa_monitor_events_total"]["series"][
                (c.monitor.monitor_id, "results_handled")]
        lease = c.broker.lease_stats()
        for key in ("granted", "completed", "failed", "requeued", "active",
                    "revoked", "revoked_total", "stale_drops"):
            assert key in lease, key
        assert set(lease["revoked"]) == set(RevokeReason.ALL)
        port = c.http_port
        body, _ = _get(port, "/summary")
        assert json.loads(body)["done"] == 4
        cfg = c.status()
    for key in ("prefix", "started", "agents", "broker", "leases", "monitor"):
        assert key in cfg, key


def test_monitor_route_index_lint():
    """Repo lint (pytest-collected): every literal route dispatched in
    MonitorAgent's do_GET must be listed in ROUTES (served by GET /), and
    vice versa — so the index payload can't silently rot."""
    import inspect

    import repro.core.monitor as monitor_mod
    src = inspect.getsource(monitor_mod)
    m = re.search(r"def do_GET\(self\).*", src, re.S)
    assert m, "could not locate do_GET dispatch block"
    body = m.group(0)
    dispatched = set(re.findall(r'parts == \["(\w+)"\]', body))
    dispatched |= set(re.findall(r'parts\[0\] == "(\w+)"', body))
    dispatched.add("")  # the `if not parts:` index route
    indexed = {r.strip("/").split("/")[0] for r in ROUTES}
    assert dispatched == indexed, (
        f"monitor routes drifted: dispatched={sorted(dispatched)} "
        f"vs ROUTES={sorted(indexed)}")


# ---------------------------------------------------------------------------
# RSS sampling (mem-overage policing measures, not trusts)
# ---------------------------------------------------------------------------

def test_sample_rss_mb_reads_kernel_accounting():
    rss = sample_rss_mb(cached=False)
    assert rss > 1.0  # a live CPython interpreter is many MB resident
    assert sample_rss_mb() == pytest.approx(sample_rss_mb(), rel=0.5)


def test_mem_used_is_measured_with_report_override():
    from repro.core.messages import TaskMessage

    class _Quiet(ClusterComputing):
        def run(self):
            return {}

    broker = Broker(default_partitions=1)
    from repro.core import Producer
    t = _Quiet(TaskMessage(task_id="m1", script="quiet"),
               Producer(broker), "mm", "agent-x")
    broker.close()
    # kernel-measured delta vs construction-time baseline: near zero for a
    # task that allocated nothing, never negative
    assert 0.0 <= t.mem_used_mb < 64.0
    # the legacy self-reporting hook remains as an explicit override
    t.report_mem(512.0)
    assert t.mem_used_mb == 512.0
    t.mem_used_mb = 1024.0
    assert t.mem_used_mb == 1024.0


# ---------------------------------------------------------------------------
# the obs switch and overhead posture
# ---------------------------------------------------------------------------

def test_obs_disabled_keeps_counters_but_drops_traces():
    reg = MetricsRegistry(enabled=False)
    h = reg.histogram("off_seconds")
    h.observe(1.0)
    assert h.count == 0 and h.quantile(0.5) is None
    c = reg.counter("off_total")
    c.inc()
    assert c.value == 1  # counters never turn off

    with KsaCluster(prefix="obs5", workers=1, worker_slots=2, obs=False,
                    poll_interval_s=0.005) as c:
        assert isinstance(c.broker.spans, NullSpanStore)
        tids = [c.submit("sleep", params={"duration": 0.0})
                for _ in range(3)]
        assert c.wait_all(tids, timeout=10.0)
        assert c.trace(tids[0]) == []
        # the legacy views still work: counters stay live
        assert c.broker.lease_stats()["completed"] == 3
        assert c.agents[0].tasks_completed == 3
        text = c.metrics_text()  # /metrics still serves, minus histogram data
        assert "ksa_leases_granted_total 3" in text
        # histogram series render but record nothing (null observations)
        assert 'ksa_task_run_seconds_count{cls="cpu"} 0' in text


def test_campaign_report_splits_queue_run_retry():
    spec = PipelineSpec("obs-rep", [
        Stage("a", "sleep", fan_out=1, params={"duration": 0.05}),
        Stage("b", "sleep", depends_on=("a",), params={"duration": 0.02}),
    ])
    with KsaCluster(prefix="obs6", workers=1, worker_slots=2,
                    poll_interval_s=0.005) as c:
        cid = c.submit_campaign(spec, list(range(3)))
        assert c.wait_campaign(cid, timeout=30.0).state == "COMPLETED"
        rep = c.campaign_report(cid)
        assert rep["campaign_id"] == cid and rep["state"] == "COMPLETED"
        assert list(rep["stages"]) == ["a", "b"]  # topological order
        a = rep["stages"]["a"]
        assert a["tasks"] == a["traced"] == 3
        assert a["run_s"] >= 3 * 0.04  # three 50 ms tasks actually ran
        assert a["queue_s"] >= 0.0 and a["retry_s"] == 0.0
        assert a["wall_s"] > 0.0
        assert rep["dominant_stage"] in ("a", "b")
        assert rep["wall_s"] >= max(s["run_s"] for s in
                                    rep["stages"].values()) / 2


# ---------------------------------------------------------------------------
# telemetry plane: time-series store, SLO burn alerts, flight recorder,
# broker-streamed publisher/collector (ISSUE 9)
# ---------------------------------------------------------------------------

def _get_any(port, path):
    """GET that returns (status, parsed-json) for 2xx and 4xx alike."""
    try:
        with urllib.request.urlopen(f"http://127.0.0.1:{port}{path}") as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def test_time_series_store_queries_and_validation():
    st = TimeSeriesStore(resolution_s=0.5)
    now = 100.0
    for i in range(10):
        st.ingest("m_total", {"site": "a"}, now + i, float(i), "counter")
        st.ingest("m_total", {"site": "b"}, now + i, float(2 * i), "counter")
        st.ingest("lat:p95", {"site": "a"}, now + i, 0.1 * i, "gauge")
    t = now + 9
    assert st.latest("m_total", {"site": "a"}) == 9.0
    assert st.sum_by("m_total", "site", now=t) == {"a": 9.0, "b": 18.0}
    assert st.sum("m_total", now=t) == 27.0
    # counter slope, per-series and summed across the label match
    assert st.rate("m_total", {"site": "a"}, 60.0, t) == pytest.approx(1.0)
    assert st.rate("m_total", None, 60.0, t) == pytest.approx(3.0)
    assert st.quantile("lat:p95", 1.0, None, 60.0, t) == pytest.approx(0.9)
    assert st.quantile("lat:p95", 0.5, None, 60.0, t) == pytest.approx(0.4)
    assert len(st.points("m_total", {"site": "a"}, 4.5, t)) == 5
    # the /query facade validates before it aggregates
    out = st.query("m_total", agg="sum_by", by="site", now=t)
    assert out["result"]["b"] == 18.0 and out["agg"] == "sum_by"
    assert st.query("m_total", agg="latest")["result"] == 9.0
    with pytest.raises(ValueError):
        st.query("m_total", agg="nope")
    with pytest.raises(ValueError):
        st.query("m_total", agg="quantile")     # requires q
    with pytest.raises(ValueError):
        st.query("m_total", agg="sum_by")       # requires by
    stats = st.stats()
    assert stats["series"] == 3


def test_time_series_store_same_bucket_folds_min_max_sum():
    st = TimeSeriesStore(resolution_s=10.0)
    for v in (1.0, 5.0, 3.0):
        st.ingest("g", None, 100.0, v, "gauge")
    pts = st.points("g")
    assert len(pts) == 1 and pts[0][1] == 3.0  # last write wins the sample
    assert st.latest("g") == 3.0


def test_alert_engine_multi_window_fire_and_resolve():
    store = TimeSeriesStore(resolution_s=0.1)
    now = 1000.0
    for i in range(11):                          # slope 2/s for 10 s
        store.ingest("err_total", None, now - 10 + i, float(2 * i), "counter")
    slo = SloSpec(name="errs", metric="err_total", kind="rate", objective=1.0)
    rule = AlertRule(slo=slo, long_window_s=20.0, short_window_s=5.0)
    reg = MetricsRegistry()
    fired = []
    eng = AlertEngine(store, [rule], registry=reg,
                      on_fire=lambda r, ev: fired.append(r))
    evs = eng.evaluate(now=now)
    assert evs[0]["breach"] and evs[0]["burn_short"] >= 1.0
    assert fired == ["errs"]
    assert [a["rule"] for a in eng.active()] == ["errs"]
    # still firing on the next pass, but no duplicate transition
    eng.evaluate(now=now + 0.5)
    assert [h["state"] for h in eng.status()["history"]] == ["firing"]
    # counter goes flat -> short-window burn decays -> resolves
    for i in range(11):
        store.ingest("err_total", None, now + i, 20.0, "counter")
    eng.evaluate(now=now + 10)
    st = eng.status()
    assert st["states"]["errs"]["state"] == "resolved"
    assert st["firing"] == []
    assert [h["state"] for h in st["history"]] == ["firing", "resolved"]
    text = reg.render()
    assert 'ksa_alerts_total{rule="errs",state="firing"} 1' in text
    assert 'ksa_alerts_total{rule="errs",state="resolved"} 1' in text


def test_slo_threshold_quantile_and_ratio_kinds():
    store = TimeSeriesStore(resolution_s=0.1)
    now = 50.0
    for i in range(10):
        store.ingest("wait:p95", None, now - 9 + i, 4.0, "gauge")
        store.ingest("bad_total", None, now - 9 + i, float(i), "counter")
        store.ingest("all_total", None, now - 9 + i, float(10 * i), "counter")
    q = SloSpec(name="p95", metric="wait:p95", objective=2.0, q=0.95)
    assert q.burn(store, 30.0, now) == pytest.approx(2.0)  # 4s vs 2s target
    ratio = SloSpec(name="errratio", metric="bad_total", kind="ratio",
                    total_metric="all_total", objective=0.05)
    assert ratio.burn(store, 30.0, now) == pytest.approx(2.0)  # 10% vs 5%
    # ratio with an empty denominator reads as zero burn, not a crash
    empty = SloSpec(name="e", metric="bad_total", kind="ratio",
                    total_metric="missing_total", objective=0.05)
    assert empty.burn(store, 30.0, now) == 0.0
    with pytest.raises(ValueError):
        SloSpec(name="x", metric="m", kind="ratio", objective=1.0)
    with pytest.raises(ValueError):
        SloSpec(name="x", metric="m", objective=0.0)
    with pytest.raises(ValueError):
        AlertRule(slo=q, long_window_s=5.0, short_window_s=10.0)


def test_flight_recorder_ring_drain_and_storm_autodump():
    fr = FlightRecorder(max_events=32, storm_threshold=5,
                        storm_window_s=60.0, storm_cooldown_s=0.0)
    fr.context_fn = lambda: {"extra": 1}
    for i in range(4):
        fr.record("grant", holder=f"w{i}")
    seq, evs = fr.since(0)
    assert [e["kind"] for e in evs] == ["grant"] * 4 and seq == 4
    seq2, evs2 = fr.since(seq)                   # incremental drain
    assert (seq2, evs2) == (4, [])
    for i in range(5):
        fr.record("revocation", task_id=f"t{i}", reason="preempt")
    dumps = fr.dumps()
    assert [d["trigger"] for d in dumps] == ["revocation_storm"]
    revs = [e for e in dumps[0]["events"] if e["kind"] == "revocation"]
    assert len(revs) == 5
    assert all(e["reason"] == "preempt" for e in revs)
    assert dumps[0]["context"]["extra"] == 1     # injected live context
    assert fr.stats()["counts"] == {"grant": 4, "revocation": 5}
    snap = fr.snapshot()
    assert snap["seq"] == 9 and len(snap["dumps"]) == 1


def test_telemetry_topic_schema_and_collector_fold():
    with KsaCluster(prefix="obs7", workers=1, telemetry=True) as c:
        ids = [c.submit("sleep", params={"duration": 0.01}) for _ in range(3)]
        assert c.wait_all(ids, timeout=30.0)
        c.telemetry_publisher.publish_once()
        topic = topic_names("obs7")["telemetry"]
        recs = c.broker.read_from(topic, 0)
        assert recs, "publisher produced nothing on the telemetry topic"
        rec = recs[-1].value
        assert rec["kind"] == "telemetry" and rec["v"] == 1
        for key in ("source", "site", "seq", "ts", "metrics", "spans",
                    "events"):
            assert key in rec
        by_type = {}
        for row in rec["metrics"]:
            by_type.setdefault(row["type"], []).append(row)
        assert {"value"} <= set(by_type["counter"][0])
        hist = by_type["histogram"][0]
        assert {"count", "sum", "p50", "p95", "p99"} <= set(hist)
        # collector folds the records into queryable series
        c.telemetry_collector.poll()
        st = c.telemetry_store
        assert st.sum("ksa_leases_completed_total") >= 3
        names = set(st.series_names())
        assert "ksa_task_queue_wait_seconds:p95" in names   # digest series
        assert "ksa_task_queue_wait_seconds_count" in names
        # the facade query sees the same numbers
        out = c.query("ksa_leases_completed_total", agg="sum")
        assert out["result"] >= 3


def test_restarted_collector_rebuilds_store_from_topic_replay():
    """Killing the monitor (the collector's host) loses nothing: a fresh
    collector replays the durable PREFIX-telemetry topic from offset 0 via
    Broker.read_from and rebuilds the exact same series."""
    with KsaCluster(prefix="obs8", workers=1, telemetry=True) as c:
        ids = [c.submit("sleep", params={"duration": 0.01}) for _ in range(5)]
        assert c.wait_all(ids, timeout=30.0)
        c.telemetry_publisher.publish_once()
        c.telemetry_collector.poll()
        live = c.telemetry_store
        granted = live.sum("ksa_leases_granted_total")
        assert granted >= 5
        c.monitor.stop()                          # kill the collector host
        store2 = TimeSeriesStore()
        coll2 = TelemetryCollector(c.broker, topic_names("obs8")["telemetry"],
                                   store=store2)
        n = coll2.poll()
        assert n > 0
        assert store2.sum("ksa_leases_granted_total") == granted
        # no gap: every series the live store knew is rebuilt
        assert set(store2.series_names()) >= set(live.series_names())


def test_revocation_storm_fires_alert_and_dumps_blackbox():
    slo = SloSpec(name="revocation-rate", metric="ksa_leases_revoked_total",
                  kind="rate", objective=0.2)
    rule = AlertRule(slo=slo, long_window_s=60.0, short_window_s=30.0)
    with KsaCluster(prefix="obs9", workers=2, worker_slots=6,
                    telemetry=True, slos=[rule]) as c:
        ids = [c.submit("hang") for _ in range(12)]
        # keyed partitioning can split unevenly, so not all 12 lease at
        # once — wait for a storm's worth and revoke whatever is active
        # (revoking frees slots, so the queued remainder leases next)
        assert _wait(lambda: c.broker.lease_stats()["active"] >= 10,
                     timeout=15.0)
        c.telemetry_publisher.publish_once()      # pre-storm sample
        revoked = [t for t in ids[:6]
                   if c.revoke(t, reason=RevokeReason.PREEMPT,
                               requeue=False)]
        time.sleep(0.3)
        c.telemetry_publisher.publish_once()      # mid-storm sample
        pending = [t for t in ids if t not in revoked]
        deadline = time.time() + 8.0
        while len(revoked) < 12 and time.time() < deadline:
            for tid in list(pending):
                if c.revoke(tid, reason=RevokeReason.PREEMPT,
                            requeue=False):
                    revoked.append(tid)
                    pending.remove(tid)
            time.sleep(0.05)
        assert len(revoked) >= 10                 # a storm's worth
        time.sleep(0.3)
        c.telemetry_publisher.publish_once()
        c.telemetry_collector.poll()
        c.alert_engine.evaluate()
        # the burn-rate alert fired on the revocation counter's slope
        assert _wait(lambda: "revocation-rate" in
                     c.alert_engine.status()["firing"], timeout=5.0)
        assert [a["rule"] for a in c.status()["alerts"]] == \
            ["revocation-rate"]
        assert 'ksa_alerts_total{rule="revocation-rate",state="firing"} 1' \
            in c.metrics_text()
        # 12 revocations inside the storm window auto-latched a blackbox
        # dump; the alert firing latched a second one
        triggers = [d["trigger"] for d in c.broker.blackbox.dumps()]
        assert "revocation_storm" in triggers
        assert "alert:revocation-rate" in triggers
        storm = next(d for d in c.broker.blackbox.dumps()
                     if d["trigger"] == "revocation_storm")
        revs = [e for e in storm["events"] if e["kind"] == "revocation"]
        assert len(revs) >= 10
        assert all(e["reason"] == RevokeReason.PREEMPT for e in revs)
        assert {e["task_id"] for e in revs} <= set(ids)
        assert "leases" in storm["context"]       # injected cluster context
        # a forced dump works with or without telemetry and is retained
        manual = c.dump_blackbox()
        assert manual["trigger"] == "manual"
        assert manual in c.broker.blackbox.dumps()


def test_monitor_query_alerts_blackbox_endpoints():
    slo = SloSpec(name="qw-p95", metric="ksa_task_queue_wait_seconds:p95",
                  objective=30.0, q=0.95)
    with KsaCluster(prefix="obs10", workers=1, http=True,
                    telemetry=True, slos=[slo]) as c:
        port = c.http_port
        ids = [c.submit("sleep", params={"duration": 0.01}) for _ in range(3)]
        assert c.wait_all(ids, timeout=30.0)
        c.telemetry_publisher.publish_once()
        c.telemetry_collector.poll()
        code, data = _get_any(
            port, "/query?name=ksa_leases_completed_total&agg=sum")
        assert code == 200 and data["result"] >= 3
        code, data = _get_any(
            port, "/query?name=ksa_task_queue_wait_seconds:p95"
                  "&agg=quantile&q=0.95&window_s=120")
        assert code == 200 and data["result"] is not None
        # label filter: l.<key>=<value>
        code, data = _get_any(
            port, "/query?name=ksa_leases_granted_total&agg=sum&l.cls=cpu")
        assert code == 200
        code, data = _get_any(port, "/alerts")
        assert code == 200 and data["rules"] == ["qw-p95"]
        assert data["firing"] == []               # 30 s objective holds
        code, data = _get_any(port, "/blackbox")
        assert code == 200
        assert any(e["kind"] == "grants" for e in data["events"])
        # /query, /alerts, /blackbox are advertised on the index
        code, data = _get_any(port, "/")
        assert {"/query", "/alerts", "/blackbox"} <= set(data["endpoints"])


def test_monitor_http_error_paths_are_structured_json():
    """Unknown /trace and /campaigns ids and malformed /query parameters
    come back as structured JSON 404/400 payloads, never empty bodies."""
    with KsaCluster(prefix="obs11", workers=1, http=True,
                    telemetry=True) as c:
        port = c.http_port
        for path in ("/trace/no-such-task", "/campaigns/no-such-campaign",
                     "/tasks/no-such-task"):
            code, data = _get_any(port, path)
            assert code == 404, path
            assert data["error"], path            # human-readable message
        bad_queries = [
            "/query",                              # missing name
            "/query?agg=rate",                     # still missing name
            "/query?name=m&agg=bogus",             # unknown aggregation
            "/query?name=m&window_s=abc",          # non-numeric window
            "/query?name=m&q=x&agg=quantile",      # non-numeric q
            "/query?name=m&agg=quantile",          # quantile without q
            "/query?name=m&agg=sum_by",            # sum_by without by
            "/query?name=m&bogus=1",               # unknown parameter
        ]
        for path in bad_queries:
            code, data = _get_any(port, path)
            assert code == 400, path
            assert data["error"] == "bad query" and data["detail"], path
    # without a telemetry plane the query surface 404s instead of crashing
    with KsaCluster(prefix="obs12", workers=0, http=True) as c2:
        port = c2.http_port
        code, data = _get_any(port, "/query?name=m")
        assert code == 404 and "telemetry" in data["error"]
        code, data = _get_any(port, "/alerts")
        assert code == 404 and "alert" in data["error"]
        code, data = _get_any(port, "/blackbox")
        assert code == 200                        # blackbox is always on
        with pytest.raises(RuntimeError):
            c2.query("m")
        with pytest.raises(RuntimeError):
            c2.alerts()


def test_autoscale_sensing_reads_from_time_series_store():
    """The autoscaler's backlog/drain-rate history lives in a
    TimeSeriesStore; with the telemetry plane on it shares the cluster's
    store, so /query can read the controller's own sensing series."""
    from repro.autoscale import AutoscaleConfig, PoolSpec
    cfg = AutoscaleConfig(pools=(PoolSpec("cpu", min_agents=1,
                                          max_agents=2),))
    with KsaCluster(prefix="obs13", workers=0, telemetry=True,
                    autoscale=cfg) as c:
        assert c.autoscaler.store is c.telemetry_store  # shared, not private
        ids = [c.submit("sleep", params={"duration": 0.01}) for _ in range(4)]
        c.autoscaler.tick()
        assert c.wait_all(ids, timeout=30.0)
        c.autoscaler.tick()
        out = c.query("ksa_pool_backlog", agg="points",
                      labels={"pool": "cpu", "src": "autoscale"})
        assert out["result"], "controller sensing did not land in the store"
        rate = c.query("ksa_pool_consumed_total", agg="rate",
                       labels={"pool": "cpu", "src": "autoscale"},
                       window_s=30.0)
        assert rate["result"] >= 0.0


# ---------------------------------------------------------------------------
# satellite: Prometheus text-format conformance lint
# ---------------------------------------------------------------------------

_PROM_PAIR = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)='
                        r'"((?:[^"\\\n]|\\\\|\\"|\\n)*)"')
_PROM_SAMPLE = re.compile(r'^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{.*\})? (\S+)$')


def _lint_prometheus(text):
    """Parse + lint a Prometheus 0.0.4 exposition. Returns the samples as
    (name, labels, value) triples; asserts on any conformance violation."""
    help_count, type_count, types = {}, {}, {}
    samples = []
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            name = line.split(" ", 3)[2]
            help_count[name] = help_count.get(name, 0) + 1
        elif line.startswith("# TYPE "):
            _, _, name, kind = line.split(" ", 3)
            type_count[name] = type_count.get(name, 0) + 1
            types[name] = kind
        elif line.startswith("#"):
            continue
        else:
            m = _PROM_SAMPLE.match(line)
            assert m, f"unparseable sample line: {line!r}"
            name, braces, value = m.groups()
            labels = {}
            if braces:
                body, pos = braces[1:-1], 0
                while pos < len(body):  # strict: every char must be covered
                    pm = _PROM_PAIR.match(body, pos)
                    assert pm, f"bad label escaping in {line!r}"
                    labels[pm.group(1)] = pm.group(2)
                    pos = pm.end()
                    if pos < len(body):
                        assert body[pos] == ",", line
                        pos += 1
            float(value)  # every sample value must parse
            samples.append((name, labels, value))
    families = {}
    for name, labels, value in samples:
        fam = name
        for suffix in ("_bucket", "_count", "_sum"):
            if name.endswith(suffix) and \
                    types.get(name[:-len(suffix)]) == "histogram":
                fam = name[:-len(suffix)]
                break
        families.setdefault(fam, []).append((name, labels, value))
    for fam in families:
        if not fam.startswith("ksa_"):
            continue
        assert help_count.get(fam) == 1, \
            f"{fam}: {help_count.get(fam, 0)} HELP lines (want exactly 1)"
        assert type_count.get(fam) == 1, \
            f"{fam}: {type_count.get(fam, 0)} TYPE lines (want exactly 1)"
    for fam, kind in types.items():
        if kind != "histogram":
            continue
        counts, infs = {}, {}
        for name, labels, value in families.get(fam, []):
            child = tuple(sorted((k, v) for k, v in labels.items()
                                 if k != "le"))
            if name == fam + "_count":
                counts[child] = float(value)
            elif name == fam + "_bucket" and labels.get("le") == "+Inf":
                infs[child] = float(value)
        assert set(counts) == set(infs), \
            f"{fam}: children missing a +Inf bucket or a _count"
        for child in counts:
            assert counts[child] == infs[child], \
                f"{fam}{dict(child)}: le=\"+Inf\" != _count"
    return samples


def test_prometheus_lint_escapes_label_values():
    reg = MetricsRegistry()
    raw = 'we"ird\\pa\nth'
    reg.counter("ksa_esc_total", "escape check", labels=("path",)) \
        .labels(path=raw).inc()
    reg.histogram("ksa_esc_seconds", "escape hist", labels=("path",)) \
        .labels(path=raw).observe(0.2)
    samples = _lint_prometheus(reg.render())
    escaped = [lab["path"] for name, lab, _ in samples
               if name == "ksa_esc_total"]
    assert escaped == ['we\\"ird\\\\pa\\nth']  # \  " and newline escaped


def test_prometheus_conformance_cluster_and_federation_renders():
    with KsaCluster(prefix="obs14", workers=1, telemetry=True) as c:
        ids = [c.submit("sleep", params={"duration": 0.01}) for _ in range(3)]
        assert c.wait_all(ids, timeout=30.0)
        text = c.metrics_text()
        samples = _lint_prometheus(text)
        assert any(n.startswith("ksa_") for n, _, _ in samples)
        # federation merge: every sample gains a site label; the merged
        # exposition must still be conformant with deduped HELP/TYPE
        merged = merge_renders({"home": text, "edge": text})
        msamples = _lint_prometheus(merged)
        tagged = [lab for n, lab, _ in msamples if n.startswith("ksa_")]
        assert tagged and all(lab.get("site") in ("home", "edge")
                              for lab in tagged)


# ---------------------------------------------------------------------------
# satellite: metrics catalog lint (docs/METRICS.md)
# ---------------------------------------------------------------------------

def test_metrics_catalog_documents_every_registered_family():
    import pathlib
    from repro.obs.catalog import _full_registry, catalog_names, \
        render_catalog
    doc = pathlib.Path(__file__).resolve().parent.parent / "docs/METRICS.md"
    assert doc.exists(), "docs/METRICS.md missing — regenerate with " \
        "PYTHONPATH=src python -m repro.obs.catalog > docs/METRICS.md"
    documented = catalog_names(doc.read_text())
    reg = _full_registry()
    registered = {r["name"] for r in reg.describe()
                  if r["name"].startswith("ksa_")}
    missing = registered - documented
    assert not missing, \
        f"metrics missing from docs/METRICS.md: {sorted(missing)} — " \
        f"regenerate with PYTHONPATH=src python -m repro.obs.catalog"
    # the generator output itself round-trips through the lint
    assert catalog_names(render_catalog(reg)) == registered
