"""The unified lease lifecycle (repro.core.lease): broker-level grant /
claim / commit-fence / revoke semantics, every legacy stop-path routed
through Broker.revoke_lease (agent watchdog, monitor watchdog, drain,
scancel, mem-overage policing), preemptive fair share with the journaled
LeaseRevoked event, scheduled journal compaction from the monitor loop,
and the drain × recovery interplay (orchestrator killed mid-drain)."""
import time

import pytest

from repro.cluster import KsaCluster
from repro.core import (Broker, ClusterComputing, Consumer, FairShare,
                        ResourceProfile, Resources, RevokeReason, Submitter,
                        WorkerAgent, register_script)
from repro.pipeline import (CampaignState, CampaignSubmitted, LeaseGranted,
                            LeaseRevoked, PipelineAgent, PipelineSpec,
                            RetryPolicy, Stage, StageDispatched, TaskDone)
from repro.pipeline.state import group_journal, snapshot_event


def _wait(cond, timeout=10.0, interval=0.01):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return False


@register_script("lease_hang_once")
class _HangOnce(ClusterComputing):
    """Hangs (cancellably) on attempt 0, completes instantly afterwards —
    the deterministic straggler for watchdog-revocation tests."""

    def run(self):
        if self.attempt == 0:
            while True:
                self.check_cancel()
                time.sleep(0.005)
        return {"attempt": self.attempt}


@register_script("lease_slow_cancel")
class _SlowCancel(ClusterComputing):
    """Sleeps in coarse chunks between cancellation checks — a task that
    notices a slurm-side scancel *slowly*, so the agent's lease policing
    deterministically observes the CA/TO job state first."""

    def run(self):
        deadline = time.time() + float(self.params.get("duration", 5.0))
        while time.time() < deadline:
            time.sleep(0.2)
            self.check_cancel()
        return {"slept": True}


# ---------------------------------------------------------------------------
# broker-level lease semantics
# ---------------------------------------------------------------------------

def _lease_one(broker: Broker, prefix: str = "lb"):
    """Submit one sleep task and lease it through a consumer, returning
    (task_id, member_id, record, consumer)."""
    sub = Submitter(broker, prefix)
    tid = sub.submit("sleep", params={"duration": 0.01})
    cons = Consumer(broker, [f"{prefix}-new.cpu"],
                    group_id=f"{prefix}-agents", member_id=f"{prefix}-m1")
    recs = cons.lease(timeout=2.0)
    assert len(recs) == 1 and recs[0].key == tid
    return tid, cons.member_id, recs[0], cons


def test_lease_granted_claimed_completed():
    broker = Broker(default_partitions=2)
    tid, member, _, _cons = _lease_one(broker)
    view = broker.lease_view(tid)
    assert view["state"] == "GRANTED" and view["holder"] == member
    import threading
    cancel = threading.Event()
    assert broker.claim_start(tid, member, 0, cancel)
    assert broker.lease_view(tid)["state"] == "RUNNING"
    # the commit gate lets an unrevoked lease publish, exactly once
    assert broker.complete_lease(tid, member, 0, ok=True)
    assert broker.lease_view(tid) is None  # terminal leases are dropped
    stats = broker.lease_stats()
    assert stats["granted"] == 1 and stats["completed"] == 1
    broker.close()


def test_revoke_running_fences_commit_and_requeues_bumped_attempt():
    broker = Broker(default_partitions=2)
    tid, member, rec, _cons = _lease_one(broker)
    import threading
    cancel = threading.Event()
    assert broker.claim_start(tid, member, 0, cancel)
    assert broker.revoke_lease(tid, RevokeReason.WATCHDOG)
    # atomic consequences: the cancel event fired, the commit is fenced,
    # and the record is back on the topic it came from with attempt + 1
    assert cancel.is_set()
    assert not broker.complete_lease(tid, member, 0, ok=True)
    requeued = broker.read_from(rec.topic)
    fresh = [r for r in requeued if r.offset != rec.offset or
             r.partition != rec.partition]
    assert len(fresh) == 1 and fresh[0].value["attempt"] == 1
    # a completed lease can never be revoked (no double-run window)
    assert not broker.revoke_lease(tid, RevokeReason.WATCHDOG)
    assert broker.lease_stats()["revoked"]["watchdog"] == 1
    broker.close()


def test_revoke_granted_lease_requeues_same_attempt():
    """A lease that never started (deferred) is a requeue, not a retry."""
    broker = Broker(default_partitions=2)
    tid, member, rec, _cons = _lease_one(broker)
    assert broker.revoke_lease(tid, RevokeReason.DRAIN)
    fresh = [r for r in broker.read_from(rec.topic)
             if (r.partition, r.offset) != (rec.partition, rec.offset)]
    assert len(fresh) == 1 and fresh[0].value["attempt"] == 0
    # the holder's claim after the fact is refused (task already requeued)
    import threading
    assert not broker.claim_start(tid, member, 0, threading.Event())
    broker.close()


def test_superseded_holder_cannot_commit():
    """After a revoke + relase by another member, the old holder's commit
    is fenced by (holder, attempt), not just by state."""
    broker = Broker(default_partitions=2)
    tid, member, rec, cons = _lease_one(broker)
    import threading
    assert broker.claim_start(tid, member, 0, threading.Event())
    assert broker.revoke_lease(tid, RevokeReason.WATCHDOG)  # requeue att 1
    cons.close()  # the old member leaves; its partitions rebalance to m2
    c2 = Consumer(broker, [rec.topic], group_id="lb-agents",
                  member_id="lb-m2")
    assert _wait(lambda: any(r.key == tid for r in c2.lease(timeout=0.5)),
                 timeout=5.0)
    assert not broker.complete_lease(tid, member, 0, ok=True)  # old holder
    assert broker.complete_lease(tid, "lb-m2", 1, ok=True)     # new holder
    broker.close()


# ---------------------------------------------------------------------------
# stop-paths routed through the primitive
# ---------------------------------------------------------------------------

def test_agent_watchdog_revokes_and_monitor_resubmits():
    """Hung task: the agent watchdog revokes the lease (cancel + fence)
    and the monitor — finding nothing live to revoke — produces the fresh
    attempt, which completes. One result, zero duplicates."""
    with KsaCluster(workers=1, worker_slots=2, poll_interval_s=0.005,
                    task_timeout_s=0.4) as c:
        tid = c.submit("lease_hang_once", timeout_s=0.3)
        assert c.wait_all([tid], timeout=20.0)
        assert c.result(tid) == {"attempt": 1}
        s = c.monitor.summary()
        assert s["results_handled"] == 1 and s["duplicates_fenced"] == 0
        assert c.agents[0].stats()["revoked"] >= 1
        ls = c.status()["leases"]
        assert ls["revoked"]["watchdog"] >= 1
        assert s["resubmissions"] + s["revocations"] >= 1


def test_monitor_revokes_crashed_agents_lease():
    """A crashed agent's RUNNING lease is still on the books: the monitor
    watchdog revokes it (atomic cancel + requeue) instead of blindly
    producing a duplicate record next to a live attempt."""
    with KsaCluster(workers=1, worker_slots=1, poll_interval_s=0.005,
                    task_timeout_s=0.5, session_timeout_s=1.0) as c:
        w1 = c.agents[0]
        tid = c.submit("sleep", params={"duration": 60.0})
        assert _wait(lambda: w1.stats()["in_flight"] == 1)
        w1.crash()
        c.add_worker(slots=1)
        assert _wait(lambda: c.monitor.revocations >= 1, timeout=15.0)
        assert _wait(lambda: (c.task(tid) or None) is not None
                     and c.task(tid).attempt >= 1, timeout=15.0)
        assert c.status()["leases"]["revoked"]["watchdog"] >= 1


def test_scancel_routes_through_lease_layer():
    """An external scancel (operator / walltime) on a running Slurm job:
    the ClusterAgent polices job states and revokes the lease with
    reason="scancel" — the flat task is requeued and completes."""
    with KsaCluster(poll_interval_s=0.005,
                    slurm=dict(nodes=1, cpus_per_node=2)) as c:
        agent = c.agents[0]
        tid = c.submit("lease_slow_cancel", params={"duration": 5.0})
        assert _wait(lambda: agent.stats()["in_flight"] >= 1, timeout=10.0)
        run = agent._running[tid]
        assert _wait(lambda: agent.slurm.job(run.slurm_job_id) is not None
                     and agent.slurm.job(run.slurm_job_id).state == "R",
                     timeout=10.0)
        agent.slurm.scancel(run.slurm_job_id)
        assert _wait(lambda: c.status()["leases"]["revoked"]["scancel"] >= 1,
                     timeout=10.0)
        assert c.wait_all([tid], timeout=30.0)
        s = c.monitor.summary()
        assert s["results_handled"] == 1 and s["duplicates_fenced"] == 0


def test_mem_overage_revokes_and_requeues_flat_task():
    """Admission packs requests; policing revokes *usage*: a task reporting
    RSS over its request is revoked (reason=mem_overage), requeued with a
    bumped attempt, and completes once it behaves."""
    with KsaCluster(workers=1, worker_slots=2, poll_interval_s=0.005) as c:
        tid = c.submit("memhog", mem_mb=512,
                       params={"peak_mb": 4096, "duration": 5.0,
                               "calm_after_attempt": 1})
        assert c.wait_all([tid], timeout=30.0)
        assert c.result(tid)["attempt"] == 1
        assert c.agents[0].stats()["mem_revoked"] >= 1
        assert c.status()["leases"]["revoked"]["mem_overage"] >= 1
        assert c.monitor.summary()["duplicates_fenced"] == 0


def test_mem_overage_campaign_task_retries_on_journaled_budget():
    """Campaign tasks are never broker-requeued behind the PipelineAgent's
    back: mem overage revokes the lease and emits an ErrorMessage, and the
    pipeline retries on its own journaled RetryPolicy budget."""
    spec = PipelineSpec("hogc", [
        Stage("hog", "memhog", fan_out=1,
              params={"peak_mb": 4096, "duration": 5.0,
                      "calm_after_attempt": 1},
              resources=Resources(cpus=1, mem_mb=512),
              retry=RetryPolicy(max_attempts=3, timeout_s=60.0)),
    ])
    with KsaCluster(workers=1, worker_slots=2, poll_interval_s=0.005) as c:
        res = c.run_campaign(spec, [0], timeout_s=60.0)
        assert res.status.state == "COMPLETED"
        hog = res.status.stages["hog"]
        assert hog.done == 1 and hog.retried >= 1 and hog.errors >= 1
        assert c.status()["leases"]["revoked"]["mem_overage"] >= 1


# ---------------------------------------------------------------------------
# preemptive fair share
# ---------------------------------------------------------------------------

def _sleep_spec(name, duration, *, max_preemptions=0, timeout_s=60.0):
    return PipelineSpec(name, [
        Stage("work", "sleep", fan_out=1, params={"duration": duration},
              retry=RetryPolicy(max_attempts=3, timeout_s=timeout_s,
                                max_preemptions=max_preemptions))])


def test_fair_share_preempt_hook_is_pure():
    fs = FairShare(preempt_factor=1.5)
    # no starved peer -> work conservation, never preempt
    assert fs.preempt({"a": (1.0, 4, False, True),
                       "b": (1.0, 0, False, True)}) is None
    # starved peer + severely over-share holder -> name the holder
    assert fs.preempt({"a": (1.0, 4, False, True),
                       "b": (4.0, 0, True, True)}) == "a"
    # holder within its slice -> hold
    assert fs.preempt({"a": (4.0, 4, False, True),
                       "b": (1.0, 1, True, True)}) is None
    # an opted-out hog (no preemption budget) cannot be named — and does
    # not shield a lesser, opted-in over-share peer from paying instead
    assert fs.preempt({"a": (1.0, 6, False, False),
                       "b": (1.0, 2, False, True),
                       "c": (6.0, 0, True, True)}) == "b"
    assert fs.preempt({"a": (1.0, 4, False, False),
                       "b": (4.0, 0, True, True)}) is None
    with pytest.raises(ValueError):
        FairShare(preempt_factor=1.0)


def test_preemption_frees_slots_for_starved_campaign():
    """The ISSUE's over-share scenario: a long-task campaign saturates the
    pool; a heavier-weight small campaign arrives; preemptive FairShare
    revokes the hog's longest-running leases so the small campaign's tail
    collapses — with zero lost and zero duplicated tasks."""
    big = _sleep_spec("bigp", 1.0, max_preemptions=4)
    small = _sleep_spec("smallp", 0.05)
    with KsaCluster(workers=1, worker_slots=2, poll_interval_s=0.005,
                    lease=FairShare(preempt_factor=1.5),
                    max_in_flight_total=2) as c:
        bid = c.submit_campaign(big, list(range(8)), weight=1.0)
        time.sleep(0.3)
        t0 = time.time()
        sid = c.submit_campaign(small, list(range(2)), weight=4.0)
        st_small = c.wait_campaign(sid, timeout=30.0)
        small_dt = time.time() - t0
        st_big = c.wait_campaign(bid, timeout=60.0)
        assert st_small.state == "COMPLETED"
        assert st_big.state == "COMPLETED"
        assert st_big.preemptions >= 1
        assert small_dt < 0.7, f"starved campaign took {small_dt:.2f}s"
        # zero loss / zero duplication across the preemptions
        counts = {n: s.done for n, s in st_big.stages.items()}
        assert counts == {"work": 8}
        assert sum(s.duplicates for s in st_big.stages.values()) == 0
        assert sum(s.duplicates for s in st_small.stages.values()) == 0
        assert c.status()["leases"]["revoked"]["preempt"] >= 1
        # preemptions did not consume the retry budget
        work = st_big.stages["work"]
        assert work.revoked == st_big.preemptions


def test_preemption_bounded_by_max_preemptions():
    big = _sleep_spec("bigb", 0.5, max_preemptions=1)
    small = _sleep_spec("smallb", 0.05)
    with KsaCluster(workers=1, worker_slots=2, poll_interval_s=0.005,
                    lease=FairShare(preempt_factor=1.2),
                    max_in_flight_total=2) as c:
        bid = c.submit_campaign(big, list(range(6)), weight=1.0)
        time.sleep(0.2)
        sid = c.submit_campaign(small, list(range(4)), weight=8.0)
        assert c.wait_campaign(sid, timeout=60.0).state == "COMPLETED"
        st = c.wait_campaign(bid, timeout=60.0)
        assert st.state == "COMPLETED"
        assert st.preemptions <= 1  # the per-campaign bound held
        assert c.pipeline.preemptions <= 1


def test_zero_max_preemptions_never_preempted():
    big = _sleep_spec("bigz", 0.4)  # default: preemption disabled
    small = _sleep_spec("smallz", 0.05)
    with KsaCluster(workers=1, worker_slots=2, poll_interval_s=0.005,
                    lease=FairShare(preempt_factor=1.2),
                    max_in_flight_total=2) as c:
        bid = c.submit_campaign(big, list(range(4)), weight=1.0)
        time.sleep(0.2)
        sid = c.submit_campaign(small, list(range(2)), weight=8.0)
        assert c.wait_campaign(sid, timeout=60.0).state == "COMPLETED"
        st = c.wait_campaign(bid, timeout=60.0)
        assert st.state == "COMPLETED" and st.preemptions == 0
        assert c.status()["leases"]["revoked"]["preempt"] == 0


# ---------------------------------------------------------------------------
# the journaled LeaseRevoked event (pure reducer)
# ---------------------------------------------------------------------------

def _spec1() -> PipelineSpec:
    return PipelineSpec("lr", [Stage("s", "sleep", fan_out=1)])


def test_reducer_lease_revoked_returns_task_to_ready():
    spec = _spec1()
    cid, tid = "camp-lr", "camp-lr-s-00000"
    events = [
        CampaignSubmitted(campaign_id=cid, pipeline="lr", items=(1,), seq=0),
        StageDispatched(campaign_id=cid, stage="s", task_id=tid, index=0,
                        seq=1),
        LeaseGranted(campaign_id=cid, task_id=tid, attempt=0, seq=2),
        LeaseRevoked(campaign_id=cid, task_id=tid, reason="preempt", seq=3),
    ]
    st = CampaignState.fold(spec, cid, events)
    rec = st.tasks[tid]
    assert rec.revoke_pending and rec.revokes == 1 and rec.attempts == 1
    assert st.ready["s"] == [tid]
    assert st.stages["s"].in_flight == 0  # the slot was freed
    assert st.stages["s"].revoked == 1
    assert st.preemptions == 1
    # idempotent: duplicate suffix folds to the same state
    assert CampaignState.fold(spec, cid, events + events[-2:]) == st
    # the regrant clears the pending flag and re-occupies the slot
    st.apply(LeaseGranted(campaign_id=cid, task_id=tid, attempt=1, seq=4))
    assert not st.tasks[tid].revoke_pending
    assert st.ready["s"] == [] and st.stages["s"].in_flight == 1
    # a revocation of a never-granted or terminal task is a no-op
    assert not st.apply(LeaseRevoked(campaign_id=cid, task_id="ghost",
                                     reason="preempt", seq=5))


def test_reducer_done_on_revoke_pending_pulls_task_from_ready():
    """A TaskDone racing the regrant must pull the task back out of the
    ready queue — the pump may never grant a finished task."""
    spec = _spec1()
    cid, tid = "camp-lrd", "camp-lrd-s-00000"
    st = CampaignState.fold(spec, cid, [
        CampaignSubmitted(campaign_id=cid, pipeline="lr", items=(1,), seq=0),
        StageDispatched(campaign_id=cid, stage="s", task_id=tid, index=0,
                        seq=1),
        LeaseGranted(campaign_id=cid, task_id=tid, attempt=0, seq=2),
        LeaseRevoked(campaign_id=cid, task_id=tid, reason="preempt", seq=3),
        TaskDone(campaign_id=cid, task_id=tid, result={"x": 1}, seq=4),
    ])
    assert st.tasks[tid].done and not st.tasks[tid].revoke_pending
    assert st.ready["s"] == []
    assert st.state == CampaignState.COMPLETED


def test_snapshot_round_trips_revocation_state():
    spec = _spec1()
    cid, tid = "camp-lrs", "camp-lrs-s-00000"
    st = CampaignState.fold(spec, cid, [
        CampaignSubmitted(campaign_id=cid, pipeline="lr", items=(1,), seq=0),
        StageDispatched(campaign_id=cid, stage="s", task_id=tid, index=0,
                        seq=1),
        LeaseGranted(campaign_id=cid, task_id=tid, attempt=0, seq=2),
        LeaseRevoked(campaign_id=cid, task_id=tid, reason="preempt", seq=3),
    ])
    snap = snapshot_event(st)
    restored = CampaignState.fold(spec, cid, [snap])
    assert restored == st
    assert restored.tasks[tid].revoke_pending
    assert restored.ready["s"] == [tid]
    assert restored.preemptions == 1


# ---------------------------------------------------------------------------
# scheduled compaction (monitor-driven maintenance)
# ---------------------------------------------------------------------------

def test_scheduled_compaction_runs_from_monitor_loop():
    spec = _sleep_spec("sc", 0.01)
    with KsaCluster(workers=1, worker_slots=2, poll_interval_s=0.005,
                    compact_interval_s=0.2) as c:
        for _ in range(2):
            res = c.run_campaign(spec, [0, 1], timeout_s=30.0)
            assert res.status.state == "COMPLETED"
        assert _wait(lambda: c.monitor.summary()["compactions"] >= 1,
                     timeout=15.0)
        # terminal campaigns collapsed to snapshots on the journal topic
        topic = f"{c.prefix}-campaigns"
        journals = group_journal(
            [r.value for r in c.broker.read_from(topic)])
        for cid, events in journals.items():
            assert len(events) == 1, (cid, [type(e).__name__ for e in events])
        # a recover() of the compacted journal still rebuilds with parity
        recovered = c.pipeline.recover([spec], include_finished=True)
        assert recovered == []  # still registered on the live agent


def test_compaction_event_threshold_triggers():
    spec = _sleep_spec("sce", 0.01)
    with KsaCluster(workers=1, worker_slots=2, poll_interval_s=0.005,
                    compact_every_events=5) as c:
        res = c.run_campaign(spec, [0, 1, 2], timeout_s=30.0)
        assert res.status.state == "COMPLETED"
        assert _wait(lambda: c.monitor.summary()["compactions"] >= 1,
                     timeout=15.0)


# ---------------------------------------------------------------------------
# drain × recovery interplay (ISSUE satellite)
# ---------------------------------------------------------------------------

def test_orchestrator_killed_while_drain_requeues_deferred_leases():
    """Kill the orchestrator while an autoscale-style drain is requeuing
    deferred leases, then recover(): no task lost, none double-run, and
    the journal folds cleanly (idempotent under a duplicated suffix)."""
    broker = Broker(default_partitions=2)
    spec = PipelineSpec("dr", [
        Stage("work", "sleep", fan_out=1,
              params={"duration": 0.4},
              resources=Resources(cpus=1, mem_mb=2048),
              retry=RetryPolicy(max_attempts=3, timeout_s=20.0)),
    ])
    w1 = WorkerAgent(broker, "dr", slots=2, poll_interval_s=0.005,
                     profile=ResourceProfile(cpus=2, mem_mb=2048)).start()
    pipe1 = PipelineAgent(broker, "dr", poll_interval_s=0.005).start()
    try:
        cid = pipe1.submit_campaign(spec, list(range(4)),
                                    campaign_id="camp-drainrec")
        # mem budget 2048 with 2048-MB tasks: one runs, the rest defer
        assert _wait(lambda: w1.stats()["deferred_pending"] >= 1,
                     timeout=15.0)
        pipe1.crash()                       # orchestrator dies first...
        w1.request_drain(timeout_s=10.0)    # ...mid-drain requeue
        assert _wait(lambda: not w1.alive, timeout=30.0)
        assert w1.tasks_requeued >= 1
        # fresh pool + fresh orchestrator on the same broker
        w2 = WorkerAgent(broker, "dr", slots=2, poll_interval_s=0.005).start()
        pipe2 = PipelineAgent(broker, "dr", agent_id="drain-rec",
                              poll_interval_s=0.005).start()
        assert pipe2.recover([spec]) == [cid]
        st = pipe2.wait(cid, timeout=60.0)
        assert st.state == "COMPLETED", st.failure
        work = st.stages["work"]
        assert work.done == 4               # nothing lost
        results = pipe2.results(cid)["work"]
        assert len(results) == 4
        # nothing double-run: each task's execution was *accepted* exactly
        # once across both workers — a racing drain-requeue vs recovery
        # resubmission is resolved by the lease claim/commit fences, so a
        # superseded attempt either never starts or has its verdict
        # suppressed (the journal-replay `duplicates` counter, by contrast,
        # also counts benign redelivered records)
        assert w1.tasks_completed + w2.tasks_completed == 4, \
            (w1.stats(), w2.stats())
        # the journal folds cleanly: replaying it (even duplicated) yields
        # the same campaign state recover() reached
        topic = f"dr-campaigns"
        events = group_journal(
            [r.value for r in broker.read_from(topic)])[cid]
        st1 = CampaignState.fold(spec, cid, events)
        st2 = CampaignState.fold(spec, cid, events + events[-4:])
        assert st1 == st2
        assert st1.state == "COMPLETED"
        pipe2.stop()
        w2.stop()
    finally:
        broker.close()


# ---------------------------------------------------------------------------
# cross-site revocation fencing (repro.federation)
# ---------------------------------------------------------------------------

def test_cross_site_spill_preempted_never_commits_from_both():
    """Exactly-once across federation sites: a task spilled to site B whose
    home lease is preempted (site A takes it back) must never commit from
    both sides — the bridge revokes the remote copy, the home commit gate
    fences the stale relay, and only the post-preemption attempt's verdict
    lands."""
    from repro.federation import FederatedCluster, Site, WanLink

    # a real WAN latency on site B keeps the ordering deterministic: the
    # preempted relay's remote abort (a control call, ~remote_poll_s after
    # the cancel) always lands before the requeued retry's relay can ship
    # its payload back across the link
    b = Site("b", workers=1, link=WanLink(latency_s=0.2))
    with FederatedCluster([Site("a", workers=1), b],
                          task_timeout_s=60.0) as fed:
        # hangs on attempt 0, completes on the retry — so the preempted
        # remote execution can never "win the race" by finishing early
        tid = fed.submit("lease_hang_once", site="b")
        remote = fed.clusters["b"]
        assert _wait(lambda: remote.broker.lease_view(tid) is not None,
                     timeout=20.0)
        # home authority: one lease, stamped with the executing site
        home_lease = fed.home.broker.lease_view(tid)
        assert home_lease is not None and home_lease["site"] == "b"
        # preempt from home (site A reclaims the task)
        assert fed.revoke(tid, RevokeReason.PREEMPT)
        assert fed.wait_all([tid], timeout=40.0)
        e = fed.task(tid)
        assert e.done and e.duplicate_results == 0
        assert e.result_attempt >= 1          # preempted attempt 0 never lands
        assert e.result["attempt"] >= 1
        # the revocation crossed the WAN and fenced the remote holder too
        assert remote.broker.lease_stats()["revoked"].get(
            RevokeReason.PREEMPT, 0) >= 1
        # the bridge observed the fence: its relay was dropped, not returned
        snap = fed.home.broker.metrics.snapshot()
        events = snap["ksa_bridge_events_total"]["series"]
        fenced = sum(v for k, v in events.items() if k[-1] == "fenced")
        remote_revoked = sum(v for k, v in events.items()
                             if k[-1] == "remote_revoked")
        assert fenced >= 1 and remote_revoked >= 1
        # exactly one committed completion at the home lease table
        assert fed.home.broker.lease_stats()["completed"] == 1
