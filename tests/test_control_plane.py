"""End-to-end tests of the KSA control plane: Submitter -> broker ->
Cluster/Worker agents -> MonitorAgent, including the paper's watchdog,
oversubscription, and the attempt-fencing extension."""
import time

import pytest

from repro.core import (Broker, ClusterAgent, MonitorAgent, SimSlurm,
                        Submitter, TaskStatus, WorkerAgent)


@pytest.fixture
def stack():
    broker = Broker(default_partitions=4, session_timeout_s=1.0)
    sub = Submitter(broker, "t")
    mon = MonitorAgent(broker, "t", task_timeout_s=2.0,
                       poll_interval_s=0.01).start()
    agents = []
    slurms = []

    def add_worker(**kw):
        a = WorkerAgent(broker, "t", poll_interval_s=0.01, **kw).start()
        agents.append(a)
        return a

    def add_cluster(nodes=2, cpus=4, **kw):
        s = SimSlurm(nodes=nodes, cpus_per_node=cpus)
        slurms.append(s)
        a = ClusterAgent(broker, s, "t", poll_interval_s=0.01, **kw).start()
        agents.append(a)
        return a

    yield broker, sub, mon, add_worker, add_cluster
    for a in agents:
        a.stop()
    mon.stop()
    for s in slurms:
        s.shutdown()
    broker.close()


def test_worker_agent_runs_tasks(stack):
    broker, sub, mon, add_worker, _ = stack
    add_worker(slots=4)
    ids = [sub.submit("sleep", params={"duration": 0.02}) for _ in range(10)]
    assert mon.wait_all(ids, timeout=10.0)
    for tid in ids:
        e = mon.task(tid)
        assert e.status == TaskStatus.DONE.value
        assert e.result == {"slept": 0.02}


def test_cluster_agent_via_simslurm(stack):
    broker, sub, mon, _, add_cluster = stack
    agent = add_cluster(nodes=2, cpus=2)
    ids = [sub.submit("sleep", params={"duration": 0.02}, cpus=1)
           for _ in range(12)]
    assert mon.wait_all(ids, timeout=15.0)
    assert agent.tasks_completed == 12
    # all Slurm jobs drained (nodes released between tasks — the anti-Celery
    # property from paper §2)
    assert agent.slurm.sinfo()["running"] == 0
    assert agent.slurm.sinfo()["free_cpus"] == agent.slurm.total_cpus


def test_multi_pool_load_balancing(stack):
    """Tasks spread across two clusters + one workstation (paper §1: run
    concurrently on multiple Slurm clusters and workstations)."""
    broker, sub, mon, add_worker, add_cluster = stack
    w = add_worker(slots=2)
    c1 = add_cluster(nodes=1, cpus=2)
    c2 = add_cluster(nodes=1, cpus=2)
    ids = [sub.submit("sleep", params={"duration": 0.05}) for _ in range(24)]
    assert mon.wait_all(ids, timeout=20.0)
    done = [a.tasks_completed for a in (w, c1, c2)]
    assert sum(done) == 24
    assert all(d > 0 for d in done)  # every pool contributed


def test_error_flow_and_retry(stack):
    """fail-twice task: ERROR flow routes through PREFIX-error, monitor
    resubmits, third attempt succeeds."""
    broker, sub, mon, add_worker, _ = stack
    add_worker(slots=2)
    tid = sub.submit("fail", params={"fail_times": 2})
    deadline = time.time() + 10
    while time.time() < deadline:
        e = mon.task(tid)
        if e is not None and e.done:
            break
        time.sleep(0.02)
    e = mon.task(tid)
    assert e.done
    assert e.result == {"succeeded_after": 2}
    assert len(e.errors) == 2
    assert mon.resubmissions >= 2


def test_watchdog_cancels_hung_task_and_monitor_resubmits(stack):
    """Paper §3: hung tasks are cancelled on timeout; our monitor extension
    then resubmits (straggler mitigation)."""
    broker, sub, mon, add_worker, _ = stack
    mon.max_attempts = 2
    add_worker(slots=2, default_timeout_s=0.3)
    tid = sub.submit("sleep", params={"duration": 0.05}, timeout_s=0.3)
    tid_hang = sub.submit("hang", timeout_s=0.3)
    assert mon.wait_all([tid], timeout=5.0)
    deadline = time.time() + 8
    while time.time() < deadline:
        e = mon.task(tid_hang)
        if e is not None and mon.resubmissions >= 1:
            break
        time.sleep(0.02)
    assert mon.resubmissions >= 1
    hist = [h[1] for h in mon.task(tid_hang).history]
    assert TaskStatus.TIMEOUT.value in hist


def test_agent_crash_task_redelivered(stack):
    """Kill an agent mid-task: the monitor's watchdog notices the stale
    heartbeat and resubmits; a second agent completes the task."""
    broker, sub, mon, add_worker, _ = stack
    mon.task_timeout_s = 0.6
    a1 = add_worker(slots=1, heartbeat_interval_s=0.1)
    tid = sub.submit("sleep", params={"duration": 60.0})  # long task
    # wait until a1 picks it up
    deadline = time.time() + 5
    while time.time() < deadline:
        e = mon.task(tid)
        if e is not None and e.status == TaskStatus.RUNNING.value:
            break
        time.sleep(0.02)
    a1.crash()
    a2 = add_worker(slots=1, heartbeat_interval_s=0.1)
    # monitor resubmits after task_timeout_s of silence; a2 runs attempt 1.
    deadline = time.time() + 15
    while time.time() < deadline:
        e = mon.task(tid)
        if e is not None and e.status == TaskStatus.RUNNING.value and \
                e.attempt >= 1 and a2.stats()["in_flight"] > 0:
            break
        time.sleep(0.02)
    e = mon.task(tid)
    assert e.attempt >= 1
    assert a2.stats()["in_flight"] == 1


def test_duplicate_result_fencing(stack):
    """Two agents complete the same task (redelivery race): exactly one
    result is accepted, the duplicate is fenced and counted."""
    broker, sub, mon, add_worker, _ = stack
    add_worker(slots=2)
    tid = sub.submit("sleep", params={"duration": 0.02})
    assert mon.wait_all([tid], timeout=5.0)
    # simulate the late duplicate from a resurrected attempt
    from repro.core.messages import ResultMessage
    from repro.core.broker import Producer
    p = Producer(broker)
    p.send(sub.topics["done"],
           ResultMessage(task_id=tid, agent_id="ghost", attempt=9,
                         result={"slept": 999}).to_dict(), key=tid)
    deadline = time.time() + 5
    while time.time() < deadline:
        if mon.task(tid).duplicate_results == 1:
            break
        time.sleep(0.02)
    e = mon.task(tid)
    assert e.duplicate_results == 1
    assert e.result == {"slept": 0.02}  # first result won


def test_oversubscription_keeps_slurm_queue_nonempty(stack):
    """Paper's ClusterAgent strategy: pending jobs waiting in the queue while
    all slots are busy."""
    broker, sub, mon, _, add_cluster = stack
    agent = add_cluster(nodes=1, cpus=2, oversubscribe=4)
    ids = [sub.submit("sleep", params={"duration": 0.3}) for _ in range(10)]
    saw_pending_while_full = False
    deadline = time.time() + 10
    while time.time() < deadline:
        info = agent.slurm.sinfo()
        if info["running"] == 2 and info["pending"] > 0:
            saw_pending_while_full = True
            break
        time.sleep(0.005)
    assert saw_pending_while_full
    assert mon.wait_all(ids, timeout=20.0)


def test_monitor_rest_api(stack):
    import json
    import urllib.request
    broker, sub, mon, add_worker, _ = stack
    add_worker(slots=2)
    ids = [sub.submit("sleep", params={"duration": 0.02}) for _ in range(3)]
    assert mon.wait_all(ids, timeout=5.0)
    port = mon.start_http(0)

    def get(path):
        with urllib.request.urlopen(f"http://127.0.0.1:{port}{path}") as r:
            return json.loads(r.read())

    summary = get("/summary")
    assert summary["done"] == 3
    tasks = get("/tasks")
    assert set(ids) <= set(tasks)
    one = get(f"/tasks/{ids[0]}")
    assert one["status"] == "DONE"
    stats = get("/broker")
    assert "t-new" in stats["topics"]


def test_elastic_scale_up_mid_campaign(stack):
    """Elasticity: an agent joining mid-campaign is absorbed by the consumer-
    group rebalance and contributes work (paper §3: the broker load-balances
    across however many agents exist)."""
    broker, sub, mon, add_worker, _ = stack
    a1 = add_worker(slots=1)
    ids = [sub.submit("sleep", params={"duration": 0.08}) for _ in range(16)]
    time.sleep(0.3)  # campaign under way on one agent
    a2 = add_worker(slots=1)  # scale up
    assert mon.wait_all(ids, timeout=30.0)
    assert a2.tasks_completed > 0, "joined agent never got work"
    assert a1.tasks_completed + a2.tasks_completed == 16
    gens = broker.stats()["groups"]["t-agents"]["generation"]
    assert gens >= 2  # at least one rebalance happened
