"""repro.autoscale: pure policy decisions (hysteresis, cooldowns,
scale-to-zero), the broker's incremental backlog counters, the agents'
graceful-drain lifecycle (deferred leases are requeued, in-flight work
finishes — never lost, never double-run), SimSlurm node spin-up latency,
and the full sense→decide→act loop: a burst grows the gpu pool, the drain
shrinks it back, and an autoscaled knot campaign matches the flat baseline
exactly across ≥3 scale-down events."""
import time

import pytest

from repro.autoscale import (AutoscaleConfig, AutoscaleController,
                             AutoscaleError, PoolSignal, PoolSpec,
                             TargetBacklogPolicy)
from repro.cluster import KsaCluster
from repro.core import Broker, Consumer, ResourceClassPolicy, Resources
from repro.core.simslurm import SimSlurm
from repro.pipeline import PipelineSpec, RetryPolicy, Stage


def _sig(**kw) -> PoolSignal:
    base = dict(cls="gpu", backlog=0, in_flight=0, agents=1, slots=1,
                drain_rate=0.0, idle_for_s=0.0, since_scale_up_s=1e9,
                since_scale_down_s=1e9)
    base.update(kw)
    return PoolSignal(**base)


POL = TargetBacklogPolicy(target=2.0, high=1.0, idle_grace_s=0.5,
                          up_cooldown_s=0.25, down_cooldown_s=0.5)
SPEC = PoolSpec("gpu", min_agents=1, max_agents=4, slots=1)


# ---------------------------------------------------------------------------
# config / spec validation
# ---------------------------------------------------------------------------

def test_pool_spec_and_config_validation():
    with pytest.raises(AutoscaleError):
        PoolSpec("cpu", kind="k8s")
    with pytest.raises(AutoscaleError):
        PoolSpec("cpu", min_agents=3, max_agents=2)
    with pytest.raises(AutoscaleError):
        PoolSpec("cpu", slots=0)
    with pytest.raises(AutoscaleError):  # slurm kwargs on a worker pool
        PoolSpec("cpu", slurm={"nodes": 1})
    with pytest.raises(AutoscaleError):  # duplicate class
        AutoscaleConfig(pools=(PoolSpec("cpu"), PoolSpec("cpu")))
    with pytest.raises(AutoscaleError):  # empty
        AutoscaleConfig(pools=())
    # derived profiles: gpu pools are gpu-capable, label pools are tainted
    assert PoolSpec("gpu", slots=2).resolve_profile().gpus == 1
    serve = PoolSpec("serve").resolve_profile()
    assert serve.labels == ("serve",) and serve.taints == ("serve",)


def test_unknown_pool_class_fails_fast():
    cfg = AutoscaleConfig(pools=(PoolSpec("bigmem", min_agents=1),))
    with KsaCluster(prefix="asv") as c:  # default policy: cpu/gpu only
        with pytest.raises(AutoscaleError):
            AutoscaleController(c, cfg)


# ---------------------------------------------------------------------------
# the default policy is a pure function — drive synthetic signals
# ---------------------------------------------------------------------------

def test_policy_scales_up_on_backlog_and_sizes_to_demand():
    # 10 queued + 1 running on 1 slot: size for target backlog 2/slot
    assert POL.desired(_sig(backlog=10, in_flight=1), SPEC) == 4  # capped
    assert POL.desired(_sig(backlog=3, in_flight=1), SPEC) == 2
    # growth is at least one agent even when the estimate rounds down
    assert POL.desired(_sig(backlog=3, in_flight=0, agents=2), SPEC) == 3


def test_policy_up_cooldown_holds_despite_backlog():
    sig = _sig(backlog=10, since_scale_up_s=0.1)  # < up_cooldown_s
    assert POL.desired(sig, SPEC) == sig.agents


def test_policy_hysteresis_band_prevents_flapping():
    """Backlog oscillating between 0 and the high watermark changes
    nothing: not high enough to grow, not idle long enough to shrink."""
    agents = 2
    for backlog in [0, 1, 0, 2, 0, 1, 2, 0] * 3:
        sig = _sig(backlog=backlog, agents=agents, slots=1,
                   idle_for_s=0.1,  # idle flickers, never past the grace
                   since_scale_up_s=1e9, since_scale_down_s=1e9)
        assert POL.desired(sig, SPEC) == agents  # 2/slot == high: hold


def test_policy_scale_down_requires_idle_grace_cooldown_and_floor():
    # busy pool never shrinks
    assert POL.desired(_sig(agents=3, in_flight=1), SPEC) == 3
    # idle but not long enough
    assert POL.desired(_sig(agents=3, idle_for_s=0.2), SPEC) == 3
    # idle long enough but inside the down cooldown
    assert POL.desired(_sig(agents=3, idle_for_s=1.0,
                            since_scale_down_s=0.1), SPEC) == 3
    # eligible: one step down at a time
    assert POL.desired(_sig(agents=3, idle_for_s=1.0), SPEC) == 2
    # never below the floor
    assert POL.desired(_sig(agents=1, idle_for_s=1e9), SPEC) == 1


def test_policy_scale_to_zero_and_cold_wake():
    spec0 = PoolSpec("serve", min_agents=0, max_agents=2)
    # drains to zero when idle
    assert POL.desired(_sig(agents=1, idle_for_s=1.0), spec0) == 0
    # any queued demand wakes the empty pool, cooldowns notwithstanding
    assert POL.desired(_sig(agents=0, backlog=1, since_scale_up_s=0.0,
                            since_scale_down_s=0.0), spec0) == 1


# ---------------------------------------------------------------------------
# sensing: broker backlog counters
# ---------------------------------------------------------------------------

def test_broker_queue_stats_tracks_depth_and_consumed():
    b = Broker(default_partitions=2)
    for i in range(8):
        b.produce("q", {"i": i}, key=str(i))
    qs = b.queue_stats("g", ["q"])
    assert qs["q"] == {"produced": 8, "consumed": 0, "depth": 8}
    c = Consumer(b, ["q"], "g")
    c.poll(1.0)
    c.commit()
    qs = b.queue_stats("g", ["q"])
    assert qs["q"]["depth"] == 0 and qs["q"]["consumed"] == 8
    # stats() surfaces the same counters as per-group lag
    assert b.stats()["groups"]["g"]["lag"]["q"] == 0


# ---------------------------------------------------------------------------
# acting: graceful drain (the scale-down path) and SimSlurm cold start
# ---------------------------------------------------------------------------

def test_drain_requeues_deferred_and_finishes_inflight_without_dup():
    """An agent removed mid-run: its running task completes (not re-run),
    its deferred mem-queue lease is requeued and executed elsewhere."""
    with KsaCluster(workers=1, worker_slots=2, poll_interval_s=0.005) as c:
        w = c.agents[0]  # profile budget 2048 MB
        tids = [c.submit("sleep", params={"duration": 0.4}, mem_mb=2048)
                for _ in range(2)]
        assert _wait(lambda: w.stats()["deferred_pending"] == 1)
        w2 = c.add_worker(slots=2)
        assert c.drain_worker(w, timeout_s=20.0)
        assert w.state == "stopped" and w.tasks_requeued == 1
        assert w not in c.agents  # deregistered
        assert c.wait_all(tids, timeout=20.0)
        s = c.monitor.summary()
        assert s["results_handled"] == 2 and s["duplicates_fenced"] == 0
        done_by = {c.task(t).agent_id for t in tids}
        assert done_by == {w.agent_id, w2.agent_id}


def test_stop_flushes_deferred_mem_queue_regression():
    """Regression (ISSUE satellite): plain stop() used to silently drop the
    deferred queue — leased tasks nobody would redeliver until a watchdog
    timeout. They must be requeued immediately instead."""
    with KsaCluster(workers=1, worker_slots=2, poll_interval_s=0.005,
                    task_timeout_s=1.0) as c:
        w = c.agents[0]
        tids = [c.submit("sleep", params={"duration": 0.3}, mem_mb=2048)
                for _ in range(2)]
        assert _wait(lambda: w.stats()["deferred_pending"] == 1)
        c.add_worker(slots=2)
        w.stop()
        assert w.tasks_requeued == 1
        # the running task is cancelled (stop's redelivery contract, via
        # the monitor watchdog); the deferred one was requeued directly —
        # both must complete on the survivor
        assert c.wait_all(tids, timeout=30.0)


def test_simslurm_spinup_delays_placement():
    sim = SimSlurm(nodes=1, cpus_per_node=1, spinup_s=0.4,
                   scheduler_interval_s=0.01)
    try:
        ran = []
        jid = sim.sbatch(lambda: ran.append(1), cpus=1)
        time.sleep(0.15)
        assert sim.job(jid).state == "PD"  # node still booting
        assert sim.sinfo()["nodes_up"] == 0
        assert _wait(lambda: sim.job(jid).state == "CD", timeout=5.0)
        assert ran == [1] and sim.sinfo()["nodes_up"] == 1
    finally:
        sim.shutdown()


# ---------------------------------------------------------------------------
# the full loop
# ---------------------------------------------------------------------------

def _wait(cond, timeout=10.0, interval=0.01):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return False


def _fast_cfg(*pools, target=1.0) -> AutoscaleConfig:
    return AutoscaleConfig(
        pools=pools,
        policy=TargetBacklogPolicy(target=target, high=1.0, idle_grace_s=0.2,
                                   up_cooldown_s=0.05, down_cooldown_s=0.1),
        interval_s=0.02)


def test_burst_scales_gpu_pool_up_then_back_down():
    cfg = _fast_cfg(PoolSpec("cpu", min_agents=1, max_agents=2, slots=2),
                    PoolSpec("gpu", min_agents=1, max_agents=3, slots=1))
    with KsaCluster(autoscale=cfg, poll_interval_s=0.005) as c:
        a = c.autoscaler
        assert a.pool_size("cpu") == 1 and a.pool_size("gpu") == 1
        tids = [c.submit("sleep", params={"duration": 0.15}, gpus=1)
                for _ in range(12)]
        assert _wait(lambda: a.pool_size("gpu") >= 2, timeout=10.0)
        assert c.wait_all(tids, timeout=30.0)
        # the drain brings the pool back to its floor
        assert _wait(lambda: a.pool_size("gpu") == 1, timeout=15.0)
        assert a.scale_downs >= 1
        s = c.monitor.summary()
        assert s["results_handled"] == 12 and s["duplicates_fenced"] == 0
        # the /autoscale payload carries history + decisions
        st = a.status()
        assert st["pools"]["gpu"]["history"]
        assert any(d["action"] == "down" for d in st["decisions"])


def test_scale_down_loses_nothing_across_three_plus_drains():
    """The acceptance criterion: a two-class bursty campaign on an elastic
    gpu pool — every task exactly once (count parity) across >= 3
    scale-down events."""
    spec = PipelineSpec("burst", [
        Stage("screen", "sleep", fan_out=1, params={"duration": 0.01},
              resources=Resources(cpus=1),
              retry=RetryPolicy(max_attempts=3)),
        Stage("localize", "sleep", depends_on=("screen",),
              params={"duration": 0.08}, resources=Resources(cpus=1, gpus=1),
              retry=RetryPolicy(max_attempts=3)),
    ])
    cfg = _fast_cfg(PoolSpec("cpu", min_agents=1, max_agents=2, slots=2),
                    PoolSpec("gpu", min_agents=1, max_agents=4, slots=1))
    with KsaCluster(autoscale=cfg, poll_interval_s=0.005) as c:
        res = c.run_campaign(spec, list(range(32)), timeout_s=120.0)
        assert res.status.state == "COMPLETED"
        counts = {n: s.done for n, s in res.status.stages.items()}
        assert counts == {"screen": 32, "localize": 32}
        assert sum(s.duplicates for s in res.status.stages.values()) == 0
        a = c.autoscaler
        assert _wait(lambda: a.pool_size("gpu") == 1, timeout=15.0)
        assert a.scale_downs >= 3, a.status()["decisions"]
        s = c.monitor.summary()
        assert s["results_handled"] == 64 and s["duplicates_fenced"] == 0


def test_autoscaled_knot_campaign_matches_flat_baseline():
    """Knot-count parity (the ISSUE's no-loss/no-dup oracle): the same
    structures through an autoscaled gpu-localize campaign and through the
    static flat baseline must report identical knotted sets and cores."""
    from repro.apps import knots

    structures, batch, n_points = 48, 8, 64
    cfg = _fast_cfg(PoolSpec("cpu", min_agents=1, max_agents=3, slots=2),
                    PoolSpec("gpu", min_agents=1, max_agents=3, slots=1))
    with KsaCluster(autoscale=cfg, poll_interval_s=0.005,
                    pipeline_task_timeout_s=60.0) as c:
        spec = knots.knots_pipeline(batch, n_points=n_points,
                                    task_timeout_s=60.0, gpu_localize=True)
        res = c.run_campaign(spec, list(range(structures)), timeout_s=300.0)
        agg = res.final
        assert sum(s.duplicates for s in res.status.stages.values()) == 0
        assert c.autoscaler.scale_ups >= 1
        # flat baseline on a separate prefix, same broker
        with KsaCluster(prefix="flatb", broker=c.broker,
                        poll_interval_s=0.005) as fc:
            fc.add_worker(slots=2)
            tids = fc.submit_batches(
                "knot_batch", list(range(structures)), batch_size=batch,
                params={"n_points": n_points, "stage2": True})
            assert fc.wait_all(tids, timeout=300.0)
            knotted, cores = set(), {}
            for t in tids:
                r = fc.result(t)
                knotted.update(r["knotted"])
                cores.update(r["cores"])
        assert sorted(knotted) == agg["knotted"]
        assert set(cores) == set(agg["cores"])


def test_scale_to_zero_tainted_serve_pool_wakes_and_sleeps():
    """A tainted pool with min_agents=0: no agents while idle, the first
    tolerated task wakes it (cold start), and it drains back to zero."""
    placement = ResourceClassPolicy(extra_classes=("serve",))
    cfg = _fast_cfg(PoolSpec("cpu", min_agents=1, max_agents=1, slots=2),
                    PoolSpec("serve", min_agents=0, max_agents=2, slots=1))
    with KsaCluster(placement=placement, autoscale=cfg,
                    poll_interval_s=0.005) as c:
        a = c.autoscaler
        time.sleep(0.2)
        assert a.pool_size("serve") == 0  # scale-to-zero at rest
        tid = c.submit("sleep", params={"duration": 0.1}, labels=["serve"])
        assert _wait(lambda: a.pool_size("serve") >= 1, timeout=10.0)
        assert c.wait_all([tid], timeout=20.0)
        serve_agents = {ag.agent_id for ag in c.agents
                        if ag.profile and "serve" in ag.profile.taints}
        assert c.task(tid).agent_id in serve_agents
        assert _wait(lambda: a.pool_size("serve") == 0, timeout=15.0)


def test_autoscaled_slurm_pool_grows_with_spinup_cold_start():
    """A kind="slurm" pool: growth attaches a fresh SimSlurm whose nodes
    spin up with latency — the backlog rides out the cold start instead of
    over-provisioning (up_cooldown), and work completes once nodes boot."""
    cfg = AutoscaleConfig(
        pools=(PoolSpec("cpu", kind="slurm", min_agents=1, max_agents=2,
                        slots=2,
                        slurm=dict(nodes=1, cpus_per_node=2,
                                   spinup_s=0.3)),),
        policy=TargetBacklogPolicy(target=1.0, high=1.0, idle_grace_s=0.3,
                                   up_cooldown_s=0.4, down_cooldown_s=0.3),
        interval_s=0.02)
    with KsaCluster(autoscale=cfg, poll_interval_s=0.005) as c:
        a = c.autoscaler
        tids = [c.submit("sleep", params={"duration": 0.05})
                for _ in range(16)]
        assert _wait(lambda: a.pool_size("cpu") == 2, timeout=10.0)
        assert c.wait_all(tids, timeout=60.0)
        s = c.monitor.summary()
        assert s["results_handled"] == 16
        # the drained slurm pool's owned simulator is shut down with it
        assert _wait(lambda: a.pool_size("cpu") == 1, timeout=20.0)
        assert _wait(lambda: len(c._slurms) == 1, timeout=10.0)
