"""Resource-aware placement, fair-share leasing, and the KsaCluster facade:
GPU tasks can never execute on CPU-only pools (they queue on the GPU class
topic instead), weighted campaigns drain in weight proportion, the facade
owns component lifecycle (double-start, clean shutdown, aggregated status),
memory is enforced at lease time (worker admission + SimSlurm packing), and
taints make labelled pools exclusive unless a task tolerates them."""
import time

import pytest

from repro.cluster import KsaCluster
from repro.core import (Broker, FairShare, Producer, ResourceClassPolicy,
                        ResourceProfile, Resources, SingleTopicPolicy,
                        Submitter, TaskMessage, WorkerAgent, class_topic)
from repro.core.simslurm import SimSlurm
from repro.pipeline import PipelineSpec, RetryPolicy, Stage


def _task(gpus=0, labels=(), tolerations=()):
    return TaskMessage(task_id="t0", script="sleep",
                       resources=Resources(gpus=gpus, labels=labels,
                                           tolerations=tolerations))


# ---------------------------------------------------------------------------
# placement policy unit tests
# ---------------------------------------------------------------------------

def test_resource_class_policy_routes_by_class():
    pol = ResourceClassPolicy(extra_classes=("bigmem",))
    assert pol.route("p", _task()) == "p-new.cpu"
    assert pol.route("p", _task(gpus=1)) == "p-new.gpu"
    assert pol.route("p", _task(labels=("bigmem",))) == "p-new.bigmem"
    assert set(pol.topics("p")) == {"p-new.cpu", "p-new.gpu", "p-new.bigmem"}


def test_subscriptions_follow_profile():
    pol = ResourceClassPolicy()
    # universal (legacy) agent: every class
    assert set(pol.subscriptions("p", None)) == {"p-new.cpu", "p-new.gpu"}
    # cpu-only pool never sees the gpu class
    assert pol.subscriptions("p", ResourceProfile(cpus=4)) == ("p-new.cpu",)
    # gpu pool drains cpu work too by default (work conservation) ...
    assert set(pol.subscriptions("p", ResourceProfile(gpus=1))) == \
        {"p-new.gpu", "p-new.cpu"}
    # ... unless dedicated
    dedicated = ResourceClassPolicy(gpu_takes_cpu=False)
    assert dedicated.subscriptions("p", ResourceProfile(gpus=1)) == \
        ("p-new.gpu",)


def test_single_topic_policy_is_the_paper_layout():
    pol = SingleTopicPolicy()
    assert pol.route("p", _task(gpus=1)) == "p-new"
    assert pol.subscriptions("p", ResourceProfile(cpus=1)) == ("p-new",)


def test_profile_can_run_checks_routability_only():
    prof = ResourceProfile(cpus=2, gpus=0, labels=("fast",))
    assert prof.can_run(Resources(cpus=8))          # cpus: capacity, not routing
    assert not prof.can_run(Resources(gpus=1))
    assert prof.can_run(Resources(labels=("fast",)))
    assert not prof.can_run(Resources(labels=("bigmem",)))


def test_fair_share_smooth_wrr_sequence():
    """Weights 3:1 drain 3 of A for every B, deterministically."""
    lease = FairShare()
    picks = [lease.select({"A": 3.0, "B": 1.0}) for _ in range(12)]
    assert picks.count("A") == 9 and picks.count("B") == 3
    # no starvation: B appears in every window of 4
    for i in range(0, 12, 4):
        assert "B" in picks[i:i + 4]


# ---------------------------------------------------------------------------
# routing end to end
# ---------------------------------------------------------------------------

def test_gpu_tasks_never_run_on_cpu_agents_even_when_saturated():
    """The acceptance criterion: a saturated 1-slot GPU pool makes GPU tasks
    queue on the gpu class topic — idle CPU workers must not steal them."""
    with KsaCluster(prefix="rt1", poll_interval_s=0.005) as c:
        for _ in range(2):
            c.add_worker(slots=2)  # cpu-only profiles
        gpu = c.add_worker(slots=1, profile=ResourceProfile(cpus=1, gpus=1))
        # 3 serial GPU tasks on the single gpu slot + quick cpu chaff
        gpu_ids = [c.submit("sleep", params={"duration": 0.1}, gpus=1)
                   for _ in range(3)]
        cpu_ids = [c.submit("sleep", params={"duration": 0.01})
                   for _ in range(8)]
        assert c.wait_all(cpu_ids + gpu_ids, timeout=30.0)
        for tid in gpu_ids:
            assert c.task(tid).agent_id == gpu.agent_id, tid
        # the cpu pool did the cpu work (it was not starved by gpu queuing)
        cpu_agents = {c.task(t).agent_id for t in cpu_ids}
        assert cpu_agents - {gpu.agent_id}


def test_misrouted_task_is_bounced_to_its_class_topic():
    """Defence in depth: a GPU task produced straight onto the cpu class
    topic is rerouted by the cpu worker, not executed by it."""
    # dedicated gpu pool: it never subscribes the cpu class, so the bounce
    # must come from the cpu worker
    with KsaCluster(prefix="rt2", poll_interval_s=0.005,
                    placement=ResourceClassPolicy(gpu_takes_cpu=False)) as c:
        cpu = c.add_worker(slots=1)
        gpu = c.add_worker(slots=1, profile=ResourceProfile(cpus=1, gpus=1))
        bad = TaskMessage(task_id="misroute-0", script="sleep",
                          params={"duration": 0.01},
                          resources=Resources(gpus=1))
        Producer(c.broker).send(class_topic("rt2", "cpu"), bad.to_dict(),
                                key=bad.task_id)
        assert c.wait_all([bad.task_id], timeout=15.0)
        assert c.task(bad.task_id).agent_id == gpu.agent_id
        assert cpu.stats()["rerouted"] == 1


def test_pipeline_routes_stage_resources_end_to_end():
    """ParaFold split through the DAG: the gpu-stage tasks of a campaign run
    only on the GPU pool, cpu stages only see the cpu pool."""
    spec = PipelineSpec("mix", [
        Stage("prep", "sleep", fan_out=1, params={"duration": 0.0}),
        Stage("infer", "sleep", depends_on=("prep",),
              params={"duration": 0.0}, resources=Resources(gpus=1)),
    ])
    with KsaCluster(prefix="rt3", poll_interval_s=0.005) as c:
        c.add_worker(slots=2)
        gpu = c.add_worker(slots=1, profile=ResourceProfile(cpus=1, gpus=1))
        res = c.run_campaign(spec, list(range(4)), timeout_s=60.0)
        assert res.status.state == "COMPLETED"
        infer_ids = [f"{res.campaign_id}-infer-{i:05d}" for i in range(4)]
        # run_campaign returns on the pipeline agent's own consumer; the
        # monitor's mirror is async — wait for it before asserting on it
        assert c.wait_all(infer_ids, timeout=10.0)
        for tid in infer_ids:
            assert c.task(tid).agent_id == gpu.agent_id, tid


# ---------------------------------------------------------------------------
# fair sharing across campaigns
# ---------------------------------------------------------------------------

def test_weighted_campaigns_complete_in_weight_ratio():
    """Two 9-task campaigns with weights 3:1 on one 1-slot worker: when the
    heavy campaign finishes, the light one should have completed roughly a
    third as many tasks (weighted round-robin, not first-come)."""
    def spec():
        return PipelineSpec("w", [
            Stage("work", "sleep", fan_out=1, params={"duration": 0.02},
                  retry=RetryPolicy(max_attempts=2)),
        ])

    # max_in_flight_total=1 makes the agent-wide budget the contended
    # resource: every completion triggers one weighted-round-robin pick
    # across the two campaigns' ready queues.
    with KsaCluster(prefix="fs1", poll_interval_s=0.002,
                    max_in_flight_total=1) as c:
        c.add_worker(slots=1, poll_interval_s=0.002)
        heavy = c.submit_campaign(spec(), list(range(9)), weight=3.0)
        light = c.submit_campaign(spec(), list(range(9)), weight=1.0)
        st_heavy = c.wait_campaign(heavy, timeout=60.0)
        assert st_heavy.state == "COMPLETED"
        light_done = c.campaign_status(light).stages["work"].done
        # exact WRR would leave 3 light tasks done; allow generous jitter but
        # reject FIFO (0 done) and unweighted round-robin (~9 done)
        assert 1 <= light_done <= 6, light_done
        assert c.wait_campaign(light, timeout=60.0).state == "COMPLETED"


# ---------------------------------------------------------------------------
# facade lifecycle
# ---------------------------------------------------------------------------

def test_cluster_double_start_raises_and_stop_is_idempotent():
    c = KsaCluster(prefix="lc1", workers=1)
    c.start()
    try:
        with pytest.raises(RuntimeError, match="already started"):
            c.start()
    finally:
        c.stop()
    c.stop()  # idempotent
    with pytest.raises(RuntimeError, match="not running"):
        c.submit("sleep")
    with pytest.raises(RuntimeError, match="stopped"):
        c.start()  # a stopped facade cannot be restarted


def test_cluster_requires_start_before_use():
    c = KsaCluster(prefix="lc2")
    with pytest.raises(RuntimeError, match="not running"):
        c.submit("sleep")
    with pytest.raises(RuntimeError, match="not running"):
        c.add_worker()


def test_cluster_clean_shutdown_drains_agents():
    c = KsaCluster(prefix="lc3", workers=1, worker_slots=1,
                   poll_interval_s=0.005)
    c.start()
    w = c.agents[0]
    tid = c.submit("sleep", params={"duration": 30.0})
    deadline = time.time() + 5.0
    while time.time() < deadline and w.stats()["in_flight"] == 0:
        time.sleep(0.005)
    assert w.stats()["in_flight"] == 1
    c.stop()
    # drain cancelled the in-flight task and the loop exited
    assert not w.alive
    assert w.stats()["in_flight"] == 0
    assert c.broker._closed  # owned broker closed
    # the cancelled task was never completed (it would be redelivered by a
    # fresh deployment's watchdog, same as the paper's recovery flow)
    assert w.tasks_completed == 0


def test_cluster_shares_external_broker_without_closing_it():
    b = Broker(default_partitions=2)
    with KsaCluster(prefix="lc4", broker=b, workers=1) as c:
        tid = c.submit("sleep", params={"duration": 0.0})
        assert c.wait_all([tid], timeout=15.0)
    assert not b._closed
    b.close()


def test_cluster_status_aggregates_components():
    with KsaCluster(prefix="lc5", workers=1, http=True) as c:
        tid = c.submit("sleep", params={"duration": 0.0})
        assert c.wait_all([tid], timeout=15.0)
        st = c.status()
        assert st["prefix"] == "lc5"
        assert len(st["agents"]) == 1
        assert st["monitor"]["done"] == 1
        assert "lc5-new.cpu" in st["broker"]["topics"]
        assert c.http_port is not None


# ---------------------------------------------------------------------------
# taints / tolerations (satellite: exclusive labelled pools)
# ---------------------------------------------------------------------------

def test_taints_narrow_subscriptions_and_can_run():
    pol = ResourceClassPolicy(extra_classes=("serve",))
    tainted = ResourceProfile(cpus=2, labels=("serve",), taints=("serve",))
    # a serve-tainted pool subscribes ONLY to its class — it never even sees
    # the plain cpu/gpu topics
    assert pol.subscriptions("p", tainted) == ("p-new.serve",)
    # ...and refuses plain batch work even if it somehow arrives
    assert not tainted.can_run(Resources())
    assert tainted.can_run(Resources(labels=("serve",)))
    assert tainted.can_run(Resources(tolerations=("serve",)))
    # tolerating tasks are routed onto the tolerated class; unknown
    # tolerations are permissive, not demands — they fall through
    assert pol.route("p", _task(tolerations=("serve",))) == "p-new.serve"
    assert pol.route("p", _task(tolerations=("ghost",))) == "p-new.cpu"
    # ...but a gpu demand always wins: a toleration must never land a GPU
    # task on whatever hardware backs the tolerated pool
    assert pol.route("p", _task(gpus=1, tolerations=("serve",))) \
        == "p-new.gpu"
    # untainted pools are unchanged
    assert pol.subscriptions("p", ResourceProfile(cpus=2)) == ("p-new.cpu",)
    # taints naming no known class fail fast (a silently idle worker is a
    # misconfiguration), mirroring classify() on unknown labels
    with pytest.raises(ValueError, match="no resource class"):
        ResourceClassPolicy().subscriptions(
            "p", ResourceProfile(taints=("serve",)))


def test_tainted_serve_pool_refuses_plain_batch_work():
    """End to end: a serve-tainted worker never drains plain cpu tasks, but
    executes tasks that tolerate (or are labelled for) the taint."""
    pol = ResourceClassPolicy(extra_classes=("serve",))
    with KsaCluster(prefix="tt1", placement=pol, poll_interval_s=0.005) as c:
        serve = c.add_worker(
            slots=2, profile=ResourceProfile(cpus=2, mem_mb=2048,
                                             labels=("serve",),
                                             taints=("serve",)))
        cpu = c.add_worker(slots=1)
        plain = [c.submit("sleep", params={"duration": 0.01})
                 for _ in range(6)]
        tol = c.submit("sleep", params={"duration": 0.01},
                       resources=Resources(tolerations=("serve",)))
        assert c.wait_all(plain + [tol], timeout=30.0)
        # every plain task ran on the cpu pool, despite the serve pool
        # having been idle the whole time
        assert {c.task(t).agent_id for t in plain} == {cpu.agent_id}
        assert c.task(tol).agent_id == serve.agent_id
        assert serve.tasks_completed == 1


# ---------------------------------------------------------------------------
# mem-aware admission (satellite: mem_mb enforced at lease time)
# ---------------------------------------------------------------------------

def test_worker_mem_admission_serializes_oversubscribed_tasks():
    """Two 768 MB tasks on a 2-slot worker with a 1024 MB budget: slots
    would run them together, the memory budget must not — the second waits
    in the deferral queue (same packing SimSlurm applies per node)."""
    b = Broker(default_partitions=2)
    w = WorkerAgent(b, "mm", slots=2,
                    profile=ResourceProfile(cpus=2, mem_mb=1024),
                    poll_interval_s=0.005).start()
    sub = Submitter(b, "mm")
    try:
        for i in range(2):
            sub.submit("sleep", task_id=f"mem-{i}",
                       params={"duration": 0.15}, mem_mb=768)
        peak = 0
        deadline = time.time() + 15.0
        while time.time() < deadline and w.tasks_completed < 2:
            peak = max(peak, w.stats()["mem_in_flight_mb"])
            time.sleep(0.002)
        assert w.tasks_completed == 2
        assert peak <= 1024, peak            # never over budget
        assert w.stats()["deferred"] >= 1    # the second task waited
    finally:
        w.stop()
        b.close()


def test_worker_admits_oversized_task_when_idle():
    """A request larger than the whole budget can never fit; an idle worker
    runs it best-effort (mem stays a capacity hint at the margin, like cpus)
    instead of deadlocking the deferral queue."""
    b = Broker(default_partitions=2)
    w = WorkerAgent(b, "mo", slots=1,
                    profile=ResourceProfile(cpus=1, mem_mb=512),
                    poll_interval_s=0.005).start()
    sub = Submitter(b, "mo")
    try:
        sub.submit("sleep", task_id="big-0", params={"duration": 0.0},
                   mem_mb=4096)
        deadline = time.time() + 10.0
        while time.time() < deadline and w.tasks_completed < 1:
            time.sleep(0.005)
        assert w.tasks_completed == 1
    finally:
        w.stop()
        b.close()


def test_simslurm_packs_memory_like_cpus():
    """Per-node memory is a packed resource: two 1536 MB jobs on one
    4-cpu/2048 MB node run sequentially even though cpus are free."""
    sim = SimSlurm(nodes=1, cpus_per_node=4, mem_mb_per_node=2048,
                   scheduler_interval_s=0.005)
    try:
        running = []

        def job(cancel_event=None):
            running.append(time.time())
            time.sleep(0.1)

        j1 = sim.sbatch(job, cpus=1, mem_mb=1536)
        j2 = sim.sbatch(job, cpus=1, mem_mb=1536)
        deadline = time.time() + 5.0
        overlapped = False
        while time.time() < deadline:
            states = {sim.job(j1).state, sim.job(j2).state}
            if states == {"R"}:
                overlapped = True
            if states == {"CD"}:
                break
            time.sleep(0.005)
        assert sim.job(j1).state == sim.job(j2).state == "CD"
        assert not overlapped  # memory, not cpus, was the binding constraint
        assert sim.sinfo()["free_mem_mb"] == 2048
    finally:
        sim.shutdown()


# ---------------------------------------------------------------------------
# heartbeat-failure surfacing (satellite)
# ---------------------------------------------------------------------------

def test_heartbeat_failures_are_counted_not_swallowed():
    b = Broker(default_partitions=2)
    # slots=0 keeps the agent permanently saturated, so every tick takes the
    # heartbeat-only path; evicting its membership then makes that heartbeat
    # raise, which must be counted, not silently dropped.
    w = WorkerAgent(b, "hb", slots=0, poll_interval_s=0.005).start()
    try:
        time.sleep(0.05)
        b.leave_group("hb-agents", w._consumer.member_id)
        deadline = time.time() + 5.0
        while time.time() < deadline and w.stats()["heartbeat_failures"] == 0:
            time.sleep(0.005)
        assert w.stats()["heartbeat_failures"] > 0
    finally:
        w.stop()
        b.close()


# ---------------------------------------------------------------------------
# review hardening: unroutable labels, legacy bare-topic producers, unwind
# ---------------------------------------------------------------------------

def test_unknown_label_fails_fast_at_submission():
    pol = ResourceClassPolicy()
    with pytest.raises(ValueError, match="no resource class"):
        pol.route("p", _task(labels=("bigmem",)))
    with KsaCluster(prefix="ul1", workers=1) as c:
        with pytest.raises(ValueError, match="no resource class"):
            c.submit("sleep", labels=("bigmem",))
        # campaigns validate every stage up front, before planning tasks
        from repro.pipeline import PipelineError
        spec = PipelineSpec("bad", [
            Stage("src", "sleep", fan_out=1),
            Stage("big", "sleep", depends_on=("src",),
                  resources=Resources(labels=("bigmem",))),
        ])
        with pytest.raises(PipelineError, match="unroutable"):
            c.submit_campaign(spec, [1, 2])


def test_gpu_count_is_capacity_not_routability():
    """A 1-GPU pool may run a gpus=2 task (capacity hint, like cpus) — what
    it must never do is run on a 0-GPU pool."""
    assert ResourceProfile(gpus=1).can_run(Resources(gpus=2))
    assert not ResourceProfile(gpus=0).can_run(Resources(gpus=1))


def test_bare_topic_task_is_forwarded_to_class_topic():
    """A legacy producer writing to the paper's bare `PREFIX-new` topic:
    no agent consumes it under class routing, so the monitor forwards it —
    the task runs without waiting for any watchdog timeout."""
    with KsaCluster(prefix="lg1", workers=1, poll_interval_s=0.005) as c:
        legacy = TaskMessage(task_id="legacy-0", script="sleep",
                             params={"duration": 0.01})
        Producer(c.broker).send("lg1-new", legacy.to_dict(),
                                key=legacy.task_id)
        assert c.wait_all([legacy.task_id], timeout=15.0)
        assert c.monitor.legacy_forwards == 1


def test_cluster_start_failure_unwinds_started_components():
    c = KsaCluster(prefix="uw1", workers=1,
                   slurm=dict(nodes=1, cpus_per_node=1, oversubscrib=2))
    with pytest.raises(TypeError):
        c.start()  # typo'd ClusterAgent kwarg surfaces after pools started
    # the partially-started deployment was torn down, not leaked
    assert all(not a.alive for a in c.agents)
    assert c.monitor is not None and c.monitor._thread is not None
    assert not c.monitor._thread.is_alive()
    assert c.broker._closed


def test_balanced_partitioner_levels_task_records():
    """``Submitter(partitioner="balanced")`` places task records on the
    least-loaded partition while keeping the ``key=task_id`` the lease
    grant path requires — 24 records over 8 partitions land exactly 3
    deep, where keyed hashing would skew (and the most-loaded member of a
    sticky consumer group sets a campaign's makespan)."""
    b = Broker(default_partitions=8)
    try:
        sub = Submitter(b, "bp", partitioner="balanced")
        for i in range(24):
            sub.submit("sleep", task_id=f"bal-{i}", params={"duration": 0.0})
        topic = class_topic("bp", "cpu")
        recs = b.read_from(topic)
        per_part = [0] * 8
        for r in recs:
            assert r.key == r.value["task_id"]
            per_part[r.partition] += 1
        assert per_part == [3] * 8, per_part
    finally:
        b.close()


def test_submitter_rejects_unknown_partitioner():
    b = Broker(default_partitions=2)
    try:
        with pytest.raises(ValueError, match="partitioner"):
            Submitter(b, "bq", partitioner="sticky")
    finally:
        b.close()
