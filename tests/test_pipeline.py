"""Pipeline semantics: spec validation, fan-out → map → join execution,
duplicate-result fencing at the barrier, backpressure, watchdog recovery
from a mid-campaign agent kill, and the /campaigns REST mirror."""
import json
import time
import urllib.request

import pytest

from repro.core import (Broker, ClusterComputing, MonitorAgent, Submitter,
                        WorkerAgent, register_script)
from repro.core.broker import Producer
from repro.core.messages import ResultMessage, topic_names
from repro.pipeline import (PipelineAgent, PipelineError, PipelineSpec,
                            RetryPolicy, SpecError, Stage, run_campaign)


# ---------------------------------------------------------------------------
# tiny deterministic stage scripts
# ---------------------------------------------------------------------------

@register_script("pl_double")
class _Double(ClusterComputing):
    def run(self):
        return {"values": [v * 2 for v in self.params["batch"]]}


@register_script("pl_pass")
class _Pass(ClusterComputing):
    def run(self):
        up = self.params["upstream"]
        return {"values": list(up["values"]), "dep_index": self.params["dep_index"]}


@register_script("pl_sum")
class _Sum(ClusterComputing):
    def run(self):
        up = self.params["upstream"]
        total = sum(v for r in up["fwd"] for v in r["values"])
        return {"total": total, "n_src": len(up["src"]),
                "n_fwd": len(up["fwd"])}


@register_script("pl_slow")
class _Slow(ClusterComputing):
    def run(self):
        deadline = time.time() + float(self.params.get("duration", 0.1))
        while time.time() < deadline:
            self.check_cancel()
            time.sleep(0.005)
        return {"batch": list(self.params["batch"])}


def _three_stage(fan_out=3, **stage_kw) -> PipelineSpec:
    return PipelineSpec("t3", [
        Stage("src", "pl_double", fan_out=fan_out, **stage_kw),
        Stage("fwd", "pl_pass", depends_on=("src",), **stage_kw),
        Stage("agg", "pl_sum", depends_on=("src", "fwd"), join=True),
    ])


def _wait(cond, timeout=10.0, interval=0.01):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return False


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------

def test_spec_rejects_cycles_and_bad_deps():
    with pytest.raises(SpecError):
        PipelineSpec("c", [Stage("a", "pl_pass", depends_on=("b",)),
                           Stage("b", "pl_pass", depends_on=("a",))])
    with pytest.raises(SpecError):
        PipelineSpec("u", [Stage("a", "pl_double", depends_on=("ghost",))])
    with pytest.raises(SpecError):  # map stages take exactly one dependency
        PipelineSpec("m", [Stage("a", "pl_double"), Stage("b", "pl_double"),
                           Stage("c", "pl_pass", depends_on=("a", "b"))])
    with pytest.raises(SpecError):  # fan_out only on sources
        Stage("x", "pl_pass", depends_on=("a",), fan_out=4)
    with pytest.raises(SpecError):  # joins need upstream stages
        Stage("j", "pl_sum", join=True)


def test_expected_counts_source_map_join():
    spec = _three_stage(fan_out=4)
    assert spec.expected_counts(10) == {"src": 3, "fwd": 3, "agg": 1}
    assert spec.expected_counts(0) == {"src": 1, "fwd": 1, "agg": 1}
    assert [s.name for s in spec.terminals()] == ["agg"]


# ---------------------------------------------------------------------------
# end-to-end DAG execution
# ---------------------------------------------------------------------------

def test_fanout_map_join_end_to_end():
    broker = Broker(default_partitions=4)
    w = WorkerAgent(broker, "p1", slots=2, poll_interval_s=0.01).start()
    try:
        res = run_campaign(_three_stage(fan_out=3), list(range(10)),
                           broker=broker, prefix="p1", timeout_s=60.0)
        assert res.final["total"] == sum(v * 2 for v in range(10))
        assert res.final["n_src"] == 4  # ceil(10/3) fan-out batches
        st = res.status
        assert st.state == "COMPLETED"
        assert {n: s.done for n, s in st.stages.items()} == \
            {"src": 4, "fwd": 4, "agg": 1}
        assert st.stages["agg"].submitted == 1
        # every map task carries campaign metadata + its upstream dep
        assert all(len(r["values"]) > 0 for r in res.results["fwd"])
    finally:
        w.stop()
        broker.close()


def test_join_fires_exactly_once_despite_duplicate_upstream_results():
    """The barrier invariant from the ISSUE: duplicate (re-attempted)
    upstream results must not double-trigger the join. Results are driven by
    hand (no worker agents) so the interleaving is deterministic."""
    broker = Broker(default_partitions=2)
    pipe = PipelineAgent(broker, "p2", poll_interval_s=0.005).start()
    prod = Producer(broker)
    topics = topic_names("p2")
    try:
        cid = pipe.submit_campaign(_three_stage(fan_out=2), [1, 2, 3, 4],
                                   campaign_id="camp-dup")
        src0, src1 = "camp-dup-src-00000", "camp-dup-src-00001"

        def done(tid, result, attempt=0):
            prod.send(topics["done"],
                      ResultMessage(task_id=tid, agent_id="hand",
                                    result=result, attempt=attempt).to_dict(),
                      key=tid)

        done(src0, {"values": [2, 4]})
        done(src0, {"values": [2, 4]}, attempt=1)   # duplicate: late attempt
        done(src0, {"values": [999]}, attempt=2)    # duplicate with bad data
        done(src1, {"values": [6, 8]})
        # map tasks appear 1:1 as upstream completes, despite the duplicates
        assert _wait(lambda: pipe.status(cid).stages["fwd"].submitted == 2)
        assert pipe.status(cid).stages["fwd"].submitted == 2
        done("camp-dup-fwd-00000", {"values": [2, 4]})
        done("camp-dup-fwd-00000", {"values": [2, 4]}, attempt=1)  # dup
        done("camp-dup-fwd-00001", {"values": [6, 8]})
        # the join barrier fires exactly once
        assert _wait(lambda: pipe.status(cid).stages["agg"].submitted == 1)
        time.sleep(0.1)  # give a double-fire the chance to happen
        st = pipe.status(cid)
        assert st.stages["agg"].submitted == 1
        assert st.stages["src"].duplicates == 2
        assert st.stages["fwd"].duplicates == 1
        done("camp-dup-agg-00000", {"total": 20, "n_src": 2, "n_fwd": 2})
        assert _wait(lambda: pipe.status(cid).done)
        assert pipe.status(cid).state == "COMPLETED"
        # the fenced duplicate's payload never reached the join
        assert pipe.final_result(cid)["total"] == 20
        assert pipe.results(cid)["src"][0] == {"values": [2, 4]}
    finally:
        pipe.stop()
        broker.close()


def test_backpressure_bounds_in_flight_tasks():
    """max_in_flight=2 with a 4-slot worker: the stage never has more than
    two tasks outstanding, yet the campaign drains completely."""
    broker = Broker(default_partitions=4)
    spec = PipelineSpec("bp", [
        Stage("work", "pl_slow", fan_out=1, params={"duration": 0.1},
              max_in_flight=2),
    ])
    w = WorkerAgent(broker, "p3", slots=4, poll_interval_s=0.005).start()
    pipe = PipelineAgent(broker, "p3", poll_interval_s=0.005).start()
    try:
        cid = pipe.submit_campaign(spec, list(range(8)))
        seen_max = 0
        deadline = time.time() + 30.0
        while time.time() < deadline:
            st = pipe.status(cid)
            seen_max = max(seen_max, st.stages["work"].in_flight)
            if st.done:
                break
            time.sleep(0.005)
        st = pipe.status(cid)
        assert st.state == "COMPLETED"
        assert st.stages["work"].done == 8
        assert 0 < seen_max <= 2, seen_max
    finally:
        pipe.stop()
        w.stop()
        broker.close()


def test_mid_campaign_agent_kill_redelivers_and_completes():
    """Crash a worker holding an in-flight stage task: the pipeline watchdog
    resubmits after RetryPolicy.timeout_s and the survivor finishes the
    campaign (at-least-once end-to-end, duplicates fenced)."""
    broker = Broker(default_partitions=4, session_timeout_s=0.5)
    retry = RetryPolicy(max_attempts=5, timeout_s=1.0)
    spec = PipelineSpec("kill", [
        Stage("work", "pl_slow", fan_out=1, params={"duration": 0.3},
              retry=retry),
        Stage("agg", "pl_sum_batches", depends_on=("work",), join=True),
    ])
    a1 = WorkerAgent(broker, "p4", slots=1, poll_interval_s=0.01).start()
    a2 = WorkerAgent(broker, "p4", slots=1, poll_interval_s=0.01).start()
    pipe = PipelineAgent(broker, "p4", poll_interval_s=0.01).start()
    try:
        cid = pipe.submit_campaign(spec, list(range(6)))
        assert _wait(lambda: a1.stats()["in_flight"] > 0
                     or pipe.status(cid).stages["work"].done >= 2)
        a1.crash()
        st = pipe.wait(cid, timeout=60.0)
        assert st.state == "COMPLETED", st.failure
        assert st.stages["work"].done == 6
        # all six input items survived the crash (no task lost, none doubled)
        batches = sorted(v for r in pipe.results(cid)["work"]
                         for v in r["batch"])
        assert batches == list(range(6))
        assert pipe.final_result(cid)["n_batches"] == 6
    finally:
        pipe.stop()
        a1.stop()
        a2.stop()
        broker.close()


@register_script("pl_sum_batches")
class _SumBatches(ClusterComputing):
    def run(self):
        up = self.params["upstream"]
        items = [v for r in up["work"] for v in r["batch"]]
        return {"n_batches": len(up["work"]), "items": sorted(items)}


def test_error_retry_then_success():
    """A stage task that fails once is resubmitted by the pipeline's error
    handler (bounded by RetryPolicy.max_attempts) and the campaign
    completes."""
    broker = Broker(default_partitions=2)
    spec = PipelineSpec("err", [
        Stage("flaky", "fail", fan_out=None,
              params={"fail_times": 1},
              retry=RetryPolicy(max_attempts=3)),
    ])
    w = WorkerAgent(broker, "p5", slots=1, poll_interval_s=0.01).start()
    pipe = PipelineAgent(broker, "p5", poll_interval_s=0.01).start()
    try:
        cid = pipe.submit_campaign(spec, [])
        st = pipe.wait(cid, timeout=30.0)
        assert st.state == "COMPLETED", st.failure
        assert st.stages["flaky"].errors >= 1
        assert st.stages["flaky"].retried >= 1
    finally:
        pipe.stop()
        w.stop()
        broker.close()


def test_late_result_cannot_resurrect_failed_campaign():
    """A result arriving after a task exhausted its retry budget must be
    fenced: the FAILED verdict is final and no downstream (ghost) tasks are
    emitted."""
    broker = Broker(default_partitions=2)
    pipe = PipelineAgent(broker, "p8", poll_interval_s=0.005).start()
    prod = Producer(broker)
    topics = topic_names("p8")
    spec = PipelineSpec("late", [
        Stage("src", "pl_double", fan_out=4,
              retry=RetryPolicy(max_attempts=1, timeout_s=0.2)),
        Stage("fwd", "pl_pass", depends_on=("src",)),
    ])
    try:
        cid = pipe.submit_campaign(spec, [1, 2], campaign_id="camp-late")
        # no workers: the watchdog exhausts the single attempt and fails
        assert _wait(lambda: pipe.status(cid).state == "FAILED", timeout=10.0)
        # the straggler's result finally lands
        prod.send(topics["done"],
                  ResultMessage(task_id="camp-late-src-00000", agent_id="gh",
                                result={"values": [2, 4]}).to_dict(),
                  key="camp-late-src-00000")
        time.sleep(0.2)
        st = pipe.status(cid)
        assert st.state == "FAILED"
        assert st.stages["src"].done == 0
        assert st.stages["fwd"].submitted == 0  # no ghost downstream task
        assert st.stages["src"].duplicates == 1  # fenced, counted
    finally:
        pipe.stop()
        broker.close()


def test_finished_campaigns_are_evicted_beyond_retention():
    broker = Broker(default_partitions=2)
    w = WorkerAgent(broker, "p9", slots=2, poll_interval_s=0.005).start()
    pipe = PipelineAgent(broker, "p9", poll_interval_s=0.005,
                         retain_finished=2).start()
    spec = PipelineSpec("tiny", [Stage("src", "pl_double", fan_out=4)])
    try:
        cids = []
        for i in range(4):  # sequentially, so eviction order is determinate
            c = pipe.submit_campaign(spec, [i])
            assert pipe.wait(c, 30.0).done
            cids.append(c)
        assert sorted(pipe.campaigns()) == sorted(cids[-2:])
        with pytest.raises(KeyError):
            pipe.status(cids[0])  # oldest evicted
    finally:
        pipe.stop()
        w.stop()
        broker.close()


def test_retry_exhaustion_fails_campaign():
    broker = Broker(default_partitions=2)
    spec = PipelineSpec("doom", [
        Stage("hopeless", "fail", params={"fail_times": 99},
              retry=RetryPolicy(max_attempts=2)),
    ])
    w = WorkerAgent(broker, "p6", slots=1, poll_interval_s=0.01).start()
    try:
        with pytest.raises(PipelineError, match="exhausted"):
            run_campaign(spec, [], broker=broker, prefix="p6",
                         timeout_s=30.0)
    finally:
        w.stop()
        broker.close()


# ---------------------------------------------------------------------------
# knots campaign parity + /campaigns REST
# ---------------------------------------------------------------------------

def test_knots_pipeline_matches_flat_baseline():
    """The 3-stage knots campaign reports identical knot counts and cores to
    the flat single-stage submission (acceptance criterion)."""
    from repro.apps import knots
    broker = Broker(default_partitions=4)
    ids = list(range(24))
    sub = Submitter(broker, "kf")
    mon = MonitorAgent(broker, "kf", poll_interval_s=0.01).start()
    ws = [WorkerAgent(broker, "kf", slots=1, poll_interval_s=0.01).start()
          for _ in range(2)]
    try:
        tids = sub.submit_batches("knot_batch", ids, batch_size=8,
                                  params={"n_points": 64, "stage2": True})
        assert mon.wait_all(tids, timeout=240.0)
        flat_knotted, flat_cores = set(), {}
        for t in tids:
            r = mon.task(t).result
            flat_knotted.update(r["knotted"])
            flat_cores.update(r["cores"])

        spec = knots.knots_pipeline(8, n_points=64)
        res = run_campaign(spec, ids, broker=broker, prefix="kf",
                           timeout_s=240.0)
        assert res.final["knotted"] == sorted(flat_knotted)
        assert res.final["cores"] == flat_cores
        assert res.final["processed"] == len(ids)
        assert res.status.stages["screen"].done == 3
        assert res.status.stages["localize"].done == 3
    finally:
        for w in ws:
            w.stop()
        mon.stop()
        broker.close()


def test_monitor_campaigns_rest_endpoint():
    """PipelineAgent snapshots on PREFIX-campaigns surface through the
    MonitorAgent REST API (satellite: /campaigns endpoint)."""
    broker = Broker(default_partitions=2)
    mon = MonitorAgent(broker, "p7", poll_interval_s=0.01).start()
    w = WorkerAgent(broker, "p7", slots=2, poll_interval_s=0.01).start()
    try:
        res = run_campaign(_three_stage(fan_out=2), [1, 2, 3],
                           broker=broker, prefix="p7", timeout_s=60.0)
        cid = res.campaign_id
        assert _wait(lambda: mon.campaign(cid) is not None and
                     mon.campaign(cid)["state"] == "COMPLETED")
        port = mon.start_http(0)

        def get(path):
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}{path}") as r:
                return json.loads(r.read())

        camps = get("/campaigns")
        assert cid in camps
        one = get(f"/campaigns/{cid}")
        assert one["state"] == "COMPLETED"
        assert one["pipeline"] == "t3"
        stages = one["stages"]
        assert stages["src"]["done"] == stages["src"]["expected"] == 2
        assert stages["agg"]["done"] == 1
        assert stages["agg"]["in_flight"] == 0
        assert get("/summary")["campaigns"] >= 1
    finally:
        w.stop()
        mon.stop()
        broker.close()


def test_serve_pipeline_spec_shape():
    """The serving DAG wires serve_request as a map stage between tokenize
    fan-out and the post-process join (workload-agnostic subsystem)."""
    from repro.serve import serve_pipeline
    spec = serve_pipeline(batch_size=4)
    names = [s.name for s in spec.topological()]
    assert names == ["tokenize", "generate", "postprocess"]
    assert spec.stages["generate"].script == "serve_request"
    assert spec.stages["generate"].max_in_flight == 1
    assert spec.stages["postprocess"].join
    assert spec.expected_counts(10) == \
        {"tokenize": 3, "generate": 3, "postprocess": 1}


# ---------------------------------------------------------------------------
# conditional edges / early exit (skip_when)
# ---------------------------------------------------------------------------

def test_skip_when_short_circuits_map_tasks_and_completes():
    """Map tasks whose upstream result matches skip_when are never submitted;
    the join still fires (with only live results) and the campaign finishes
    COMPLETED, not FAILED."""
    spec = PipelineSpec("cond", [
        Stage("src", "pl_double", fan_out=1),
        Stage("fwd", "pl_pass", depends_on=("src",),
              skip_when=lambda r: r["values"][0] % 4 == 0),  # skip 0, 2
        Stage("agg", "pl_sum", depends_on=("src", "fwd"), join=True),
    ])
    broker = Broker(default_partitions=2)
    w = WorkerAgent(broker, "sk1", slots=2, poll_interval_s=0.005).start()
    try:
        res = run_campaign(spec, [0, 1, 2, 3], broker=broker, prefix="sk1",
                           timeout_s=60.0)
        st = res.status
        assert st.state == "COMPLETED"
        assert st.stages["fwd"].skipped == 2
        assert st.stages["fwd"].done == 2
        assert st.stages["fwd"].submitted == 2  # skipped ones never submitted
        # the join only saw the two live fwd results (items 1 and 3 doubled)
        assert res.final["n_fwd"] == 2
        assert res.final["total"] == 2 + 6
        assert res.final["n_src"] == 4
    finally:
        w.stop()
        broker.close()


def test_skip_all_upstream_still_fires_join_and_finishes():
    """Every map task skipped (the 'no screen survivors' scenario): the
    barrier fires with an empty result list and the campaign completes."""
    spec = PipelineSpec("cond2", [
        Stage("src", "pl_double", fan_out=2),
        Stage("fwd", "pl_pass", depends_on=("src",),
              skip_when=lambda r: True),
        Stage("agg", "pl_sum", depends_on=("src", "fwd"), join=True),
    ])
    broker = Broker(default_partitions=2)
    w = WorkerAgent(broker, "sk2", slots=2, poll_interval_s=0.005).start()
    try:
        res = run_campaign(spec, [1, 2, 3, 4], broker=broker, prefix="sk2",
                           timeout_s=60.0)
        st = res.status
        assert st.state == "COMPLETED"
        assert st.stages["fwd"].skipped == 2
        assert st.stages["fwd"].done == 0
        assert st.stages["fwd"].submitted == 0
        assert res.final["n_fwd"] == 0 and res.final["total"] == 0
    finally:
        w.stop()
        broker.close()


def test_skip_when_on_join_skips_terminal_stage():
    """A join's skip_when sees the assembled upstream dict; a skipped
    terminal barrier still completes the campaign (early exit)."""
    spec = PipelineSpec("cond3", [
        Stage("src", "pl_double", fan_out=2),
        Stage("agg", "pl_sum_batches", depends_on=("src",), join=True,
              skip_when=lambda up: len(up["src"]) < 99),  # always skip
    ])
    broker = Broker(default_partitions=2)
    w = WorkerAgent(broker, "sk3", slots=2, poll_interval_s=0.005).start()
    pipe = PipelineAgent(broker, "sk3", poll_interval_s=0.005).start()
    try:
        cid = pipe.submit_campaign(spec, [1, 2, 3])
        st = pipe.wait(cid, timeout=30.0)
        assert st.state == "COMPLETED", st.failure
        assert st.stages["agg"].skipped == 1
        assert st.stages["agg"].submitted == 0
        assert pipe.final_result(cid) is None  # skipped terminal: no result
    finally:
        pipe.stop()
        w.stop()
        broker.close()


def test_knots_pipeline_skips_localize_without_survivors():
    """The ROADMAP's early-exit example end to end: a campaign of unknotted
    coils produces zero screen survivors, so every localize task is skipped
    and the campaign is finished, not failed."""
    from repro.apps import knots
    broker = Broker(default_partitions=2)
    w = WorkerAgent(broker, "sk4", slots=2, poll_interval_s=0.01).start()
    try:
        # ids ≡ 1 (mod 4) synthesize unknotted random coils
        ids = [1, 5, 9, 13]
        spec = knots.knots_pipeline(2, n_points=48)
        res = run_campaign(spec, ids, broker=broker, prefix="sk4",
                           timeout_s=240.0)
        st = res.status
        assert st.state == "COMPLETED"
        assert st.stages["localize"].skipped == 2
        assert st.stages["localize"].submitted == 0
        assert res.final["knotted"] == [] and res.final["cores"] == {}
        assert res.final["processed"] == len(ids)
    finally:
        w.stop()
        broker.close()
