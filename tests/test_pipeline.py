"""Pipeline semantics: spec validation, fan-out → map → join execution,
duplicate-result fencing at the barrier, backpressure, watchdog recovery
from a mid-campaign agent kill, the /campaigns REST mirror, and the
event-sourced durability contract — journal replay idempotence, truncated
tails, evicted campaigns, journaled retry budgets, and orchestrator-kill
crash recovery via PipelineAgent.recover()."""
import dataclasses
import json
import time
import urllib.request

import pytest

from repro.core import (Broker, ClusterComputing, MonitorAgent, Submitter,
                        WorkerAgent, register_script)
from repro.core.broker import Producer
from repro.core.messages import ResultMessage, topic_names
from repro.pipeline import (BarrierReleased, CampaignState, CampaignSubmitted,
                            LeaseGranted, PipelineAgent, PipelineError,
                            PipelineSpec, RetryPolicy, SpecError, Stage,
                            StageDispatched, TaskDone, run_campaign)
from repro.pipeline.state import group_journal


# ---------------------------------------------------------------------------
# tiny deterministic stage scripts
# ---------------------------------------------------------------------------

@register_script("pl_double")
class _Double(ClusterComputing):
    def run(self):
        return {"values": [v * 2 for v in self.params["batch"]]}


@register_script("pl_pass")
class _Pass(ClusterComputing):
    def run(self):
        up = self.params["upstream"]
        return {"values": list(up["values"]), "dep_index": self.params["dep_index"]}


@register_script("pl_sum")
class _Sum(ClusterComputing):
    def run(self):
        up = self.params["upstream"]
        total = sum(v for r in up["fwd"] for v in r["values"])
        return {"total": total, "n_src": len(up["src"]),
                "n_fwd": len(up["fwd"])}


@register_script("pl_slow")
class _Slow(ClusterComputing):
    def run(self):
        deadline = time.time() + float(self.params.get("duration", 0.1))
        while time.time() < deadline:
            self.check_cancel()
            time.sleep(0.005)
        return {"batch": list(self.params["batch"])}


def _three_stage(fan_out=3, **stage_kw) -> PipelineSpec:
    return PipelineSpec("t3", [
        Stage("src", "pl_double", fan_out=fan_out, **stage_kw),
        Stage("fwd", "pl_pass", depends_on=("src",), **stage_kw),
        Stage("agg", "pl_sum", depends_on=("src", "fwd"), join=True),
    ])


def _wait(cond, timeout=10.0, interval=0.01):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return False


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------

def test_spec_rejects_cycles_and_bad_deps():
    with pytest.raises(SpecError):
        PipelineSpec("c", [Stage("a", "pl_pass", depends_on=("b",)),
                           Stage("b", "pl_pass", depends_on=("a",))])
    with pytest.raises(SpecError):
        PipelineSpec("u", [Stage("a", "pl_double", depends_on=("ghost",))])
    with pytest.raises(SpecError):  # map stages take exactly one dependency
        PipelineSpec("m", [Stage("a", "pl_double"), Stage("b", "pl_double"),
                           Stage("c", "pl_pass", depends_on=("a", "b"))])
    with pytest.raises(SpecError):  # fan_out only on sources
        Stage("x", "pl_pass", depends_on=("a",), fan_out=4)
    with pytest.raises(SpecError):  # joins need upstream stages
        Stage("j", "pl_sum", join=True)


def test_expected_counts_source_map_join():
    spec = _three_stage(fan_out=4)
    assert spec.expected_counts(10) == {"src": 3, "fwd": 3, "agg": 1}
    assert spec.expected_counts(0) == {"src": 1, "fwd": 1, "agg": 1}
    assert [s.name for s in spec.terminals()] == ["agg"]


# ---------------------------------------------------------------------------
# end-to-end DAG execution
# ---------------------------------------------------------------------------

def test_fanout_map_join_end_to_end():
    broker = Broker(default_partitions=4)
    w = WorkerAgent(broker, "p1", slots=2, poll_interval_s=0.01).start()
    try:
        res = run_campaign(_three_stage(fan_out=3), list(range(10)),
                           broker=broker, prefix="p1", timeout_s=60.0)
        assert res.final["total"] == sum(v * 2 for v in range(10))
        assert res.final["n_src"] == 4  # ceil(10/3) fan-out batches
        st = res.status
        assert st.state == "COMPLETED"
        assert {n: s.done for n, s in st.stages.items()} == \
            {"src": 4, "fwd": 4, "agg": 1}
        assert st.stages["agg"].submitted == 1
        # every map task carries campaign metadata + its upstream dep
        assert all(len(r["values"]) > 0 for r in res.results["fwd"])
    finally:
        w.stop()
        broker.close()


def test_join_fires_exactly_once_despite_duplicate_upstream_results():
    """The barrier invariant from the ISSUE: duplicate (re-attempted)
    upstream results must not double-trigger the join. Results are driven by
    hand (no worker agents) so the interleaving is deterministic."""
    broker = Broker(default_partitions=2)
    pipe = PipelineAgent(broker, "p2", poll_interval_s=0.005).start()
    prod = Producer(broker)
    topics = topic_names("p2")
    try:
        cid = pipe.submit_campaign(_three_stage(fan_out=2), [1, 2, 3, 4],
                                   campaign_id="camp-dup")
        src0, src1 = "camp-dup-src-00000", "camp-dup-src-00001"

        def done(tid, result, attempt=0):
            prod.send(topics["done"],
                      ResultMessage(task_id=tid, agent_id="hand",
                                    result=result, attempt=attempt).to_dict(),
                      key=tid)

        done(src0, {"values": [2, 4]})
        done(src0, {"values": [2, 4]}, attempt=1)   # duplicate: late attempt
        done(src0, {"values": [999]}, attempt=2)    # duplicate with bad data
        done(src1, {"values": [6, 8]})
        # map tasks appear 1:1 as upstream completes, despite the duplicates
        assert _wait(lambda: pipe.status(cid).stages["fwd"].submitted == 2)
        assert pipe.status(cid).stages["fwd"].submitted == 2
        done("camp-dup-fwd-00000", {"values": [2, 4]})
        done("camp-dup-fwd-00000", {"values": [2, 4]}, attempt=1)  # dup
        done("camp-dup-fwd-00001", {"values": [6, 8]})
        # the join barrier fires exactly once
        assert _wait(lambda: pipe.status(cid).stages["agg"].submitted == 1)
        time.sleep(0.1)  # give a double-fire the chance to happen
        st = pipe.status(cid)
        assert st.stages["agg"].submitted == 1
        assert st.stages["src"].duplicates == 2
        assert st.stages["fwd"].duplicates == 1
        done("camp-dup-agg-00000", {"total": 20, "n_src": 2, "n_fwd": 2})
        assert _wait(lambda: pipe.status(cid).done)
        assert pipe.status(cid).state == "COMPLETED"
        # the fenced duplicate's payload never reached the join
        assert pipe.final_result(cid)["total"] == 20
        assert pipe.results(cid)["src"][0] == {"values": [2, 4]}
    finally:
        pipe.stop()
        broker.close()


def test_backpressure_bounds_in_flight_tasks():
    """max_in_flight=2 with a 4-slot worker: the stage never has more than
    two tasks outstanding, yet the campaign drains completely."""
    broker = Broker(default_partitions=4)
    spec = PipelineSpec("bp", [
        Stage("work", "pl_slow", fan_out=1, params={"duration": 0.1},
              max_in_flight=2),
    ])
    w = WorkerAgent(broker, "p3", slots=4, poll_interval_s=0.005).start()
    pipe = PipelineAgent(broker, "p3", poll_interval_s=0.005).start()
    try:
        cid = pipe.submit_campaign(spec, list(range(8)))
        seen_max = 0
        deadline = time.time() + 30.0
        while time.time() < deadline:
            st = pipe.status(cid)
            seen_max = max(seen_max, st.stages["work"].in_flight)
            if st.done:
                break
            time.sleep(0.005)
        st = pipe.status(cid)
        assert st.state == "COMPLETED"
        assert st.stages["work"].done == 8
        assert 0 < seen_max <= 2, seen_max
    finally:
        pipe.stop()
        w.stop()
        broker.close()


def test_mid_campaign_agent_kill_redelivers_and_completes():
    """Crash a worker holding an in-flight stage task: the pipeline watchdog
    resubmits after RetryPolicy.timeout_s and the survivor finishes the
    campaign (at-least-once end-to-end, duplicates fenced)."""
    broker = Broker(default_partitions=4, session_timeout_s=0.5)
    retry = RetryPolicy(max_attempts=5, timeout_s=1.0)
    spec = PipelineSpec("kill", [
        Stage("work", "pl_slow", fan_out=1, params={"duration": 0.3},
              retry=retry),
        Stage("agg", "pl_sum_batches", depends_on=("work",), join=True),
    ])
    a1 = WorkerAgent(broker, "p4", slots=1, poll_interval_s=0.01).start()
    a2 = WorkerAgent(broker, "p4", slots=1, poll_interval_s=0.01).start()
    pipe = PipelineAgent(broker, "p4", poll_interval_s=0.01).start()
    try:
        cid = pipe.submit_campaign(spec, list(range(6)))
        assert _wait(lambda: a1.stats()["in_flight"] > 0
                     or pipe.status(cid).stages["work"].done >= 2)
        a1.crash()
        st = pipe.wait(cid, timeout=60.0)
        assert st.state == "COMPLETED", st.failure
        assert st.stages["work"].done == 6
        # all six input items survived the crash (no task lost, none doubled)
        batches = sorted(v for r in pipe.results(cid)["work"]
                         for v in r["batch"])
        assert batches == list(range(6))
        assert pipe.final_result(cid)["n_batches"] == 6
    finally:
        pipe.stop()
        a1.stop()
        a2.stop()
        broker.close()


@register_script("pl_sum_batches")
class _SumBatches(ClusterComputing):
    def run(self):
        up = self.params["upstream"]
        items = [v for r in up["work"] for v in r["batch"]]
        return {"n_batches": len(up["work"]), "items": sorted(items)}


def test_error_retry_then_success():
    """A stage task that fails once is resubmitted by the pipeline's error
    handler (bounded by RetryPolicy.max_attempts) and the campaign
    completes."""
    broker = Broker(default_partitions=2)
    spec = PipelineSpec("err", [
        Stage("flaky", "fail", fan_out=None,
              params={"fail_times": 1},
              retry=RetryPolicy(max_attempts=3)),
    ])
    w = WorkerAgent(broker, "p5", slots=1, poll_interval_s=0.01).start()
    pipe = PipelineAgent(broker, "p5", poll_interval_s=0.01).start()
    try:
        cid = pipe.submit_campaign(spec, [])
        st = pipe.wait(cid, timeout=30.0)
        assert st.state == "COMPLETED", st.failure
        assert st.stages["flaky"].errors >= 1
        assert st.stages["flaky"].retried >= 1
    finally:
        pipe.stop()
        w.stop()
        broker.close()


def test_late_result_cannot_resurrect_failed_campaign():
    """A result arriving after a task exhausted its retry budget must be
    fenced: the FAILED verdict is final and no downstream (ghost) tasks are
    emitted."""
    broker = Broker(default_partitions=2)
    pipe = PipelineAgent(broker, "p8", poll_interval_s=0.005).start()
    prod = Producer(broker)
    topics = topic_names("p8")
    spec = PipelineSpec("late", [
        Stage("src", "pl_double", fan_out=4,
              retry=RetryPolicy(max_attempts=1, timeout_s=0.2)),
        Stage("fwd", "pl_pass", depends_on=("src",)),
    ])
    try:
        cid = pipe.submit_campaign(spec, [1, 2], campaign_id="camp-late")
        # no workers: the watchdog exhausts the single attempt and fails
        assert _wait(lambda: pipe.status(cid).state == "FAILED", timeout=10.0)
        # the straggler's result finally lands
        prod.send(topics["done"],
                  ResultMessage(task_id="camp-late-src-00000", agent_id="gh",
                                result={"values": [2, 4]}).to_dict(),
                  key="camp-late-src-00000")
        time.sleep(0.2)
        st = pipe.status(cid)
        assert st.state == "FAILED"
        assert st.stages["src"].done == 0
        assert st.stages["fwd"].submitted == 0  # no ghost downstream task
        assert st.stages["src"].duplicates == 1  # fenced, counted
    finally:
        pipe.stop()
        broker.close()


def test_finished_campaigns_are_evicted_beyond_retention():
    broker = Broker(default_partitions=2)
    w = WorkerAgent(broker, "p9", slots=2, poll_interval_s=0.005).start()
    pipe = PipelineAgent(broker, "p9", poll_interval_s=0.005,
                         retain_finished=2).start()
    spec = PipelineSpec("tiny", [Stage("src", "pl_double", fan_out=4)])
    try:
        cids = []
        for i in range(4):  # sequentially, so eviction order is determinate
            c = pipe.submit_campaign(spec, [i])
            assert pipe.wait(c, 30.0).done
            cids.append(c)
        assert sorted(pipe.campaigns()) == sorted(cids[-2:])
        with pytest.raises(KeyError):
            pipe.status(cids[0])  # oldest evicted
    finally:
        pipe.stop()
        w.stop()
        broker.close()


def test_retry_exhaustion_fails_campaign():
    broker = Broker(default_partitions=2)
    spec = PipelineSpec("doom", [
        Stage("hopeless", "fail", params={"fail_times": 99},
              retry=RetryPolicy(max_attempts=2)),
    ])
    w = WorkerAgent(broker, "p6", slots=1, poll_interval_s=0.01).start()
    try:
        with pytest.raises(PipelineError, match="exhausted"):
            run_campaign(spec, [], broker=broker, prefix="p6",
                         timeout_s=30.0)
    finally:
        w.stop()
        broker.close()


# ---------------------------------------------------------------------------
# knots campaign parity + /campaigns REST
# ---------------------------------------------------------------------------

def test_knots_pipeline_matches_flat_baseline():
    """The 3-stage knots campaign reports identical knot counts and cores to
    the flat single-stage submission (acceptance criterion)."""
    from repro.apps import knots
    broker = Broker(default_partitions=4)
    ids = list(range(24))
    sub = Submitter(broker, "kf")
    mon = MonitorAgent(broker, "kf", poll_interval_s=0.01).start()
    ws = [WorkerAgent(broker, "kf", slots=1, poll_interval_s=0.01).start()
          for _ in range(2)]
    try:
        tids = sub.submit_batches("knot_batch", ids, batch_size=8,
                                  params={"n_points": 64, "stage2": True})
        assert mon.wait_all(tids, timeout=240.0)
        flat_knotted, flat_cores = set(), {}
        for t in tids:
            r = mon.task(t).result
            flat_knotted.update(r["knotted"])
            flat_cores.update(r["cores"])

        spec = knots.knots_pipeline(8, n_points=64)
        res = run_campaign(spec, ids, broker=broker, prefix="kf",
                           timeout_s=240.0)
        assert res.final["knotted"] == sorted(flat_knotted)
        assert res.final["cores"] == flat_cores
        assert res.final["processed"] == len(ids)
        assert res.status.stages["screen"].done == 3
        assert res.status.stages["localize"].done == 3
    finally:
        for w in ws:
            w.stop()
        mon.stop()
        broker.close()


def test_monitor_campaigns_rest_endpoint():
    """PipelineAgent snapshots on PREFIX-campaigns surface through the
    MonitorAgent REST API (satellite: /campaigns endpoint)."""
    broker = Broker(default_partitions=2)
    mon = MonitorAgent(broker, "p7", poll_interval_s=0.01).start()
    w = WorkerAgent(broker, "p7", slots=2, poll_interval_s=0.01).start()
    try:
        res = run_campaign(_three_stage(fan_out=2), [1, 2, 3],
                           broker=broker, prefix="p7", timeout_s=60.0)
        cid = res.campaign_id
        assert _wait(lambda: mon.campaign(cid) is not None and
                     mon.campaign(cid)["state"] == "COMPLETED")
        port = mon.start_http(0)

        def get(path):
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}{path}") as r:
                return json.loads(r.read())

        camps = get("/campaigns")
        assert cid in camps
        one = get(f"/campaigns/{cid}")
        assert one["state"] == "COMPLETED"
        assert one["pipeline"] == "t3"
        stages = one["stages"]
        assert stages["src"]["done"] == stages["src"]["expected"] == 2
        assert stages["agg"]["done"] == 1
        assert stages["agg"]["in_flight"] == 0
        # recovery status: the write-ahead journal is tallied per campaign
        assert one["journal"]["events"] > 5
        assert one["journal"]["last_seq"] == one["journal"]["events"] - 1
        assert one["recovered"] is False
        summary = get("/summary")
        assert summary["campaigns"] >= 1
        assert summary["journal_events"] >= one["journal"]["events"]
    finally:
        w.stop()
        mon.stop()
        broker.close()


def test_serve_pipeline_spec_shape():
    """The serving DAG wires serve_request as a map stage between tokenize
    fan-out and the post-process join (workload-agnostic subsystem)."""
    from repro.serve import serve_pipeline
    spec = serve_pipeline(batch_size=4)
    names = [s.name for s in spec.topological()]
    assert names == ["tokenize", "generate", "postprocess"]
    assert spec.stages["generate"].script == "serve_request"
    assert spec.stages["generate"].max_in_flight == 1
    assert spec.stages["postprocess"].join
    assert spec.expected_counts(10) == \
        {"tokenize": 3, "generate": 3, "postprocess": 1}


# ---------------------------------------------------------------------------
# conditional edges / early exit (skip_when)
# ---------------------------------------------------------------------------

def test_skip_when_short_circuits_map_tasks_and_completes():
    """Map tasks whose upstream result matches skip_when are never submitted;
    the join still fires (with only live results) and the campaign finishes
    COMPLETED, not FAILED."""
    spec = PipelineSpec("cond", [
        Stage("src", "pl_double", fan_out=1),
        Stage("fwd", "pl_pass", depends_on=("src",),
              skip_when=lambda r: r["values"][0] % 4 == 0),  # skip 0, 2
        Stage("agg", "pl_sum", depends_on=("src", "fwd"), join=True),
    ])
    broker = Broker(default_partitions=2)
    w = WorkerAgent(broker, "sk1", slots=2, poll_interval_s=0.005).start()
    try:
        res = run_campaign(spec, [0, 1, 2, 3], broker=broker, prefix="sk1",
                           timeout_s=60.0)
        st = res.status
        assert st.state == "COMPLETED"
        assert st.stages["fwd"].skipped == 2
        assert st.stages["fwd"].done == 2
        assert st.stages["fwd"].submitted == 2  # skipped ones never submitted
        # the join only saw the two live fwd results (items 1 and 3 doubled)
        assert res.final["n_fwd"] == 2
        assert res.final["total"] == 2 + 6
        assert res.final["n_src"] == 4
    finally:
        w.stop()
        broker.close()


def test_skip_all_upstream_still_fires_join_and_finishes():
    """Every map task skipped (the 'no screen survivors' scenario): the
    barrier fires with an empty result list and the campaign completes."""
    spec = PipelineSpec("cond2", [
        Stage("src", "pl_double", fan_out=2),
        Stage("fwd", "pl_pass", depends_on=("src",),
              skip_when=lambda r: True),
        Stage("agg", "pl_sum", depends_on=("src", "fwd"), join=True),
    ])
    broker = Broker(default_partitions=2)
    w = WorkerAgent(broker, "sk2", slots=2, poll_interval_s=0.005).start()
    try:
        res = run_campaign(spec, [1, 2, 3, 4], broker=broker, prefix="sk2",
                           timeout_s=60.0)
        st = res.status
        assert st.state == "COMPLETED"
        assert st.stages["fwd"].skipped == 2
        assert st.stages["fwd"].done == 0
        assert st.stages["fwd"].submitted == 0
        assert res.final["n_fwd"] == 0 and res.final["total"] == 0
    finally:
        w.stop()
        broker.close()


def test_skip_when_on_join_skips_terminal_stage():
    """A join's skip_when sees the assembled upstream dict; a skipped
    terminal barrier still completes the campaign (early exit)."""
    spec = PipelineSpec("cond3", [
        Stage("src", "pl_double", fan_out=2),
        Stage("agg", "pl_sum_batches", depends_on=("src",), join=True,
              skip_when=lambda up: len(up["src"]) < 99),  # always skip
    ])
    broker = Broker(default_partitions=2)
    w = WorkerAgent(broker, "sk3", slots=2, poll_interval_s=0.005).start()
    pipe = PipelineAgent(broker, "sk3", poll_interval_s=0.005).start()
    try:
        cid = pipe.submit_campaign(spec, [1, 2, 3])
        st = pipe.wait(cid, timeout=30.0)
        assert st.state == "COMPLETED", st.failure
        assert st.stages["agg"].skipped == 1
        assert st.stages["agg"].submitted == 0
        assert pipe.final_result(cid) is None  # skipped terminal: no result
    finally:
        pipe.stop()
        w.stop()
        broker.close()


# ---------------------------------------------------------------------------
# event-sourced durability: journal replay + crash recovery
# ---------------------------------------------------------------------------

def _produce_journal(broker, prefix, events):
    """Hand-write a campaign journal (seq-stamped) onto PREFIX-campaigns —
    simulates what a now-dead orchestrator left behind."""
    prod = Producer(broker)
    topics = topic_names(prefix)
    for i, ev in enumerate(events):
        ev = dataclasses.replace(ev, seq=i, ts=time.time())
        prod.send(topics["campaigns"], ev.to_dict(), key=ev.campaign_id)


def _read_journal(broker, prefix, campaign_id):
    topics = topic_names(prefix)
    records = [r.value for r in broker.read_from(topics["campaigns"])]
    return group_journal(records).get(campaign_id, [])


def test_orchestrator_kill_recovery_resumes_knot_campaign():
    """ISSUE acceptance: kill -9 the orchestrator mid-campaign; a fresh
    pipeline agent folds the journal via recover() and resumes the knots
    campaign to COMPLETED with knot-count parity vs an uninterrupted run and
    zero duplicate terminal-stage executions."""
    from repro.apps import knots
    broker = Broker(default_partitions=2)
    ids = list(range(24))
    spec = knots.knots_pipeline(4, n_points=64)
    try:
        # uninterrupted baseline on its own prefix (same broker — the broker
        # is the shared infrastructure that survives, like the paper's Kafka)
        wb = [WorkerAgent(broker, "rcb", slots=1, poll_interval_s=0.01).start()
              for _ in range(2)]
        base = run_campaign(spec, ids, broker=broker, prefix="rcb",
                            timeout_s=240.0).final
        for w in wb:
            w.stop()

        ws = [WorkerAgent(broker, "rca", slots=1, poll_interval_s=0.01).start()
              for _ in range(2)]
        pipe1 = PipelineAgent(broker, "rca", poll_interval_s=0.01).start()
        cid = pipe1.submit_campaign(spec, ids, campaign_id="camp-rec")
        # crash while screen tasks are mid-flight, long before the terminal
        # aggregate barrier exists
        assert _wait(lambda: pipe1.status(cid).stages["screen"].done >= 1,
                     timeout=120.0)
        pipe1.crash()

        pipe2 = PipelineAgent(broker, "rca", agent_id="recovery",
                              poll_interval_s=0.01).start()
        assert pipe2.recover([spec]) == [cid]
        st = pipe2.wait(cid, timeout=240.0)
        assert st.state == "COMPLETED", st.failure
        # knot-count parity with the uninterrupted baseline
        final = pipe2.final_result(cid)
        assert final["knotted"] == base["knotted"]
        assert final["cores"] == base["cores"]
        assert final["processed"] == len(ids)
        # zero duplicate terminal-stage executions: the aggregate barrier
        # was planned, submitted, and executed exactly once
        agg = st.stages["aggregate"]
        assert agg.submitted == 1 and agg.done == 1
        assert agg.retried == 0 and agg.duplicates == 0
        pipe2.stop()
        for w in ws:
            w.stop()
    finally:
        broker.close()


def test_reducer_fold_is_idempotent_under_duplicate_suffix():
    """fold(events) == fold(events + dup_suffix): at-least-once journal
    delivery (or a replayed tail) must not change the folded state."""
    broker = Broker(default_partitions=2)
    w = WorkerAgent(broker, "ri", slots=2, poll_interval_s=0.005).start()
    spec = _three_stage(fan_out=2)
    try:
        res = run_campaign(spec, [1, 2, 3, 4], broker=broker, prefix="ri",
                           timeout_s=60.0)
        events = _read_journal(broker, "ri", res.campaign_id)
        assert len(events) > 10  # submitted + dispatched + leases + dones
        st1 = CampaignState.fold(spec, res.campaign_id, events)
        st2 = CampaignState.fold(spec, res.campaign_id,
                                 events + events[-5:] + [events[3]])
        assert st1 == st2
        assert st1.state == "COMPLETED"
        assert st1.stages["agg"].done == 1
        # group_journal itself dedupes repeated records (at-least-once reads)
        doubled = [e.to_dict() for e in events] * 2
        assert group_journal(doubled)[res.campaign_id] == events
    finally:
        w.stop()
        broker.close()


def test_recovery_repairs_truncated_journal_tail():
    """A crash between journal writes: TaskDone persisted but its downstream
    StageDispatched lost. The repair pass re-plans the gap from the pure
    planners and the campaign still completes."""
    broker = Broker(default_partitions=2)
    spec = PipelineSpec("tr", [
        Stage("src", "pl_double"),
        Stage("fwd", "pl_pass", depends_on=("src",)),
    ])
    cid, src = "camp-trunc", "camp-trunc-src-00000"
    _produce_journal(broker, "tr", [
        CampaignSubmitted(campaign_id=cid, pipeline="tr", items=(1, 2),
                          params={}, weight=1.0),
        StageDispatched(campaign_id=cid, stage="src", task_id=src, index=0,
                        params={"batch": [1, 2], "batch_index": 0}),
        LeaseGranted(campaign_id=cid, task_id=src, attempt=0),
        TaskDone(campaign_id=cid, task_id=src, result={"values": [2, 4]}),
        # truncated here: the fwd StageDispatched never made it out
    ])
    w = WorkerAgent(broker, "tr", slots=1, poll_interval_s=0.005).start()
    pipe = PipelineAgent(broker, "tr", poll_interval_s=0.005).start()
    try:
        assert pipe.recover([spec]) == [cid]
        st = pipe.wait(cid, timeout=30.0)
        assert st.state == "COMPLETED", st.failure
        assert st.stages["src"].done == 1  # replayed, not re-executed
        assert st.stages["fwd"].done == 1  # repaired + executed
        assert pipe.results(cid)["fwd"][0]["values"] == [2, 4]
    finally:
        pipe.stop()
        w.stop()
        broker.close()


def test_recovery_repairs_torn_barrier_release():
    """The other torn-write shape: BarrierReleased journaled but the join
    task's StageDispatched lost. The repair pass must re-plan the join task
    (without double-firing the barrier) instead of hanging at RUNNING."""
    broker = Broker(default_partitions=2)
    spec = PipelineSpec("tb", [
        Stage("work", "pl_double"),
        Stage("agg", "pl_sum_batches", depends_on=("work",), join=True),
    ])
    cid, src = "camp-torn", "camp-torn-work-00000"
    _produce_journal(broker, "tb", [
        CampaignSubmitted(campaign_id=cid, pipeline="tb", items=(1, 2),
                          params={}, weight=1.0),
        StageDispatched(campaign_id=cid, stage="work", task_id=src, index=0,
                        params={"batch": [1, 2], "batch_index": 0}),
        LeaseGranted(campaign_id=cid, task_id=src, attempt=0),
        TaskDone(campaign_id=cid, task_id=src, result={"batch": [1, 2]}),
        BarrierReleased(campaign_id=cid, stage="agg"),
        # torn here: the agg StageDispatched never hit the journal
    ])
    w = WorkerAgent(broker, "tb", slots=1, poll_interval_s=0.005).start()
    pipe = PipelineAgent(broker, "tb", poll_interval_s=0.005).start()
    try:
        assert pipe.recover([spec]) == [cid]
        st = pipe.wait(cid, timeout=30.0)
        assert st.state == "COMPLETED", st.failure
        assert st.stages["agg"].submitted == 1  # fired exactly once
        assert pipe.final_result(cid)["n_batches"] == 1
    finally:
        pipe.stop()
        w.stop()
        broker.close()


def test_recovery_absorbs_results_produced_while_down():
    """A worker finished a task while no orchestrator was alive AND the task
    had already spent its whole retry budget: recovery must absorb the
    success from `-done` (never re-execute or fail it), even though the
    agent's consumer loop may have drained the record before the campaign
    was registered."""
    broker = Broker(default_partitions=2)
    spec = PipelineSpec("ab", [
        Stage("w", "pl_slow", params={"duration": 9.0},
              retry=RetryPolicy(max_attempts=2, timeout_s=0.5)),
    ])
    cid, tid = "camp-absorb", "camp-absorb-w-00000"
    _produce_journal(broker, "ab", [
        CampaignSubmitted(campaign_id=cid, pipeline="ab", items=(1,),
                          params={}, weight=1.0),
        StageDispatched(campaign_id=cid, stage="w", task_id=tid, index=0,
                        params={"batch": [1], "batch_index": 0}),
        LeaseGranted(campaign_id=cid, task_id=tid, attempt=0),
        LeaseGranted(campaign_id=cid, task_id=tid, attempt=1),  # budget gone
    ])
    # ...and the last attempt actually succeeded during the outage:
    topics = topic_names("ab")
    Producer(broker).send(
        topics["done"],
        ResultMessage(task_id=tid, agent_id="survivor", attempt=1,
                      result={"batch": [1]}).to_dict(), key=tid)
    pipe = PipelineAgent(broker, "ab", poll_interval_s=0.005).start()
    try:
        time.sleep(0.1)  # let the loop drain -done before recover registers
        assert pipe.recover([spec]) == [cid]
        st = pipe.status(cid)
        assert st.state == "COMPLETED", st.failure
        assert st.stages["w"].done == 1
        # nothing was resubmitted: no task message ever hit the class topic
        assert broker.read_from("ab-new.cpu") == []
    finally:
        pipe.stop()
        broker.close()


def test_recovery_skips_evicted_finished_campaign():
    """Journal events for a campaign the agent already evicted
    (retain_finished): recover() must not resurrect it by default, but
    include_finished=True rebuilds it for result re-reads."""
    broker = Broker(default_partitions=2)
    w = WorkerAgent(broker, "ev", slots=2, poll_interval_s=0.005).start()
    spec = PipelineSpec("tiny", [Stage("src", "pl_double", fan_out=4)])
    pipe = PipelineAgent(broker, "ev", poll_interval_s=0.005,
                         retain_finished=0).start()
    try:
        cid = pipe.submit_campaign(spec, [1, 2, 3])
        assert _wait(lambda: cid not in pipe.campaigns(), timeout=30.0)
        # the journal outlives the eviction...
        assert len(_read_journal(broker, "ev", cid)) > 0
        # ...but a finished campaign is not resurrected by default
        rec = PipelineAgent(broker, "ev", agent_id="ev-rec",
                            poll_interval_s=0.005).start()
        assert rec.recover([spec]) == []
        assert rec.recover([spec], include_finished=True) == [cid]
        st = rec.status(cid)
        assert st.state == "COMPLETED"
        assert rec.results(cid)["src"][0]["values"] == [2, 4, 6]
        # none of its (terminal) tasks were resubmitted
        assert st.stages["src"].retried == 0
        rec.stop()
    finally:
        pipe.stop()
        w.stop()
        broker.close()


def test_recovery_preserves_replayed_retry_budget():
    """Satellite fix: attempts journaled before the crash count against the
    RetryPolicy budget after recovery — the watchdog must not grant a fresh
    budget to a recovering campaign."""
    broker = Broker(default_partitions=2)
    spec = PipelineSpec("rb", [
        Stage("w", "pl_slow", params={"duration": 9.0},
              retry=RetryPolicy(max_attempts=3, timeout_s=0.3)),
    ])
    cid, tid = "camp-budget", "camp-budget-w-00000"
    # the dead orchestrator had already spent two of the three attempts
    _produce_journal(broker, "rb", [
        CampaignSubmitted(campaign_id=cid, pipeline="rb", items=(1,),
                          params={}, weight=1.0),
        StageDispatched(campaign_id=cid, stage="w", task_id=tid, index=0,
                        params={"batch": [1], "batch_index": 0}),
        LeaseGranted(campaign_id=cid, task_id=tid, attempt=0),
        LeaseGranted(campaign_id=cid, task_id=tid, attempt=1),
    ])
    pipe = PipelineAgent(broker, "rb", poll_interval_s=0.01).start()
    try:
        assert pipe.recover([spec]) == [cid]
        # recovery resubmits the in-flight task once (third and last attempt)
        st = pipe.status(cid)
        assert st.stages["w"].retried == 2  # attempts 1 (replayed) + 2 (new)
        # no workers: the watchdog times the last attempt out and the budget
        # — already charged for the pre-crash attempts — is exhausted
        assert _wait(lambda: pipe.status(cid).state == "FAILED", timeout=15.0)
        assert "exhausted 3 attempts" in pipe.status(cid).failure
        # exactly ONE task message ever hit the class topic: the recovery
        # resubmission (the journal records above were never submitted)
        sent = broker.read_from("rb-new.cpu")
        assert len(sent) == 1 and sent[0].value["attempt"] == 2
    finally:
        pipe.stop()
        broker.close()


def test_recovery_with_already_skipped_stages():
    """Replay of StageSkipped events: skip_when decisions made before the
    crash are folded back verbatim (never re-evaluated, never doubled) and
    the recovered campaign completes with the same skip counts."""
    spec = PipelineSpec("condrec", [
        Stage("src", "pl_double", fan_out=1),
        Stage("fwd", "pl_pass", depends_on=("src",),
              skip_when=lambda r: r["values"][0] % 4 == 0),  # skip 0 and 2
        Stage("agg", "pl_sum", depends_on=("src", "fwd"), join=True),
    ])
    broker = Broker(default_partitions=2)
    pipe1 = PipelineAgent(broker, "sr", poll_interval_s=0.005).start()
    prod = Producer(broker)
    topics = topic_names("sr")
    try:
        cid = pipe1.submit_campaign(spec, [0, 1, 2, 3], campaign_id="camp-sk")

        def done(tid, result):
            prod.send(topics["done"],
                      ResultMessage(task_id=tid, agent_id="hand",
                                    result=result).to_dict(), key=tid)

        # item 0 -> fwd skipped, item 1 -> fwd dispatched; then crash
        done("camp-sk-src-00000", {"values": [0]})
        done("camp-sk-src-00001", {"values": [2]})
        assert _wait(lambda: pipe1.status(cid).stages["fwd"].skipped == 1)
        pipe1.crash()

        w = WorkerAgent(broker, "sr", slots=2, poll_interval_s=0.005).start()
        pipe2 = PipelineAgent(broker, "sr", agent_id="rec",
                              poll_interval_s=0.005).start()
        assert pipe2.recover([spec]) == [cid]
        st = pipe2.wait(cid, timeout=60.0)
        assert st.state == "COMPLETED", st.failure
        assert st.stages["fwd"].skipped == 2   # replayed skip + items 2
        assert st.stages["fwd"].done == 2      # items 1 and 3
        assert st.stages["agg"].done == 1
        # the replayed skip (fwd-00000) was never submitted to any topic
        sent = {r.value["task_id"] for r in broker.read_from("sr-new.cpu")}
        assert "camp-sk-fwd-00000" not in sent
        final = pipe2.final_result(cid)
        assert final["n_fwd"] == 2 and final["total"] == 2 + 6
        pipe2.stop()
        w.stop()
    finally:
        broker.close()


def test_knots_pipeline_skips_localize_without_survivors():
    """The ROADMAP's early-exit example end to end: a campaign of unknotted
    coils produces zero screen survivors, so every localize task is skipped
    and the campaign is finished, not failed."""
    from repro.apps import knots
    broker = Broker(default_partitions=2)
    w = WorkerAgent(broker, "sk4", slots=2, poll_interval_s=0.01).start()
    try:
        # ids ≡ 1 (mod 4) synthesize unknotted random coils
        ids = [1, 5, 9, 13]
        spec = knots.knots_pipeline(2, n_points=48)
        res = run_campaign(spec, ids, broker=broker, prefix="sk4",
                           timeout_s=240.0)
        st = res.status
        assert st.state == "COMPLETED"
        assert st.stages["localize"].skipped == 2
        assert st.stages["localize"].submitted == 0
        assert res.final["knotted"] == [] and res.final["cores"] == {}
        assert res.final["processed"] == len(ids)
    finally:
        w.stop()
        broker.close()


# ---------------------------------------------------------------------------
# journal compaction (ISSUE satellite: snapshot + truncate terminal campaigns)
# ---------------------------------------------------------------------------

def test_campaign_weight_validated_at_submit():
    """ISSUE satellite: zero/negative weights starve (and NaN poisons) the
    FairShare weighted round-robin — all rejected at the API edge."""
    broker = Broker(default_partitions=2)
    pipe = PipelineAgent(broker, "wv", poll_interval_s=0.01)
    spec = PipelineSpec("tiny", [Stage("src", "pl_double", fan_out=4)])
    try:
        for bad in (0, -1.0, float("nan"), float("inf")):
            with pytest.raises(PipelineError):
                pipe.submit_campaign(spec, [1], weight=bad)
        assert pipe.campaigns() == {}  # nothing half-registered
    finally:
        broker.close()


def test_snapshot_fold_equals_full_history_fold():
    """The compaction contract at the reducer level: folding just the
    CampaignSnapshot record reproduces the exact domain state of folding
    the full event history."""
    from repro.pipeline.state import snapshot_event

    broker = Broker(default_partitions=2)
    w = WorkerAgent(broker, "sf", slots=2, poll_interval_s=0.005).start()
    spec = _three_stage(fan_out=2)
    try:
        res = run_campaign(spec, [1, 2, 3, 4], broker=broker, prefix="sf",
                           timeout_s=60.0)
        events = _read_journal(broker, "sf", res.campaign_id)
        full = CampaignState.fold(spec, res.campaign_id, events)
        snap = dataclasses.replace(snapshot_event(full), seq=full.seq + 1)
        restored = CampaignState.fold(spec, res.campaign_id, [snap])
        assert restored == full  # domain-snapshot equality
        # and folding a truncated prefix + the snapshot is equally exact
        garbled = CampaignState.fold(spec, res.campaign_id,
                                     events[3:7] + [snap])
        assert garbled == full
    finally:
        w.stop()
        broker.close()


def test_compact_bounds_journal_and_keeps_recovery_parity():
    """compact() collapses each terminal campaign to one snapshot record and
    truncates its event history off the topic; recover(include_finished=True)
    on a fresh agent still rebuilds results exactly."""
    broker = Broker(default_partitions=2)
    w = WorkerAgent(broker, "cp", slots=2, poll_interval_s=0.005).start()
    spec = _three_stage(fan_out=2)
    topics = topic_names("cp")
    pipe = PipelineAgent(broker, "cp", poll_interval_s=0.005).start()
    try:
        cids, finals = [], {}
        for i in range(3):
            res = run_campaign(spec, list(range(4)), broker=broker,
                               prefix="cp", agent=pipe, timeout_s=60.0)
            cids.append(res.campaign_id)
            finals[res.campaign_id] = res.final
        before = len(broker.read_from(topics["campaigns"]))
        out = pipe.compact()
        after = len(broker.read_from(topics["campaigns"]))
        assert sorted(out["campaigns"]) == sorted(cids)
        assert out["truncated"] > 0
        assert after < before / 3  # bounded: one snapshot per campaign
        # repeat compaction is churn-free: no new snapshots, nothing cut
        journaled = pipe.events_journaled
        out2 = pipe.compact()
        assert pipe.events_journaled == journaled
        assert out2["truncated"] == 0
        assert len(broker.read_from(topics["campaigns"])) == after
        # a fresh agent folds snapshot-then-events back to full parity
        rec = PipelineAgent(broker, "cp", agent_id="cp-rec",
                            poll_interval_s=0.005).start()
        assert rec.recover([spec]) == []  # terminal: not resurrected
        assert sorted(rec.recover([spec], include_finished=True)) == \
            sorted(cids)
        for cid in cids:
            st = rec.status(cid)
            assert st.state == "COMPLETED"
            assert rec.final_result(cid) == finals[cid]
            assert len(rec.results(cid)["fwd"]) == 2
        rec.stop()
    finally:
        pipe.stop()
        w.stop()
        broker.close()


def test_compact_preserves_live_campaigns_and_evicted_with_specs():
    """Compaction must never touch a live campaign's journal (recovery needs
    it), and with specs supplied it also folds + compacts terminal campaigns
    already evicted from agent memory."""
    broker = Broker(default_partitions=2)
    w = WorkerAgent(broker, "cl", slots=2, poll_interval_s=0.005).start()
    fast = PipelineSpec("tiny", [Stage("src", "pl_double", fan_out=4)])
    slow = PipelineSpec("slow", [
        Stage("w", "pl_slow", fan_out=1, params={"duration": 30.0}),
    ])
    topics = topic_names("cl")
    pipe = PipelineAgent(broker, "cl", poll_interval_s=0.005,
                         retain_finished=0).start()
    try:
        done_cid = pipe.submit_campaign(fast, [1, 2, 3])
        assert _wait(lambda: done_cid not in pipe.campaigns(), timeout=30.0)
        live_cid = pipe.submit_campaign(slow, [[9]], campaign_id="camp-live")
        assert _wait(lambda: pipe.status(live_cid)
                     .stages["w"].submitted == 1, timeout=10.0)
        # without specs the evicted campaign is unknown -> kept verbatim
        out = pipe.compact()
        assert out["campaigns"] == []
        assert len(_read_journal(broker, "cl", done_cid)) > 1
        # with specs it is folded, snapshotted, and truncated to one record
        out = pipe.compact({"tiny": fast})
        assert out["campaigns"] == [done_cid]
        done_events = _read_journal(broker, "cl", done_cid)
        assert [type(e).__name__ for e in done_events] == \
            ["CampaignSnapshot"]
        # the live campaign's full journal survived and still recovers
        live_events = _read_journal(broker, "cl", live_cid)
        assert any(type(e).__name__ == "CampaignSubmitted"
                   for e in live_events)
        pipe.crash()
        rec = PipelineAgent(broker, "cl", agent_id="cl-rec",
                            poll_interval_s=0.005).start()
        assert rec.recover([fast, slow]) == [live_cid]
        rec.stop()
    finally:
        pipe.stop()
        w.stop()
        broker.close()
