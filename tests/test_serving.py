"""Continuous-batching serving engine tests: correctness of ragged decode
(per-slot positions) vs the whole-sequence reference, slot reuse, and the
KSA-driven request flow."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import init_params, model_spec
from repro.models.transformer import forward
from repro.serve import ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("stablelm_1_6b")
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0),
                         jnp.dtype(cfg.dtype))
    return cfg, params


def _greedy_reference(cfg, params, prompt, max_new):
    """Whole-sequence greedy decoding (re-runs forward each step)."""
    toks = list(prompt)
    for _ in range(max_new):
        logits, _, _ = forward(params, cfg,
                               {"tokens": jnp.asarray([toks], jnp.int32)})
        logits = logits[0, -1, :cfg.vocab_size]
        toks.append(int(jnp.argmax(logits)))
    return toks[len(prompt):]


def test_engine_matches_whole_sequence_reference(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, n_slots=2, max_len=64)
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(0, cfg.vocab_size, 6)),
               list(rng.randint(0, cfg.vocab_size, 9))]
    out = eng.run_until_drained([("a", prompts[0], 5), ("b", prompts[1], 5)])
    assert set(out) == {"a", "b"}
    for rid, prompt in zip(("a", "b"), prompts):
        ref = _greedy_reference(cfg, params, prompt, 5)
        assert out[rid] == ref, (rid, out[rid], ref)


def test_ragged_joining_and_slot_reuse(small_model):
    """More requests than slots with different prompt lengths: continuous
    batching must finish them all and reuse slots."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, n_slots=2, max_len=64)
    rng = np.random.RandomState(1)
    reqs = [(f"r{i}", list(rng.randint(0, cfg.vocab_size, 3 + i)), 4)
            for i in range(5)]
    out = eng.run_until_drained(list(reqs))
    assert set(out) == {f"r{i}" for i in range(5)}
    for rid, prompt, n in reqs:
        assert out[rid] == _greedy_reference(cfg, params, prompt, 4), rid
    assert eng.tokens_out == 20


def test_engine_hybrid_arch(small_model):
    """Continuous batching over the hybrid (RG-LRU + local ring cache) arch:
    exercises per-slot positions on the ring cache path."""
    cfg = smoke_config("recurrentgemma_2b")
    params = init_params(model_spec(cfg), jax.random.PRNGKey(1),
                         jnp.dtype(cfg.dtype))
    eng = ServeEngine(cfg, params, n_slots=2, max_len=96)
    rng = np.random.RandomState(2)
    prompts = [list(rng.randint(0, cfg.vocab_size, 5)),
               list(rng.randint(0, cfg.vocab_size, 8))]
    out = eng.run_until_drained([("x", prompts[0], 4), ("y", prompts[1], 4)])
    for rid, prompt in zip(("x", "y"), prompts):
        ref = _greedy_reference(cfg, params, prompt, 4)
        assert out[rid] == ref, (rid, out[rid], ref)
