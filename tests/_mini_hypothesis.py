"""Deterministic stand-in for the `hypothesis` property-testing API.

The container this repo targets does not ship `hypothesis` (and the no-new-
dependencies rule forbids installing it). Property tests still run: this
module implements the tiny subset the test-suite uses — ``given`` /
``settings`` / ``strategies.integers|sampled_from|lists`` with ``.map`` —
drawing examples from a fixed-seed RNG so runs are reproducible. When real
hypothesis is available the tests import it instead (see the try/except at
each call site); this fallback trades shrinking and coverage-guided search
for determinism, not correctness.
"""
from __future__ import annotations

import random
from typing import Any, Callable, Sequence

_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def map(self, fn: Callable[[Any], Any]) -> "_Strategy":
        return _Strategy(lambda rng: fn(self._draw(rng)))

    def example_draw(self, rng: random.Random) -> Any:
        return self._draw(rng)


class strategies:  # noqa: N801 - mirrors `hypothesis.strategies` module name
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements: Sequence[Any]) -> _Strategy:
        elems = list(elements)
        return _Strategy(lambda rng: elems[rng.randrange(len(elems))])

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        return _Strategy(
            lambda rng: [elem.example_draw(rng)
                         for _ in range(rng.randint(min_size, max_size))])


def settings(max_examples: int = 20, deadline: Any = None,
             **_ignored: Any) -> Callable:
    def deco(fn: Callable) -> Callable:
        fn._mini_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy) -> Callable:
    def deco(fn: Callable) -> Callable:
        def wrapper() -> None:
            cfg = getattr(wrapper, "_mini_settings", None) or \
                getattr(fn, "_mini_settings", {})
            rng = random.Random(_SEED)
            for _ in range(cfg.get("max_examples", 20)):
                pos = [s.example_draw(rng) for s in arg_strategies]
                kws = {k: s.example_draw(rng)
                       for k, s in kw_strategies.items()}
                fn(*pos, **kws)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
