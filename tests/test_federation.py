"""repro.federation: site/link modeling, site-aware routing, cross-site
relays, WAN-tolerant leases, spillover, and the federated observability
surface."""
import json
import time
import urllib.request

import pytest

from repro.cluster import KsaCluster
from repro.core.lease import LeaseTolerance, RevokeReason
from repro.core.messages import Resources, TaskMessage, TaskStatus
from repro.federation import (FederatedCluster, Site, SiteRouter,
                              SpilloverConfig, SpilloverController, WanLink,
                              site_class)


def _wait(cond, timeout=10.0, interval=0.01):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return cond()


def _task(task_id="t1", script="sleep", **res):
    return TaskMessage(task_id=task_id, script=script,
                       resources=Resources(**res))


# -- model ------------------------------------------------------------------


def test_wanlink_transfer_model():
    link = WanLink(latency_s=0.05, bandwidth_mbps=100.0)
    assert link.one_way_s() == pytest.approx(0.05)
    # 10 MB over 100 Mbps = 0.8 s of transfer on top of latency
    assert link.one_way_s(10.0) == pytest.approx(0.85)
    assert link.round_trip_s(10.0) == pytest.approx(0.90)
    assert link.up
    link.partition()
    assert not link.up and link.to_dict()["up"] is False
    link.heal()
    assert link.up
    with pytest.raises(ValueError):
        WanLink(latency_s=-1.0)
    with pytest.raises(ValueError):
        WanLink(bandwidth_mbps=0.0)


def test_site_name_validation():
    with pytest.raises(ValueError):
        Site("")
    with pytest.raises(ValueError):
        Site("a.b")  # dot collides with the class-topic separator
    assert Site("hpc", workers=2, worker_slots=3).slots == 6


def test_lease_tolerance_deadline():
    assert LeaseTolerance().deadline(10.0) == pytest.approx(10.0)
    t = LeaseTolerance(slack_s=2.0, rtt_factor=1.5)
    assert t.deadline(10.0) == pytest.approx(17.0)
    assert t.deadline(None) == pytest.approx(2.0)
    assert LeaseTolerance().deadline(None) is None


# -- routing ----------------------------------------------------------------


def test_site_router_classification():
    router = SiteRouter(["a", "b"], home="a")
    assert site_class("b") in router.classes()
    assert site_class("a") not in router.classes()
    assert router.classify(_task(site="b")) == site_class("b")
    # home pin and no pin both fall through to cpu/gpu classes
    assert router.classify(_task(site="a")) == "cpu"
    assert router.classify(_task()) == "cpu"
    assert router.classify(_task(gpus=1)) == "gpu"
    with pytest.raises(ValueError):
        router.classify(_task(site="nowhere"))
    with pytest.raises(ValueError):
        SiteRouter(["a", "b"], home="c")


def test_affinity_profile_subscribes_only_to_site_class():
    router = SiteRouter(["a", "b"], home="a")
    prof = router.affinity_profile("b")
    assert router.subscriptions("ksa", prof) == (f"ksa-new.{site_class('b')}",)
    # ordinary pools never see the site classes
    from repro.core.scheduling import ResourceProfile
    cpu = ResourceProfile(cpus=2, mem_mb=2048)
    assert f"ksa-new.{site_class('b')}" not in \
        router.subscriptions("ksa", cpu)


def test_spill_score_prices_coldstart_slots_and_transfer():
    router = SiteRouter(["a", "b", "c"], home="a")
    cheap = Site("b", link=WanLink(latency_s=0.01, bandwidth_mbps=1000.0))
    pricey = Site("c", spinup_s=5.0, slot_cost=3.0,
                  link=WanLink(latency_s=0.2, bandwidth_mbps=10.0))
    assert router.spill_score(cheap) < router.spill_score(pricey)
    # data locality: input weight charges the link both ways matter
    heavy = _task(input_mb=100.0)
    assert router.spill_score(cheap, heavy) > router.spill_score(cheap)
    pricey.link.partition()
    assert router.spill_score(pricey) == float("inf")


# -- federated execution ----------------------------------------------------


def test_pinned_task_relays_to_remote_site():
    with FederatedCluster([Site("a", workers=1), Site("b", workers=1)],
                          task_timeout_s=30.0) as fed:
        local = fed.submit("sleep", params={"duration": 0.02}, site="a")
        remote = fed.submit("sleep", params={"duration": 0.02}, site="b",
                            input_mb=1.0)
        assert fed.wait_all([local, remote], timeout=30.0)
        assert fed.result(remote) == {"slept": 0.02}
        assert fed.task(remote).agent_id.startswith("bridge-b-")
        assert not fed.task(local).agent_id.startswith("bridge-")
        # the remote control plane really executed it
        re = fed.clusters["b"].task(remote)
        assert re is not None and re.done
        with pytest.raises(ValueError):
            fed.submit("sleep", site="nowhere")


def test_campaign_stage_pinned_to_site():
    from repro.pipeline import PipelineSpec, Stage
    spec = PipelineSpec("fedcamp", [
        Stage("local", "sleep", fan_out=2,
              params={"duration": 0.02}, resources=Resources(cpus=1)),
        Stage("remote", "sleep", depends_on=("local",), join=True,
              params={"duration": 0.02},
              resources=Resources(cpus=1, site="b")),
    ])
    with FederatedCluster([Site("a", workers=1), Site("b", workers=1)],
                          task_timeout_s=30.0) as fed:
        res = fed.run_campaign(spec, list(range(4)), timeout_s=60.0)
        assert res.status.state == "COMPLETED"
        # the pinned join stage ran through the site-b bridge
        entries = fed.clusters["b"].monitor.tasks()
        assert any(e.done for e in entries.values())


def test_remote_failure_propagates_home():
    with FederatedCluster([Site("a", workers=1), Site("b", workers=1)],
                          max_attempts=1) as fed:
        tid = fed.submit("fail", params={"fail_times": 5}, site="b")
        assert _wait(lambda: fed.task(tid) is not None
                     and fed.task(tid).errors, timeout=20.0)
        e = fed.task(tid)
        assert not e.done
        assert "site b" in e.errors[-1]["error"]


def test_bridge_requires_remote_monitor():
    fed = FederatedCluster([
        Site("a", workers=1),
        Site("b", workers=1, cluster_kw={"monitor": False})])
    with pytest.raises(ValueError, match="monitor"):
        fed.start()
    fed.stop()


# -- WAN-tolerant leases ----------------------------------------------------


def test_partition_within_tolerance_is_not_revoked():
    """A WAN partition longer than the uniform monitor deadline must not
    trip the watchdog when the site's LeaseTolerance covers it — the relay
    resumes after heal and the task completes on its first attempt."""
    b = Site("b", workers=1, tolerance=LeaseTolerance(slack_s=60.0))
    with FederatedCluster([Site("a", workers=1), b],
                          task_timeout_s=0.5) as fed:
        tid = fed.submit("sleep", params={"duration": 0.2}, site="b")
        # the home lease is stamped with the site + stretched deadline
        assert _wait(lambda: fed.home.broker.lease_view(tid) is not None,
                     timeout=10.0)
        lease = fed.home.broker.lease_view(tid)
        assert lease["site"] == "b"
        assert lease["deadline_s"] == pytest.approx(60.5)
        b.link.partition()
        time.sleep(1.2)  # > task_timeout_s: heartbeats stopped, staleness grew
        b.link.heal()
        assert fed.wait_all([tid], timeout=30.0)
        e = fed.task(tid)
        assert e.result_attempt == 0          # never resubmitted
        assert e.duplicate_results == 0
        revoked = fed.home.broker.lease_stats()["revoked"]
        assert revoked.get(RevokeReason.WATCHDOG, 0) == 0


def test_partition_beyond_tolerance_recovers_via_watchdog():
    """Without tolerance the same partition trips the per-site deadline
    (== the uniform one) and the monitor reclaims the lease; the task must
    still complete exactly once after redelivery."""
    b = Site("b", workers=1)  # default tolerance: no extra headroom
    with FederatedCluster([Site("a", workers=1), b],
                          task_timeout_s=0.4) as fed:
        tid = fed.submit("sleep", params={"duration": 0.2}, site="b")
        assert _wait(lambda: fed.home.broker.lease_view(tid) is not None,
                     timeout=10.0)
        b.link.partition()
        time.sleep(1.0)
        b.link.heal()
        assert fed.wait_all([tid], timeout=30.0)
        e = fed.task(tid)
        assert e.done
        assert e.duplicate_results == 0


# -- cross-site revocation fencing ------------------------------------------


def test_cross_site_preemption_fences_remote_verdict():
    """Preempting a spilled task from home revokes the remote copy too;
    the home commit gate accepts exactly one verdict across both sites."""
    # a real link latency keeps the fence deterministic: the cancelled
    # relay's remote abort is control traffic (no link wait), so it always
    # beats the requeued retry's data shipment to site B
    b = Site("b", workers=1, link=WanLink(latency_s=0.2))
    with FederatedCluster([Site("a", workers=1), b],
                          task_timeout_s=60.0) as fed:
        tid = fed.submit("sleep", params={"duration": 1.0}, site="b")
        remote = fed.clusters["b"]
        assert _wait(lambda: (remote.task(tid) is not None and
                              remote.task(tid).status ==
                              TaskStatus.RUNNING.value), timeout=20.0)
        assert fed.revoke(tid, RevokeReason.PREEMPT)
        assert fed.wait_all([tid], timeout=40.0)
        e = fed.task(tid)
        assert e.duplicate_results == 0       # one committed verdict, ever
        assert e.result_attempt >= 1          # the re-run, not the preempted
        # the preemption crossed the WAN: the remote lease was revoked
        remote_revoked = remote.broker.lease_stats()["revoked"]
        assert remote_revoked.get(RevokeReason.PREEMPT, 0) >= 1


# -- spillover --------------------------------------------------------------


def test_spillover_borrows_and_returns_remote_capacity():
    cfg = SpilloverConfig(classes=("cpu",), horizon_s=0.5, min_backlog=2,
                          cooldown_s=0.0, drain_idle_s=0.05,
                          bridge_slots=2, max_bridges_per_class=2)
    with FederatedCluster([Site("a", workers=0),
                           Site("b", workers=1, worker_slots=2)]) as fed:
        ctl = SpilloverController(fed, cfg)  # tick by hand: no loop thread
        tids = [fed.submit("sleep", params={"duration": 0.05})
                for _ in range(6)]
        ctl.tick()
        assert ctl.bridge_count("cpu") >= 1   # home has no cpu capacity
        assert fed.bridges("b")
        assert fed.wait_all(tids, timeout=30.0)
        # backlog gone: ticks drain the spill bridges back
        assert _wait(lambda: (ctl.tick() or ctl.bridge_count("cpu") == 0),
                     timeout=20.0, interval=0.05)
        # ...and deregistered, leaving only the permanent affinity bridge
        assert _wait(lambda: (ctl.tick() or
                              [b.role for b in fed.bridges("b")] ==
                              ["affinity"]),
                     timeout=20.0, interval=0.05)
        st = ctl.status()
        actions = [d["action"] for d in st["decisions"]]
        assert "spill" in actions and "release" in actions
        assert st["classes"]["cpu"]["spills"] >= 1


def test_spillover_rejects_unknown_class():
    with FederatedCluster([Site("a", workers=1), Site("b")]) as fed:
        with pytest.raises(ValueError, match="resource class"):
            SpilloverController(fed, SpilloverConfig(classes=("warp",)))


# -- federated observability ------------------------------------------------


def test_sites_endpoint_and_federated_metrics():
    with FederatedCluster([Site("a", workers=1), Site("b", workers=1)],
                          http=True) as fed:
        tid = fed.submit("sleep", params={"duration": 0.02}, site="b")
        assert fed.wait_all([tid], timeout=30.0)
        port = fed.http_port
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/sites") as r:
            payload = json.loads(r.read())
        assert payload["home"] == "a"
        assert set(payload["sites"]) == {"a", "b"}
        assert payload["sites"]["b"]["bridges"], "affinity bridge missing"
        assert payload["sites"]["b"]["broker"]["site"] == "b"
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics") as r:
            text = r.read().decode()
        assert 'site="a"' in text and 'site="b"' in text
        for line in text.splitlines():
            if line and not line.startswith("#"):
                assert 'site="' in line, f"unlabelled sample: {line}"
    # standalone clusters keep the unlabelled single-site exposition
    with KsaCluster(workers=1, http=True) as c:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{c.http_port}/metrics") as r:
            text = r.read().decode()
        assert 'site="' not in text


def test_home_query_answers_sum_by_site_across_the_federation():
    """ISSUE 9 acceptance: with the telemetry plane on, the home collector
    holds a feed into every remote site's PREFIX-telemetry topic, so one
    home /query answers sum_by(site) across the whole federation."""
    fed = FederatedCluster(
        [Site("home", workers=1), Site("edge", workers=1)],
        prefix="fedq", http=True, telemetry=True)
    with fed:
        ids = [fed.submit("sleep", params={"duration": 0.01})
               for _ in range(4)]
        ids.append(fed.submit("sleep", site="edge",
                              params={"duration": 0.01}))
        assert fed.wait_all(ids, timeout=30.0)
        # drive the plane deterministically: both sites publish, then the
        # home facade polls its feeds inside query()
        for cluster in fed.clusters.values():
            cluster.telemetry_publisher.publish_once()
        out = fed.query("ksa_leases_granted_total", agg="sum_by", by="site")
        assert set(out["result"]) == {"home", "edge"}
        assert out["result"]["home"] >= 4
        assert out["result"]["edge"] >= 1       # the relayed task's lease
        # the same question over the home monitor's HTTP surface
        with urllib.request.urlopen(
                f"http://127.0.0.1:{fed.http_port}/query?"
                f"name=ksa_leases_granted_total&agg=sum_by&by=site") as r:
            data = json.loads(r.read())
        assert set(data["result"]) == {"home", "edge"}
        assert data["result"] == out["result"]
        # remote spans fold into the home span store tagged with the site
        edge_grants = fed.query("ksa_leases_granted_total", agg="sum",
                                labels={"site": "edge"})
        assert edge_grants["result"] >= 1
        # alerts and blackbox ride the same home surface
        assert fed.alerts()["rules"] == []
        assert fed.dump_blackbox()["trigger"] == "manual"
