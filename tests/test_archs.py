"""Per-architecture smoke tests: reduced configs of the same family run one
forward + one train step on CPU, asserting shapes and finiteness. Decode
paths are checked for prefill/decode consistency on the families that serve.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models import count_params, init_params, model_spec
from repro.models.transformer import forward, init_caches
from repro.optim import OptimizerConfig
from repro.train import init_train_state, make_serve_step, make_train_step


def _smoke_batch(cfg, rng, batch=2, seq=32):
    r = np.random.RandomState(rng)
    out = {}
    if cfg.frontend is not None and cfg.frontend.kind == "audio_frames":
        out["embeds"] = jnp.asarray(
            r.randn(batch, seq, cfg.frontend.input_dim), jnp.float32)
        out["labels"] = jnp.asarray(
            r.randint(0, cfg.vocab_size, (batch, seq)), jnp.int32)
        return out
    if cfg.frontend is not None and cfg.frontend.kind == "vit_patches":
        n_p = cfg.frontend.n_positions
        out["embeds"] = jnp.asarray(
            r.randn(batch, n_p, cfg.frontend.input_dim), jnp.float32)
        out["tokens"] = jnp.asarray(
            r.randint(0, cfg.vocab_size, (batch, seq)), jnp.int32)
        out["labels"] = jnp.asarray(
            r.randint(0, cfg.vocab_size, (batch, seq)), jnp.int32)
        return out
    out["tokens"] = jnp.asarray(
        r.randint(0, cfg.vocab_size, (batch, seq)), jnp.int32)
    out["labels"] = jnp.asarray(
        r.randint(0, cfg.vocab_size, (batch, seq)), jnp.int32)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0),
                         jnp.dtype(cfg.dtype))
    batch = _smoke_batch(cfg, 0)
    logits, caches, aux = forward(params, cfg, batch)
    s = batch["labels"].shape[1]
    assert logits.shape == (2, s, cfg.padded_vocab)
    assert caches is None
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_descends(arch):
    cfg = smoke_config(arch)
    ocfg = OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=100,
                           schedule="constant", weight_decay=0.0)
    state = init_train_state(cfg, ocfg, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(cfg, ocfg))
    batch = _smoke_batch(cfg, 1)
    losses = []
    for _ in range(4):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses  # memorizes a fixed batch
    assert int(state.step) == 4


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if a not in ("hubert_xlarge",)])
def test_smoke_decode_matches_prefill(arch):
    """Teacher-forced decode equals the training-forward logits (validates
    caches: KV, ring-buffer local, MLA latent, SSD/RG-LRU state)."""
    cfg = smoke_config(arch)
    if cfg.frontend is not None:
        pytest.skip("vlm decode covered separately")
    params = init_params(model_spec(cfg), jax.random.PRNGKey(2),
                         jnp.dtype(cfg.dtype))
    seq = 48
    batch = _smoke_batch(cfg, 2, seq=seq)
    ref_logits, _, _ = forward(params, cfg, batch)

    caches = init_caches(cfg, 2, seq, jnp.dtype(cfg.dtype))
    serve = jax.jit(make_serve_step(cfg))
    errs = []
    for t in range(seq):
        logits, _, caches = serve(params, batch["tokens"][:, t:t + 1],
                                  caches, jnp.asarray(t, jnp.int32))
        errs.append(float(jnp.abs(logits - ref_logits[:, t]).max()))
    assert max(errs) < 2e-2, max(errs)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_instantiates_abstractly(arch):
    """The assigned full-size config builds an abstract param tree (no
    allocation) with a sane parameter count."""
    cfg = get_config(arch)
    spec = model_spec(cfg)
    n = count_params(spec)
    expected = {
        "moonshot_v1_16b_a3b": (20e9, 35e9),
        "deepseek_v3_671b": (600e9, 720e9),
        "stablelm_1_6b": (1.2e9, 2.2e9),
        "gemma3_1b": (0.7e9, 1.5e9),
        "internlm2_1_8b": (1.4e9, 2.4e9),
        "gemma3_4b": (3e9, 5.5e9),
        "hubert_xlarge": (0.7e9, 1.3e9),
        "recurrentgemma_2b": (2e9, 3.5e9),
        "internvl2_1b": (0.4e9, 1.0e9),
        "mamba2_130m": (0.1e9, 0.2e9),
    }[arch]
    assert expected[0] < n < expected[1], f"{arch}: {n/1e9:.2f}B params"
    # analytic count from the config agrees with the spec tree
    assert abs(cfg.param_count() - n) / n < 0.05


def test_vlm_prefill_places_patches_before_text():
    cfg = smoke_config("internvl2_1b")
    params = init_params(model_spec(cfg), jax.random.PRNGKey(3),
                         jnp.dtype(cfg.dtype))
    batch = _smoke_batch(cfg, 3, seq=16)
    logits, _, _ = forward(params, cfg, batch)
    # logits cover text positions only
    assert logits.shape == (2, 16, cfg.padded_vocab)
