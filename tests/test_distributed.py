"""Distributed-path correctness: the sharded program (GSPMD + shard_map
islands) must match the single-device program. Runs in a subprocess because
the host-device-count flag must be set before jax initializes."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


def _run(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, *args], capture_output=True,
                          text=True, timeout=timeout, env=env,
                          cwd=str(ROOT))


@pytest.mark.slow
def test_sharded_matches_single_device_all_families():
    r = _run([str(ROOT / "tests" / "island_check.py")])
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]


def test_sharded_matches_single_device_moe():
    r = _run([str(ROOT / "tests" / "island_check.py"),
              "moonshot_v1_16b_a3b"])
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]


def test_dryrun_smoke_cell():
    """One real dry-run cell end-to-end (small arch) on the production mesh
    machinery — exercises dryrun.py exactly as the full matrix does."""
    r = _run(["-m", "repro.launch.dryrun", "--arch", "mamba2_130m",
              "--shape", "train_4k", "--single-pod",
              "--out", "/tmp/dryrun_test", "--force"], timeout=1800)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "OK" in r.stdout
