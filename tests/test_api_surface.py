"""API-surface lints: the federated metrics contract (every registered
``ksa_`` metric is site-labelled when federation is on) and import hygiene
for examples/benchmarks (public package roots only, no site-internal
wiring)."""
import pathlib
import re
import time

from repro.federation import FederatedCluster, Site

REPO = pathlib.Path(__file__).resolve().parent.parent


def _wait(cond, timeout=10.0, interval=0.01):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return cond()


def test_every_registered_metric_is_site_labelled_under_federation():
    """The federated ``/metrics`` exposition must cover every ``ksa_``
    family any site's registry holds, and every sample line must carry a
    ``site`` label — a scrape of the home monitor sees the whole
    federation, unambiguously."""
    with FederatedCluster([Site("a", workers=1), Site("b", workers=1)],
                          task_timeout_s=30.0) as fed:
        tids = [fed.submit("sleep", params={"duration": 0.01}),
                fed.submit("sleep", params={"duration": 0.01}, site="b")]
        assert fed.wait_all(tids, timeout=30.0)
        merged = fed.home.monitor.metrics_text()
        sample_lines = [ln for ln in merged.splitlines()
                        if ln and not ln.startswith("#")]
        assert sample_lines
        for ln in sample_lines:
            assert 'site="' in ln, f"unlabelled sample line: {ln}"
        for name, cluster in fed.clusters.items():
            snap = cluster.broker.metrics.snapshot()
            for family, data in snap.items():
                if not family.startswith("ksa_") or not data["series"]:
                    continue
                pat = re.compile(
                    rf"^{re.escape(family)}(?:_\w+)?\{{[^}}]*"
                    rf"site=\"{re.escape(name)}\"", re.M)
                assert pat.search(merged), \
                    (f"metric {family} of site {name} missing from the "
                     f"federated /metrics exposition")


def test_examples_and_benchmarks_import_public_api_only():
    """Examples and benchmarks are the copy-paste templates — they must go
    through the public package roots (``repro.federation``,
    ``repro.cluster``, ...), never reach into federation site-internal
    wiring (``repro.federation.bridge`` et al.)."""
    internal = re.compile(
        r"^\s*(?:from\s+repro\.federation\.\w+\s+import|"
        r"import\s+repro\.federation\.\w+)", re.M)
    offenders = []
    for folder in ("examples", "benchmarks"):
        for path in sorted((REPO / folder).glob("*.py")):
            if internal.search(path.read_text()):
                offenders.append(str(path.relative_to(REPO)))
    assert not offenders, \
        (f"site-internal federation imports in {offenders}; import from "
         f"the repro.federation package root instead")
