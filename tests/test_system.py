"""System-level behaviour: the public API surface assembles end-to-end —
paper components, model zoo, step builders, kernels, checkpointing — without
touching the heavier e2e suites (those live in test_control_plane /
test_fault_tolerance / test_knots / test_distributed)."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
import repro.apps.knots  # noqa: F401 - registers knot_batch
import repro.serve.engine  # noqa: F401 - registers serve_request
import repro.train.trainer  # noqa: F401 - registers train_chunk
from repro.configs import ARCHS, all_cells, cells_for, get_config, smoke_config
from repro.core import registered_scripts


def test_public_api_surface():
    for name in ("Broker", "Submitter", "ClusterAgent", "WorkerAgent",
                 "MonitorAgent", "ClusterComputing", "SimSlurm"):
        assert hasattr(core, name), name


def test_all_paper_scripts_registered():
    scripts = registered_scripts()
    # built-ins + the three production task kinds
    for s in ("sleep", "fail", "hang", "train_chunk", "knot_batch",
              "serve_request"):
        assert s in scripts, s


def test_cell_matrix_shape():
    """The assignment's cell matrix: 10 archs, with the documented skips
    (encoder has no decode; long_500k only for sub-quadratic stacks)."""
    cells = all_cells()
    assert len(ARCHS) == 10
    assert len(cells) == 33
    assert len(cells_for("hubert_xlarge")) == 2
    assert len(cells_for("mamba2_130m")) == 4
    assert len(cells_for("deepseek_v3_671b")) == 3


def test_smoke_end_to_end_minimal():
    """One tiny train step + one decode step through the public builders."""
    from repro.optim import OptimizerConfig
    from repro.train import (init_train_state, make_serve_step,
                             make_train_step)
    from repro.models.transformer import init_caches

    cfg = smoke_config("stablelm_1_6b")
    ocfg = OptimizerConfig(warmup_steps=0, schedule="constant")
    state = init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 16)),
                                   jnp.int32)}
    state, metrics = jax.jit(make_train_step(cfg, ocfg))(state, batch)
    assert np.isfinite(float(metrics["loss"]))

    caches = init_caches(cfg, 2, 16, jnp.dtype(cfg.dtype))
    logits, next_id, caches = jax.jit(make_serve_step(cfg))(
        state.params, batch["tokens"][:, :1], caches,
        jnp.zeros((), jnp.int32))
    assert logits.shape == (2, cfg.padded_vocab)
    assert int(next_id.max()) < cfg.vocab_size  # padding masked
