"""Per-kernel validation: Pallas (interpret=True, executes the kernel body on
CPU) vs the pure-jnp ref.py oracle, swept over shapes/dtypes — including
hypothesis-driven shape sweeps on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic fallback
    from _mini_hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd import ssd_scan
from repro.kernels.writhe import writhe_map


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("sq,h,kh,d,win,bq,bk", [
    (256, 4, 2, 64, None, 64, 64),
    (256, 4, 1, 64, 96, 64, 64),
    (192, 2, 2, 32, None, 64, 64),
    (128, 8, 4, 128, 32, 32, 32),
    (320, 4, 4, 80, None, 64, 64),   # hubert-style head_dim 80
    (130, 4, 2, 64, None, 64, 64),   # ragged seq (padding path)
])
def test_flash_attention_vs_ref(sq, h, kh, d, win, bq, bk, dtype, tol):
    rng = np.random.RandomState(hash((sq, h, d)) % 2**31)
    q = jnp.asarray(rng.randn(2, sq, h, d), dtype)
    k = jnp.asarray(rng.randn(2, sq, kh, d), dtype)
    v = jnp.asarray(rng.randn(2, sq, kh, d), dtype)
    out = flash_attention(q, k, v, causal=True, window=win,
                          block_q=bq, block_k=bk, interpret=True)
    want = ref.attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), causal=True, window=win)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_bidirectional():
    rng = np.random.RandomState(7)
    q = jnp.asarray(rng.randn(1, 128, 4, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 128, 4, 64), jnp.float32)
    v = jnp.asarray(rng.randn(1, 128, 4, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    sq=st.integers(2, 5).map(lambda e: 2 ** e * 16),   # 64..512
    g=st.sampled_from([1, 2, 4]),
    kh=st.sampled_from([1, 2]),
    d=st.sampled_from([32, 64]),
    win=st.sampled_from([None, 64, 130]),
)
def test_flash_attention_property_sweep(sq, g, kh, d, win):
    h = g * kh
    rng = np.random.RandomState(sq * h + d)
    q = jnp.asarray(rng.randn(1, sq, h, d), jnp.float32)
    k = jnp.asarray(rng.randn(1, sq, kh, d), jnp.float32)
    v = jnp.asarray(rng.randn(1, sq, kh, d), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=win,
                          block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("s,h,p,n,chunk", [
    (256, 2, 32, 16, 64),
    (128, 4, 64, 128, 32),
    (512, 1, 16, 8, 128),
])
def test_ssd_kernel_vs_ref(s, h, p, n, chunk, dtype, tol):
    rng = np.random.RandomState(s + h)
    x = jnp.asarray(rng.randn(2, s, h, p), dtype)
    dt = jnp.asarray(np.abs(rng.randn(2, s, h)) * 0.1, jnp.float32)
    a = -jnp.asarray(np.abs(rng.randn(h)) + 0.5, jnp.float32)
    bm = jnp.asarray(rng.randn(2, s, n), dtype)
    cm = jnp.asarray(rng.randn(2, s, n), dtype)
    out = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    want = ref.ssd_ref(x.astype(jnp.float32), dt, a,
                       bm.astype(jnp.float32), cm.astype(jnp.float32),
                       chunk=chunk)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(
    nc=st.integers(1, 6),
    chunk=st.sampled_from([32, 64]),
    h=st.integers(1, 3),
    p=st.sampled_from([16, 32]),
)
def test_ssd_property_chunk_invariance(nc, chunk, h, p):
    """Kernel output is invariant to the chunk size (state passing exact)."""
    s = nc * chunk
    rng = np.random.RandomState(s + h + p)
    x = jnp.asarray(rng.randn(1, s, h, p), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(1, s, h)) * 0.1, jnp.float32)
    a = -jnp.asarray(np.abs(rng.randn(h)) + 0.5, jnp.float32)
    bm = jnp.asarray(rng.randn(1, s, 8), jnp.float32)
    cm = jnp.asarray(rng.randn(1, s, 8), jnp.float32)
    o1 = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    o2 = ref.ssd_ref(x, dt, a, bm, cm, chunk=s)  # single chunk ref
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# writhe (the paper's workload)
# ---------------------------------------------------------------------------

def _trefoil(n=120, noise=0.0, seed=0):
    t = np.linspace(0, 2 * np.pi, n, endpoint=False)
    x = np.sin(t) + 2 * np.sin(2 * t)
    y = np.cos(t) - 2 * np.cos(2 * t)
    z = -np.sin(3 * t)
    pts = np.stack([x, y, z], -1)
    if noise:
        pts += np.random.RandomState(seed).randn(*pts.shape) * noise
    return pts


def test_writhe_kernel_vs_ref():
    coords = jnp.asarray(np.stack([_trefoil(100),
                                   _trefoil(100, noise=0.05)]), jnp.float32)
    out = writhe_map(coords, block=32, interpret=True)
    want = ref.writhe_map_ref(coords)
    # near-planar pairs can round sign() to 0 in one op order: atol covers
    # those physically-negligible contributions.
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=6e-4, rtol=1e-3)


def test_writhe_trefoil_value():
    """A closed trefoil's writhe is ≈ ±3.41 (knot-theory ground truth); an
    open random coil is near 0 — this is the knot-likelihood signal the
    AlphaKnot heuristic thresholds on."""
    tre = jnp.asarray(_trefoil(160)[None], jnp.float32)
    w = ref.writhe_map_ref(tre)
    total = float(np.abs(np.asarray(w).sum() / 2.0))
    assert 2.8 < total < 4.0, total
    rng = np.random.RandomState(3)
    walk = np.cumsum(rng.randn(160, 3) * 0.5, axis=0)
    ww = ref.writhe_map_ref(jnp.asarray(walk[None], jnp.float32))
    assert abs(float(np.asarray(ww).sum() / 2.0)) < 1.5


@settings(max_examples=8, deadline=None)
@given(n=st.integers(34, 140), block=st.sampled_from([16, 32, 64]),
       seed=st.integers(0, 5))
def test_writhe_property_block_invariance(n, block, seed):
    """Padding/tiling must not change the map; W is symmetric."""
    rng = np.random.RandomState(seed)
    coords = jnp.asarray(np.cumsum(rng.randn(1, n, 3), 1), jnp.float32)
    out = writhe_map(coords, block=block, interpret=True)
    want = ref.writhe_map_ref(coords)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=6e-4, rtol=1e-3)
    w = np.asarray(out)[0]
    # (i,j) and (j,i) blocks evaluate the Gauss integral with different
    # operand orderings -> f32 round-off asymmetry only.
    np.testing.assert_allclose(w, w.T, atol=1e-4)
