"""Sharded, checksummed, async checkpointing.

Layout (one directory per step, atomically renamed into place):

    <dir>/ckpt_<step>/
        manifest.json       # treedef, per-leaf shape/dtype/file/offset/crc
        shard_00000.bin.zst # concatenated leaf buffers, zstd-compressed

Writes go to ``.tmp-ckpt_<step>`` and rename on success, so a crash mid-save
never corrupts the latest checkpoint — the restart path always finds either
the previous complete step or the new complete step (the idempotence the KSA
step-chunk tasks rely on). ``async_save`` runs serialization on a background
thread and overlaps with the next training chunk; the returned handle joins
and re-raises. Restore accepts a ``like`` tree (ShapeDtypeStructs with
shardings) and ``device_put``s each leaf to its target sharding — this is the
resharding path used when the mesh changes between runs (elastic restart).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # container may lack zstandard: fall back to zlib.
    zstandard = None

_SHARD_TARGET_BYTES = 128 * 1024 * 1024


class _Codec:
    """Shard compression, selected per checkpoint and recorded in the
    manifest so restores pick the matching decompressor regardless of which
    codec the writing process had available."""

    def __init__(self, name: str, level: int = 3):
        if name == "zstd" and zstandard is None:
            raise ModuleNotFoundError(
                "checkpoint was written with zstd but the zstandard module "
                "is not installed; re-save with the zlib codec or install "
                "zstandard")
        self.name = name
        self._level = level

    @classmethod
    def preferred(cls) -> "_Codec":
        return cls("zstd" if zstandard is not None else "zlib")

    def compress(self, data: bytes) -> bytes:
        if self.name == "zstd":
            return zstandard.ZstdCompressor(level=self._level).compress(data)
        return zlib.compress(data, self._level)

    def decompress(self, data: bytes) -> bytes:
        if self.name == "zstd":
            return zstandard.ZstdDecompressor().decompress(
                data, max_output_size=2 ** 34)
        return zlib.decompress(data)


def _tree_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(p), leaf) for p, leaf in flat]


def save_checkpoint(directory: str | os.PathLike, step: int, tree: Any,
                    *, extra: dict | None = None) -> str:
    """Synchronous save; returns the checkpoint path."""
    directory = Path(directory)
    final = directory / f"ckpt_{step:08d}"
    tmp = directory / f".tmp-ckpt_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = _tree_paths(tree)
    cctx = _Codec.preferred()
    manifest: dict = {"step": int(step), "extra": extra or {}, "leaves": [],
                      "format": 1, "codec": cctx.name}
    shard_idx = 0
    shard_buf: list[bytes] = []
    shard_bytes = 0

    def flush():
        nonlocal shard_idx, shard_buf, shard_bytes
        if not shard_buf:
            return
        raw = b"".join(shard_buf)
        (tmp / f"shard_{shard_idx:05d}.bin.zst").write_bytes(
            cctx.compress(raw))
        shard_idx += 1
        shard_buf = []
        shard_bytes = 0

    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        buf = arr.tobytes()
        manifest["leaves"].append({
            "name": name, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "shard": shard_idx, "offset": shard_bytes, "nbytes": len(buf),
            "crc": zlib.crc32(buf) & 0xFFFFFFFF,
        })
        shard_buf.append(buf)
        shard_bytes += len(buf)
        if shard_bytes >= _SHARD_TARGET_BYTES:
            flush()
    flush()
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return str(final)


def restore_checkpoint(path: str | os.PathLike, tree_like: Any
                       ) -> tuple[Any, dict]:
    """Restore into the structure of ``tree_like``. Leaves of ``tree_like``
    may be arrays or ShapeDtypeStructs (optionally carrying ``.sharding``,
    in which case each leaf is device_put to it — resharding on restore).
    Returns (tree, manifest_extra)."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    by_name = {e["name"]: e for e in manifest["leaves"]}
    dctx = _Codec(manifest.get("codec", "zstd"))
    shards: dict[int, bytes] = {}

    def shard(i: int) -> bytes:
        if i not in shards:
            shards[i] = dctx.decompress(
                (path / f"shard_{i:05d}.bin.zst").read_bytes())
        return shards[i]

    names_like = _tree_paths(tree_like)
    leaves_out = []
    for name, like in names_like:
        e = by_name.get(name)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {name}")
        raw = shard(e["shard"])[e["offset"]: e["offset"] + e["nbytes"]]
        if (zlib.crc32(raw) & 0xFFFFFFFF) != e["crc"]:
            raise IOError(f"checksum mismatch for {name}")
        arr = np.frombuffer(raw, dtype=np.dtype(e["dtype"])).reshape(
            e["shape"]).copy()
        want_dtype = jnp.dtype(like.dtype)
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {like.shape}")
        sharding = getattr(like, "sharding", None)
        val = jnp.asarray(arr, want_dtype)
        if sharding is not None:
            val = jax.device_put(val, sharding)  # reshard on restore
        leaves_out.append(val)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves_out), \
        manifest.get("extra", {})


class _AsyncHandle:
    def __init__(self, thread: threading.Thread, box: dict):
        self._t = thread
        self._box = box

    def result(self, timeout: float | None = None) -> str:
        self._t.join(timeout)
        if self._t.is_alive():
            raise TimeoutError("checkpoint save still running")
        if "error" in self._box:
            raise self._box["error"]
        return self._box["path"]


class CheckpointManager:
    """Directory of step checkpoints with retention + async save + latest().

    ``on_save`` hook lets the trainer announce new checkpoints on the broker
    (the MonitorAgent keeps the checkpoint registry)."""

    def __init__(self, directory: str | os.PathLike, *, keep: int = 3,
                 on_save=None):
        self.directory = Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.on_save = on_save
        self._lock = threading.Lock()

    def steps(self) -> list[int]:
        out = []
        for p in self.directory.glob("ckpt_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest(self) -> tuple[int, str] | None:
        s = self.steps()
        if not s:
            return None
        return s[-1], str(self.directory / f"ckpt_{s[-1]:08d}")

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.directory / f"ckpt_{s:08d}",
                          ignore_errors=True)

    def save(self, step: int, tree: Any, *, extra: dict | None = None) -> str:
        # snapshot to host BEFORE returning so the caller may mutate state
        with self._lock:
            path = save_checkpoint(self.directory, step, tree, extra=extra)
            self._gc()
        if self.on_save:
            self.on_save(step, path)
        return path

    def async_save(self, step: int, tree: Any, *,
                   extra: dict | None = None) -> _AsyncHandle:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        box: dict = {}

        def work():
            try:
                box["path"] = self.save(step, host_tree, extra=extra)
            except Exception as exc:  # noqa: BLE001
                box["error"] = exc

        t = threading.Thread(target=work, daemon=True,
                             name=f"ckpt-save-{step}")
        t.start()
        return _AsyncHandle(t, box)

    def restore_latest(self, tree_like: Any):
        latest = self.latest()
        if latest is None:
            return None
        step, path = latest
        tree, extra = restore_checkpoint(path, tree_like)
        return step, tree, extra
