"""FederatedCluster — N KSA deployments behind the single-cluster API.

The paper's deployment already spans "multiple Slurm-managed HPC clusters
and workstations", but as one flat consumer group on one broker — every
agent polls every topic, and there is no notion of *where* a task should
run or what moving it there costs. ``FederatedCluster`` keeps each site a
full, independent control plane (its own :class:`~repro.core.broker.Broker`,
pools, monitor, autoscaler) and federates them at the control level::

    from repro.federation import FederatedCluster, Site, WanLink

    with FederatedCluster([
        Site("edge", workers=2),                       # home: submissions enter here
        Site("hpc", workers=4, spinup_s=2.0,
             link=WanLink(latency_s=0.05, bandwidth_mbps=200.0)),
    ], spillover=SpilloverConfig(horizon_s=3.0)) as fed:
        tid = fed.submit("knot_scan", params=...)              # runs anywhere
        pinned = fed.submit("knot_scan", site="hpc", ...)      # site affinity
        fed.wait_all([tid, pinned])

The first site is **home**: its broker holds the authoritative lease for
every task, its monitor serves the federated REST API (``/sites``, the
site-labelled ``/metrics``), and its class topics are where all work
lands. Remote sites receive work only through
:class:`~repro.federation.bridge.SiteBridgeAgent` relays — *affinity*
bridges (always on, draining each site's ``site.<name>`` pin class) and
*spill* bridges (raised by the :class:`~repro.federation.
SpilloverController` when home backlog outruns its drain rate). Because a
bridge is just another home consumer holding a home lease, the federation
inherits the single-site exactly-once story wholesale: cross-site
revocation fences through the same :meth:`~repro.core.broker.Broker.
complete_lease` gate, and WAN slowness is absorbed by per-site lease
deadlines (:class:`~repro.core.lease.LeaseTolerance`) instead of weakening
the watchdog everywhere.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Iterable, Mapping, Sequence

from repro.cluster import KsaCluster
from repro.core.lease import RevokeReason
from repro.core.messages import Resources, topic_names
from repro.core.scheduling import ResourceProfile
from repro.obs import merge_renders

from .bridge import SiteBridgeAgent
from .router import SiteRouter
from .site import Site
from .spillover import SpilloverConfig, SpilloverController

__all__ = ["FederatedCluster"]


class FederatedCluster:
    """Context-managed multi-site deployment, API-compatible with
    :class:`~repro.cluster.KsaCluster` for the task/campaign surface.

    ``sites[0]`` is the home site. Remote clusters run under prefix
    ``{prefix}-{site}`` on their own brokers; ``Site.cluster_kw`` passes
    extra :class:`KsaCluster` kwargs per site (e.g. a site-local
    ``autoscale`` config rides in ``Site.autoscale``). ``bridge_slots``
    bounds each affinity bridge's in-flight relays."""

    def __init__(self, sites: Sequence[Site], *, prefix: str = "ksa",
                 spillover: SpilloverConfig | None = None,
                 http: bool = False,
                 bridge_slots: int = 4,
                 remote_poll_s: float = 0.02,
                 task_timeout_s: float | None = None,
                 max_attempts: int = 3,
                 poll_interval_s: float = 0.01,
                 extra_classes: tuple[str, ...] = (),
                 gpu_takes_cpu: bool = True,
                 telemetry: bool = False,
                 telemetry_interval_s: float = 0.25,
                 slos: Sequence[Any] = ()):
        self.sites = tuple(sites)
        if not self.sites:
            raise ValueError("a federation needs at least one site")
        names = [s.name for s in self.sites]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate site names: {names}")
        self.prefix = prefix
        self.task_timeout_s = task_timeout_s
        self.bridge_slots = bridge_slots
        self.remote_poll_s = remote_poll_s
        self.poll_interval_s = poll_interval_s
        self.home_site = self.sites[0]
        self.remote_sites = self.sites[1:]
        self.router = SiteRouter(names, home=self.home_site.name,
                                 extra_classes=extra_classes,
                                 gpu_takes_cpu=gpu_takes_cpu)
        self._telemetry_enabled = telemetry
        self.home = self._build_cluster(
            self.home_site, prefix=prefix, placement=self.router,
            http=http, task_timeout_s=task_timeout_s,
            max_attempts=max_attempts, telemetry=telemetry,
            telemetry_interval_s=telemetry_interval_s, slos=tuple(slos))
        self.clusters: dict[str, KsaCluster] = {self.home_site.name: self.home}
        for s in self.remote_sites:
            self.clusters[s.name] = self._build_cluster(
                s, prefix=f"{prefix}-{s.name}", placement=None,
                http=False, task_timeout_s=task_timeout_s,
                max_attempts=max_attempts, telemetry=telemetry,
                telemetry_interval_s=telemetry_interval_s)
        self._spill_cfg = spillover
        self.spillover: SpilloverController | None = None
        self._bridges: list[SiteBridgeAgent] = []
        self._lock = threading.RLock()
        self._started = False
        self._stopped = False

    def _build_cluster(self, site: Site, **kw: Any) -> KsaCluster:
        merged: dict[str, Any] = dict(
            site=site.name, workers=site.workers,
            worker_slots=site.worker_slots, gpu_workers=site.gpu_workers,
            gpu_slots=site.gpu_slots, slurm=site.slurm,
            autoscale=site.autoscale, monitor=True,
            poll_interval_s=self.poll_interval_s)
        merged.update(kw)
        merged.update(site.cluster_kw)
        return KsaCluster(**merged)

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "FederatedCluster":
        with self._lock:
            if self._stopped:
                raise RuntimeError("FederatedCluster was stopped; "
                                   "create a new instance")
            if self._started:
                raise RuntimeError("FederatedCluster already started")
            self._started = True
            try:
                for cluster in self.clusters.values():
                    cluster.start()
                if self._telemetry_enabled:
                    # the home collector tails every remote site's telemetry
                    # topic directly, so one home /query answers
                    # sum_by("site") across the federation — no extra
                    # merge protocol on top of the metrics one
                    for s in self.remote_sites:
                        remote = self.clusters[s.name]
                        self.home.telemetry_collector.add_feed(
                            remote.broker,
                            topic_names(remote.prefix)["telemetry"],
                            site=s.name)
                for s in self.remote_sites:
                    self._start_bridge(
                        s, role="affinity",
                        profile=self.router.affinity_profile(s.name),
                        slots=self.bridge_slots)
                if self._spill_cfg is not None:
                    self.spillover = SpilloverController(
                        self, self._spill_cfg).start()
                self.home.monitor.attach_federation(self._sites_payload,
                                                    self.metrics_text)
            except BaseException:
                self.stop()
                raise
        return self

    def stop(self, timeout: float = 5.0) -> None:
        """Idempotent teardown: spillover loop first (stop raising
        bridges), then every bridge (stop relaying before the remote
        control planes go away), then remote clusters, home last (its
        monitor is the federated API)."""
        with self._lock:
            if not self._started or self._stopped:
                self._stopped = True
                return
            self._stopped = True
            spill, bridges = self.spillover, list(self._bridges)
        if spill is not None:
            spill.stop(timeout=timeout)
        for b in bridges:
            b.stop(timeout=timeout)
        for name, cluster in self.clusters.items():
            if name != self.home_site.name:
                cluster.stop(timeout=timeout)
        self.home.stop(timeout=timeout)

    def __enter__(self) -> "FederatedCluster":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    @property
    def started(self) -> bool:
        return self._started and not self._stopped

    # -- bridges -----------------------------------------------------------

    def _start_bridge(self, site: Site, *, role: str,
                      profile: ResourceProfile, slots: int
                      ) -> SiteBridgeAgent:
        bridge = SiteBridgeAgent(
            self.home.broker, self.clusters[site.name], site, self.prefix,
            role=role,
            deadline_s=site.tolerance.deadline(self.task_timeout_s),
            remote_poll_s=self.remote_poll_s, profile=profile, slots=slots,
            placement=self.router,
            poll_interval_s=self.poll_interval_s).start()
        with self._lock:
            self._bridges.append(bridge)
        return bridge

    def _start_spill_bridge(self, site: Site, cls: str, *,
                            slots: int) -> SiteBridgeAgent:
        """Raise a bridge draining the home ``cls`` topic to ``site`` (the
        spillover controller's actuator). The taint-exclusive profile makes
        the bridge subscribe to exactly that class topic — it competes with
        the home pool's members in the same consumer group, so overflow
        partitions rebalance to it without touching queued records."""
        return self._start_bridge(
            site, role=f"spill-{cls}",
            profile=ResourceProfile(labels=(cls,), taints=(cls,)),
            slots=slots)

    def _forget_bridge(self, bridge: SiteBridgeAgent) -> None:
        with self._lock:
            if bridge in self._bridges:
                self._bridges.remove(bridge)

    def bridges(self, site: str | None = None) -> list[SiteBridgeAgent]:
        with self._lock:
            return [b for b in self._bridges
                    if site is None or b.site.name == site]

    # -- task API (KsaCluster-compatible) ----------------------------------

    @staticmethod
    def _resources(site: str, input_mb: float,
                   resources: Resources | None,
                   kw: dict) -> Resources | None:
        if resources is None:
            if not site and not input_mb:
                return None
            resources = Resources(cpus=kw.pop("cpus", 1),
                                  gpus=kw.pop("gpus", 0),
                                  mem_mb=kw.pop("mem_mb", 1024),
                                  labels=tuple(kw.pop("labels", ())))
        if site:
            resources.site = site
        if input_mb:
            resources.input_mb = input_mb
        return resources

    def submit(self, script: str, *, site: str = "", input_mb: float = 0.0,
               resources: Resources | None = None, **kw: Any) -> str:
        """Submit one task. ``site`` pins it to a federation member
        (``site=<home>`` forces local execution); ``input_mb`` declares its
        input weight for data-locality scoring and WAN transfer time."""
        res = self._resources(site, input_mb, resources, kw)
        if res is not None:
            kw["resources"] = res
        return self.home.submit(script, **kw)

    def submit_batches(self, script: str, items: Any, *, site: str = "",
                       input_mb: float = 0.0,
                       resources: Resources | None = None,
                       **kw: Any) -> list[str]:
        res = self._resources(site, input_mb, resources, kw)
        if res is not None:
            kw["resources"] = res
        return self.home.submit_batches(script, items, **kw)

    def wait_all(self, task_ids: list[str], timeout: float = 60.0,
                 poll: float = 0.02) -> bool:
        return self.home.wait_all(task_ids, timeout=timeout, poll=poll)

    def task(self, task_id: str):
        return self.home.task(task_id)

    def result(self, task_id: str) -> dict | None:
        return self.home.result(task_id)

    def revoke(self, task_id: str, reason: str = RevokeReason.SCANCEL, *,
               requeue: bool | None = None) -> bool:
        """Operator ``scancel`` at federation scope: revoking the home
        lease cancels a bridge relay too — the bridge revokes the remote
        copy and fences its verdict (see
        :mod:`repro.federation.bridge`)."""
        return self.home.revoke(task_id, reason, requeue=requeue)

    # -- campaigns ---------------------------------------------------------

    @property
    def pipeline(self):
        """The home PipelineAgent — campaign stages pin to sites via
        ``Stage(resources=Resources(site=...))`` and spill like any other
        class-routed work."""
        return self.home.pipeline

    def submit_campaign(self, spec: Any, items: Iterable | None = None,
                        **kw: Any) -> str:
        return self.home.submit_campaign(spec, items, **kw)

    def run_campaign(self, spec: Any, items: Iterable | None = None,
                     **kw: Any):
        return self.home.run_campaign(spec, items, **kw)

    def campaign_status(self, campaign_id: str):
        return self.home.campaign_status(campaign_id)

    def campaign_report(self, campaign_id: str):
        """Home-plane critical path. A relayed task's queue/run split counts
        the WAN relay as run time — the home span closes when the bridge
        commits the returned verdict."""
        return self.home.campaign_report(campaign_id)

    def wait_campaign(self, campaign_id: str, timeout: float = 60.0):
        return self.home.wait_campaign(campaign_id, timeout=timeout)

    # -- observability -----------------------------------------------------

    @property
    def http_port(self) -> int | None:
        return self.home.http_port

    def metrics_text(self) -> str:
        """Federated Prometheus exposition: every site registry's render
        merged with a ``site`` label (served at the home monitor's
        ``GET /metrics``) — one scrape sees queue depths, lease churn, and
        bridge traffic across the whole federation."""
        return merge_renders({name: c.broker.metrics.render()
                              for name, c in self.clusters.items()})

    def query(self, name: str, **kw: Any) -> dict:
        """Query the home telemetry store — carries ``site``-labelled
        series from every federated feed, so ``agg="sum_by", by="site"``
        answers one question across the whole federation."""
        return self.home.query(name, **kw)

    def alerts(self) -> dict:
        """Home alert-engine status (rules evaluate over federated series)."""
        return self.home.alerts()

    def dump_blackbox(self, trigger: str = "manual") -> dict:
        """Force a post-mortem dump of the home flight recorder."""
        return self.home.dump_blackbox(trigger)

    def _sites_payload(self) -> dict:
        """The home monitor's ``GET /sites`` payload."""
        with self._lock:
            bridges = list(self._bridges)
        sites: dict[str, Any] = {}
        for s in self.sites:
            cluster = self.clusters[s.name]
            entry = s.to_dict()
            entry["home"] = s.name == self.home_site.name
            entry["prefix"] = cluster.prefix
            entry["broker"] = cluster.broker.stats()
            entry["leases"] = cluster.broker.lease_stats()
            entry["bridges"] = [
                {"agent_id": b.agent_id, "role": b.role,
                 "deadline_s": b.deadline_s, **b.stats()}
                for b in bridges if b.site.name == s.name]
            sites[s.name] = entry
        out = {"home": self.home_site.name, "sites": sites}
        if self.spillover is not None:
            out["spillover"] = self.spillover.status()
        return out

    def status(self) -> dict:
        """Aggregated federation snapshot: the home cluster's status plus
        the per-site payload ``GET /sites`` serves."""
        out = self.home.status()
        out["federation"] = self._sites_payload()
        return out

    def trace(self, task_id: str) -> list[dict]:
        """Home-plane span chain for a task; a relayed task's remote spans
        live in the remote site's own store
        (``clusters[site].trace(task_id)``)."""
        return self.home.trace(task_id)
