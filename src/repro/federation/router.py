"""SiteRouter — placement across federation sites.

Extends the single-site :class:`~repro.core.scheduling.ResourceClassPolicy`
with one new routing dimension: **which site**. Each remote site gets a
dedicated resource class ``site.<name>`` (and therefore a dedicated class
topic ``PREFIX-new.site.<name>`` on the home broker) that only that site's
bridge subscribes to — site affinity reuses the same taint-exclusive
mechanism that keeps a serve pool from draining batch work, so nothing in
the agents or the broker needs to know about federation for pinning to
work.

Three placement behaviours compose:

* **affinity** — ``Resources(site="b")`` routes to ``site.b`` regardless of
  cpu/gpu class; the site's bridge relays it. Campaign stages pin the same
  way (``Stage(resources=Resources(site=...))``).
* **data locality** — :meth:`spill_score` charges a candidate site for the
  task's ``input_mb`` over its link (latency + size/bandwidth, both ways
  for the result) so a data-heavy task prefers the site holding its input.
* **cost-aware spillover** — unpinned tasks route to their normal cpu/gpu
  class; when the home backlog outruns its drain rate the
  :class:`~repro.federation.SpilloverController` raises *spill bridges*
  that join the same consumer group on those class topics, and
  :meth:`spill_score` ranks which remote site the overflow should drain
  to (cold-start vs slot-seconds vs transfer).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Iterable

from repro.core.scheduling import (PlacementPolicy, ResourceClassPolicy,
                                   ResourceProfile, class_topic)

from .site import Site

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.messages import TaskMessage

__all__ = ["SiteRouter", "site_class"]

_SITE_PREFIX = "site."


def site_class(name: str) -> str:
    """The resource class a remote site's pinned work routes to."""
    return f"{_SITE_PREFIX}{name}"


class SiteRouter(PlacementPolicy):
    """Site-aware placement for a :class:`~repro.federation.FederatedCluster`.

    Wraps a :class:`ResourceClassPolicy` whose extra classes include one
    ``site.<name>`` class per remote site. A task with ``resources.site``
    set to a remote site classifies into that site class; everything else
    (including ``site`` equal to the home site, the explicit "keep it
    local" pin) falls through to the normal cpu/gpu/label classification.
    Subscriptions delegate unchanged, so ordinary pools never see the site
    classes and bridges opt in via taint-exclusive profiles."""

    def __init__(self, sites: Iterable[str], *, home: str,
                 extra_classes: tuple[str, ...] = (),
                 gpu_takes_cpu: bool = True):
        self.home = home
        self.site_names = tuple(sites)
        if home not in self.site_names:
            raise ValueError(
                f"home site {home!r} is not among sites "
                f"{list(self.site_names)}")
        self._remote = tuple(s for s in self.site_names if s != home)
        self._inner = ResourceClassPolicy(
            extra_classes=tuple(extra_classes)
            + tuple(site_class(s) for s in self._remote),
            gpu_takes_cpu=gpu_takes_cpu)

    # -- PlacementPolicy -------------------------------------------------

    def classes(self) -> tuple[str, ...]:
        return self._inner.classes()

    def classify(self, task: "TaskMessage") -> str:
        pin = getattr(task.resources, "site", "")
        if pin and pin != self.home:
            if pin not in self.site_names:
                raise ValueError(
                    f"task {task.task_id}: pinned to unknown site {pin!r} "
                    f"(federation sites: {list(self.site_names)})")
            return site_class(pin)
        return self._inner.classify(task)

    def topics(self, prefix: str) -> tuple[str, ...]:
        return self._inner.topics(prefix)

    def route(self, prefix: str, task: "TaskMessage") -> str:
        return class_topic(prefix, self.classify(task))

    def subscriptions(self, prefix: str,
                      profile: ResourceProfile | None) -> tuple[str, ...]:
        return self._inner.subscriptions(prefix, profile)

    # -- bridge profiles -------------------------------------------------

    def affinity_profile(self, site_name: str) -> ResourceProfile:
        """The taint-exclusive profile an affinity bridge runs with: it
        subscribes *only* to ``PREFIX-new.site.<name>``, so pinned work is
        the only work it ever leases — and no other pool ever drains the
        site class, because no other profile carries the taint."""
        cls = site_class(site_name)
        return ResourceProfile(labels=(cls,), taints=(cls,))

    # -- cost model ------------------------------------------------------

    def spill_score(self, site: Site, task: "TaskMessage" = None, *,
                    est_run_s: float = 1.0) -> float:
        """Cost (modeled seconds) of running one task at ``site`` instead
        of home: cold-start + priced slot-seconds + WAN transfer of the
        task's input there and its (weightless) result back. Lower is
        better; the spillover controller picks the argmin across remote
        sites. A partitioned link is unreachable — ``inf``."""
        if not site.link.up:
            return float("inf")
        input_mb = 0.0
        if task is not None:
            input_mb = float(getattr(task.resources, "input_mb", 0.0) or 0.0)
        transfer = site.link.one_way_s(input_mb) + site.link.one_way_s()
        return site.spinup_s + site.slot_cost * est_run_s + transfer
