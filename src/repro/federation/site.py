"""Site and WAN-link modeling for the federated control plane.

A federation composes N independent KSA deployments — the paper's target
shape, "multiple Slurm-managed HPC clusters and workstations" — where each
:class:`Site` has its own broker, pools, cold-start and cost profile, and
sits behind a modeled :class:`WanLink`. The link is the part a single-site
deployment never has to think about: latency delays every task/result
relay, bandwidth charges each task's ``Resources.input_mb``, and a
partition (``link.partition()`` / ``link.heal()``) blocks relays entirely
while leaving both sites' local control planes running — the scenario the
WAN-tolerant lease deadline (:class:`~repro.core.lease.LeaseTolerance`)
exists for.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Mapping

from repro.core.lease import LeaseTolerance

__all__ = ["Site", "WanLink"]


class WanLink:
    """One site's WAN connection to the federation's home site.

    Latency/bandwidth are a fixed one-way model: shipping ``mb`` megabytes
    takes ``latency_s + mb * 8 / bandwidth_mbps`` seconds each way. The
    ``up`` flag is mutable at runtime — :meth:`partition` / :meth:`heal`
    simulate a WAN cut; bridges stop relaying (and stop heartbeating on
    behalf of remote work) while the link is down.
    """

    def __init__(self, latency_s: float = 0.0,
                 bandwidth_mbps: float = 1000.0) -> None:
        if latency_s < 0:
            raise ValueError(f"latency_s must be >= 0 (got {latency_s!r})")
        if bandwidth_mbps <= 0:
            raise ValueError(
                f"bandwidth_mbps must be > 0 (got {bandwidth_mbps!r})")
        self.latency_s = latency_s
        self.bandwidth_mbps = bandwidth_mbps
        self._down = threading.Event()

    @property
    def up(self) -> bool:
        return not self._down.is_set()

    def partition(self) -> None:
        """Cut the link: bridge relays block until :meth:`heal`."""
        self._down.set()

    def heal(self) -> None:
        self._down.clear()

    def one_way_s(self, mb: float = 0.0) -> float:
        """Modeled one-way delivery time for ``mb`` megabytes."""
        return self.latency_s + (mb * 8.0) / self.bandwidth_mbps

    def round_trip_s(self, mb: float = 0.0) -> float:
        return self.one_way_s(mb) + self.one_way_s()

    def to_dict(self) -> dict:
        return {"latency_s": self.latency_s,
                "bandwidth_mbps": self.bandwidth_mbps,
                "up": self.up}

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        state = "up" if self.up else "DOWN"
        return (f"WanLink(latency_s={self.latency_s}, "
                f"bandwidth_mbps={self.bandwidth_mbps}, {state})")


@dataclass
class Site:
    """Declarative description of one federation member.

    The first site passed to :class:`~repro.federation.FederatedCluster` is
    the **home** site: submissions enter there, its monitor serves the
    federated REST API, and its broker holds the authoritative lease per
    task. Every other site is remote — work reaches it only through a
    bridge, pinned (``Resources.site``) or spilled
    (:class:`~repro.federation.SpilloverController`).

    ``workers``/``gpu_workers``/``slurm``/``autoscale`` provision the
    site's pools exactly like the same-named :class:`~repro.cluster.
    KsaCluster` kwargs. ``spinup_s`` is the modeled cold-start a spill
    decision charges against this site (a Slurm site's node spin-up; pass
    the same value inside ``slurm`` to actually simulate it), ``slot_cost``
    the relative price of one slot-second there, and ``tolerance`` the
    WAN-lease policy knob: how much longer than the home watchdog deadline
    a lease held across this site's ``link`` may go quiet before it is
    presumed dead."""

    name: str
    workers: int = 0
    worker_slots: int = 2
    gpu_workers: int = 0
    gpu_slots: int = 1
    slurm: Mapping[str, Any] | None = None
    autoscale: Any = None                  # AutoscaleConfig | None
    link: WanLink = field(default_factory=WanLink)
    spinup_s: float = 0.0
    slot_cost: float = 1.0
    tolerance: LeaseTolerance = field(default_factory=LeaseTolerance)
    cluster_kw: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.name or "." in self.name:
            # site names become resource-class suffixes ("site.<name>") and
            # metric label values; a dot would collide with the class-topic
            # separator
            raise ValueError(
                f"site name must be non-empty and dot-free (got "
                f"{self.name!r})")

    @property
    def slots(self) -> int:
        """Nominal local slot count (workers only; a Slurm site's capacity
        lives in the simulator) — used for spill scoring, not admission."""
        return (self.workers * self.worker_slots
                + self.gpu_workers * self.gpu_slots)

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "workers": self.workers,
            "gpu_workers": self.gpu_workers,
            "slurm": dict(self.slurm) if self.slurm else None,
            "link": self.link.to_dict(),
            "spinup_s": self.spinup_s,
            "slot_cost": self.slot_cost,
            "tolerance": {"slack_s": self.tolerance.slack_s,
                          "rtt_factor": self.tolerance.rtt_factor},
        }
