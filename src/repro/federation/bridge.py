"""SiteBridgeAgent — the home-side proxy that executes tasks on a remote
site.

A bridge is an ordinary :class:`~repro.core.agents.AgentBase` member of the
home consumer group, so every task it leases holds a real home-broker
lease — the home lease stays **the** authority for the task's lifecycle,
which is what makes cross-site execution exactly-once without a distributed
protocol:

* the bridge registers itself via :meth:`Broker.register_holder_site`, so
  its leases are stamped with the remote site and the site's WAN-tolerant
  deadline (:class:`~repro.core.lease.LeaseTolerance`) — the home watchdogs
  wait longer before presuming a relay dead;
* a home-side revocation (watchdog, preemption, drain) fires the lease's
  cancel event exactly as for a local worker; the relay thread notices,
  revokes the remote copy (``requeue=False`` — the home revoker owns the
  redelivery decision), and drops whatever verdict the remote produces;
* the remote verdict only reaches the home ``-done``/``-error`` topics
  through the home :meth:`Broker.complete_lease` gate, so a verdict racing
  a revocation is fenced at the same single commit point as everything
  else — a task preempted from site A and re-run locally can never also
  commit from site B.

The relay models the WAN explicitly: shipping the task charges
``latency + input_mb/bandwidth`` against the site's link, the result pays
the return latency, and a partitioned link blocks relays *and* the
bridge's home-bound heartbeats (the bridge cannot vouch for an execution
it cannot see) — which is exactly the silence the per-site lease deadline
must tolerate.
"""
from __future__ import annotations

import logging
import threading
import time
from typing import TYPE_CHECKING, Any

from repro.core.agents import AgentBase, _Running
from repro.core.lease import RevokeReason
from repro.core.messages import (ErrorMessage, ResultMessage, TaskMessage,
                                 TaskStatus)

from .site import Site

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.cluster import KsaCluster
    from repro.core.broker import Broker

log = logging.getLogger(__name__)

__all__ = ["SiteBridgeAgent"]


class SiteBridgeAgent(AgentBase):
    """Relays leased tasks to one remote site and their verdicts back.

    ``role`` distinguishes *affinity* bridges (taint-exclusive profile —
    only ``site.<name>``-pinned work, always running) from *spill* bridges
    (cpu/gpu-class profile, raised and drained by the
    :class:`~repro.federation.SpilloverController`). ``slots`` bounds how
    many relays are in flight — effectively the WAN-side admission window
    onto the remote site."""

    kind = "bridge"

    def __init__(self, broker: "Broker", remote: "KsaCluster", site: Site,
                 prefix: str = "ksa", *, role: str = "affinity",
                 deadline_s: float | None = None,
                 remote_poll_s: float = 0.02, **kw: Any):
        kw.setdefault("agent_id",
                      f"bridge-{site.name}-{role}-{id(self) & 0xffff:04x}")
        super().__init__(broker, prefix, **kw)
        if remote.monitor is None:
            raise ValueError(
                f"site {site.name!r}: bridges need the remote cluster's "
                f"monitor (built with monitor=False)")
        self.remote = remote
        self.site = site
        self.role = role
        self.deadline_s = deadline_s
        self.remote_poll_s = remote_poll_s
        events = broker.metrics.counter(
            "ksa_bridge_events_total",
            "Per-bridge cross-site relay events",
            labels=("bridge", "site", "event"))
        self._b = {e: events.labels(bridge=self.agent_id, site=site.name,
                                    event=e)
                   for e in ("relayed", "returned", "errored", "fenced",
                             "remote_revoked")}
        # stamp this member's leases with the site + WAN deadline before the
        # first lease is granted
        broker.register_holder_site(self._consumer.member_id, site.name,
                                    deadline_s)

    # -- AgentBase overrides ------------------------------------------------

    def _routable(self, task: TaskMessage) -> bool:
        # the bridge is a forwarder, not an executor: whatever it leases is
        # shipped whole, and the *remote* site's own placement policy routes
        # it to the right class topic there — profile can_run() semantics
        # (which would bounce site-pinned work lacking the taint label) do
        # not apply
        return True

    def _heartbeat_running(self) -> None:
        # a partitioned link means the bridge cannot observe the remote
        # execution, so it must not vouch for it either — heartbeats stop,
        # staleness accrues at the home monitor, and the stamped per-site
        # deadline (not the uniform one) decides when that silence becomes
        # a revocation
        if not self.site.link.up:
            return
        super()._heartbeat_running()

    def _watchdog(self) -> None:
        # same split as AgentBase._watchdog, but the WAN-tolerant deadline
        # scales the task timeout: a relay legitimately spends link time on
        # top of compute time. No mem policing — bridges run nothing.
        now = time.time()
        with self._lock:
            items = list(self._running.items())
        for tid, run in items:
            timeout = run.task.timeout_s or self.default_timeout_s
            if timeout is None:
                continue
            allowed = self.site.tolerance.deadline(timeout) or timeout
            if now - run.started_at > allowed and not run.cancel.is_set():
                log.warning("bridge %s: relay %s exceeded %.1fs — revoking",
                            self.agent_id, tid, allowed)
                if not self._revoke_run(run, RevokeReason.WATCHDOG,
                                        requeue=False):
                    self._cancel_task(run)
                self._send_status(run.task, TaskStatus.TIMEOUT,
                                  timeout_s=allowed, site=self.site.name)

    def stop(self, timeout: float = 5.0) -> None:
        super().stop(timeout=timeout)
        self.broker.unregister_holder_site(self._consumer.member_id)

    # -- relay ------------------------------------------------------------

    def _accept(self, task: TaskMessage) -> None:
        cancel = threading.Event()
        member = self._consumer.member_id
        if not self.broker.claim_start(task.task_id, member, task.attempt,
                                       cancel):
            self._c["dropped_revoked"].inc()
            return
        run = _Running(task=task, cancel=cancel)
        with self._lock:
            self._running[task.task_id] = run
        self._send_status(task, TaskStatus.WAITING, site=self.site.name,
                          bridge=self.agent_id)
        t = threading.Thread(target=self._relay, args=(run,),
                             name=f"{self.agent_id}-{task.task_id}",
                             daemon=True)
        run.thread = t
        t.start()

    def _wait_link(self, duration_s: float, cancel: threading.Event) -> bool:
        """Spend ``duration_s`` of link *uptime* (transfer does not progress
        across a partition); False if the home lease is cancelled first."""
        remaining = duration_s
        while True:
            if cancel.is_set():
                return False
            if not self.site.link.up:
                time.sleep(0.005)
                continue
            if remaining <= 0:
                return True
            step = min(0.01, remaining)
            time.sleep(step)
            remaining -= step

    def _remote_copy(self, task: TaskMessage) -> TaskMessage:
        """The task as the remote site sees it: re-routed locally there
        (the site pin is consumed by crossing the link) and detached from
        its campaign — the remote control plane retries it on its own flat
        budget, while DAG bookkeeping stays with the home PipelineAgent,
        which matches the relayed result by task_id."""
        copy = TaskMessage.from_dict(task.to_dict())
        copy.resources.site = ""
        copy.campaign_id = None
        copy.stage = None
        copy.dep_ids = []
        return copy

    def _abort_remote(self, task: TaskMessage, submitted: bool,
                      reason: str) -> None:
        """Cross-site revocation: fence/cancel the remote copy so a home
        revocation cannot leave site B finishing (and committing) work that
        site A's requeue is about to re-run. Revocation is control
        traffic — delivered in-process even while the data link is
        partitioned."""
        if not submitted:
            return
        try:
            if self.remote.broker.revoke_lease(task.task_id, reason,
                                               requeue=False):
                self._b["remote_revoked"].inc()
        except Exception:  # pragma: no cover - defensive
            log.exception("bridge %s: remote revoke of %s failed",
                          self.agent_id, task.task_id)

    def _drop_fenced(self, task: TaskMessage) -> None:
        with self._lock:
            self._running.pop(task.task_id, None)
        self._b["fenced"].inc()
        self._c["dropped_revoked"].inc()

    def _relay(self, run: _Running) -> None:
        task, cancel = run.task, run.cancel
        member = self._consumer.member_id
        started = time.time()
        submitted = False
        try:
            # 1. ship the input across the link
            input_mb = float(getattr(task.resources, "input_mb", 0.0) or 0.0)
            if not self._wait_link(self.site.link.one_way_s(input_mb),
                                   cancel):
                self._abort_remote(task, submitted, RevokeReason.PREEMPT)
                self._drop_fenced(task)
                return
            # 2. submit on the remote site (same task_id/attempt: the remote
            # lease table fences its own local races; the home lease fences
            # the federation-level ones)
            try:
                self.remote.submitter.submit_task(self._remote_copy(task))
            except Exception as exc:
                self._fail_home(run, started,
                                f"remote submit failed at site "
                                f"{self.site.name}: {exc!r}")
                return
            submitted = True
            self._b["relayed"].inc()
            self._send_status(task, TaskStatus.RUNNING, site=self.site.name,
                              relayed=True)
            # 3. await the remote verdict (blind while the link is down)
            while True:
                if cancel.is_set():
                    self._abort_remote(task, submitted, RevokeReason.PREEMPT)
                    self._drop_fenced(task)
                    return
                if not self.site.link.up:
                    time.sleep(self.remote_poll_s)
                    continue
                e = self.remote.monitor.task(task.task_id)
                if e is not None and e.done:
                    break
                if e is not None and not e.done and e.errors and \
                        e.status == TaskStatus.ERROR.value and \
                        e.attempts_seen >= self.remote.max_attempts:
                    # the remote site exhausted its own retry budget
                    self._fail_home(run, started,
                                    f"site {self.site.name}: "
                                    f"{e.errors[-1].get('error', 'failed')}")
                    return
                time.sleep(self.remote_poll_s)
            # 4. the result pays the return latency
            if not self._wait_link(self.site.link.one_way_s(), cancel):
                self._abort_remote(task, submitted, RevokeReason.PREEMPT)
                self._drop_fenced(task)
                return
            # 5. home commit gate — the single exactly-once authority
            if not self.broker.complete_lease(task.task_id, member,
                                              task.attempt, ok=True):
                # revoked while the result was in flight: the stale verdict
                # must not leave the bridge, and the remote lease is already
                # terminal (it finished) so there is nothing to revoke
                self._drop_fenced(task)
                return
            res = ResultMessage(task_id=task.task_id, agent_id=self.agent_id,
                                result=dict(e.result or {}),
                                attempt=task.attempt,
                                elapsed_s=time.time() - started)
            self._producer.send(self.topics["done"], res.to_dict(),
                                key=task.task_id)
            self._b["returned"].inc()
            self._finish(task, True)
        except Exception:  # pragma: no cover - defensive
            log.exception("bridge %s: relay of %s crashed", self.agent_id,
                          task.task_id)
            self._abort_remote(task, submitted, RevokeReason.WATCHDOG)
            with self._lock:
                self._running.pop(task.task_id, None)

    def _fail_home(self, run: _Running, started: float, error: str) -> None:
        task = run.task
        member = self._consumer.member_id
        if not self.broker.complete_lease(task.task_id, member, task.attempt,
                                          ok=False):
            self._drop_fenced(task)
            return
        err = ErrorMessage(task_id=task.task_id, agent_id=self.agent_id,
                           error=error, attempt=task.attempt)
        self._producer.send(self.topics["error"], err.to_dict(),
                            key=task.task_id)
        self._b["errored"].inc()
        self._finish(task, False)
