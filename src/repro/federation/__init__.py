"""repro.federation — multi-site control plane over independent KSA sites.

Composes N single-site deployments (each a full
:class:`~repro.cluster.KsaCluster`: own broker, pools, monitor) into one
federation behind the familiar API:

* :class:`Site` / :class:`WanLink` — declarative site description: pools,
  cold-start and slot cost, a modeled WAN link (latency, bandwidth,
  partitionable), and a :class:`~repro.core.lease.LeaseTolerance` for
  WAN-tolerant lease deadlines.
* :class:`SiteRouter` — placement with site affinity (``Resources.site``
  pins route to a per-site class), data locality (``Resources.input_mb``
  priced against link bandwidth), and spill scoring (cold-start vs
  slot-seconds vs transfer).
* :class:`~repro.federation.bridge.SiteBridgeAgent` — the home-side relay
  that ships leased tasks to a remote site and gates their verdicts back
  through the home lease, keeping exactly-once across sites.
* :class:`SpilloverConfig` / :class:`SpilloverController` — backlog vs
  drain-rate sensing that borrows the cheapest remote site's capacity
  when the home site falls behind, and hands it back when idle.
* :class:`FederatedCluster` — the facade wiring all of it, serving
  federated ``/sites`` and site-labelled ``/metrics`` from the home
  monitor.
"""
from .bridge import SiteBridgeAgent
from .cluster import FederatedCluster
from .router import SiteRouter, site_class
from .site import Site, WanLink
from .spillover import SpilloverConfig, SpilloverController

__all__ = [
    "FederatedCluster",
    "Site",
    "SiteBridgeAgent",
    "SiteRouter",
    "SpilloverConfig",
    "SpilloverController",
    "WanLink",
    "site_class",
]
