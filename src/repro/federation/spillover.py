"""Cost-aware spillover — the federation's cross-site load balancer.

Same sense/decide/act shape as the single-site
:class:`~repro.autoscale.AutoscaleController`, but the actuator is a
*spill bridge* instead of a local worker: when a home resource class's
backlog outruns its drain rate (the backlog would take longer than
``horizon_s`` to clear at the observed consumption rate, measured with the
same :class:`~repro.autoscale.RateTracker` primitive the autoscaler uses),
the controller raises a :class:`~repro.federation.bridge.SiteBridgeAgent`
on that class topic at the cheapest remote site —
:meth:`~repro.federation.SiteRouter.spill_score` weighs cold-start
(``Site.spinup_s``) vs slot-seconds (``Site.slot_cost``) vs WAN transfer
(link latency + input weight / bandwidth), and a partitioned site is
unreachable. Once the class has been idle for ``drain_idle_s`` the bridge
is gracefully drained (finishing its in-flight relays), so a burst borrows
remote capacity and hands it back.

Spillover and local autoscale compose: both watch the same class-topic
depth, so an autoscaled home pool absorbs what it can and the spillover
horizon decides when waiting for local elasticity is slower than paying
the WAN.
"""
from __future__ import annotations

import logging
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any

from repro.autoscale.rate import RateTracker
from repro.core.scheduling import class_topic

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .bridge import SiteBridgeAgent
    from .cluster import FederatedCluster

log = logging.getLogger(__name__)

__all__ = ["SpilloverConfig", "SpilloverController"]

_LONG_AGO = -1e12


@dataclass(frozen=True)
class SpilloverConfig:
    """Policy knobs for backlog-driven cross-site spillover.

    ``horizon_s`` is the service-level target: spill when the class backlog
    would take longer than this to drain at the observed rate (or when
    there is backlog but no observed drain at all). ``min_backlog`` guards
    against spilling a trickle; ``est_run_s`` prices a task's slot-seconds
    in the spill score; ``max_bridges_per_class`` bounds how many spill
    bridges one class runs at once. Bridges are consumer-group *members*
    — partitions are what rebalance to them — so sustained pressure adds
    bridges one per cooldown (each scored independently; several may land
    on the same cheap site) exactly like the autoscaler adds workers."""

    classes: tuple[str, ...] = ("cpu",)
    horizon_s: float = 5.0
    min_backlog: int = 4
    interval_s: float = 0.25
    rate_window_s: float = 5.0
    cooldown_s: float = 1.0
    drain_idle_s: float = 1.0
    bridge_slots: int = 4
    max_bridges_per_class: int = 1
    est_run_s: float = 1.0
    history: int = 256


class _ClassState:
    """Controller-private runtime state of one spilling resource class."""

    def __init__(self, cfg: SpilloverConfig):
        self.consumed = RateTracker(cfg.rate_window_s, cfg.history)
        self.bridges: list["SiteBridgeAgent"] = []
        self.draining: list["SiteBridgeAgent"] = []
        self.last_spill = _LONG_AGO
        self.idle_since: float | None = None
        self.spills = 0
        self.releases = 0


class SpilloverController:
    """Watches the home class topics and borrows remote capacity.

    Built by :class:`~repro.federation.FederatedCluster` when a
    :class:`SpilloverConfig` is passed; :meth:`tick` is public so tests can
    drive the loop deterministically (never :meth:`start` it then)."""

    def __init__(self, fed: "FederatedCluster", config: SpilloverConfig):
        self.fed = fed
        self.config = config
        known = set(fed.router.classes())
        for cls in config.classes:
            if cls not in known:
                raise ValueError(
                    f"spillover class {cls!r} is not a resource class of "
                    f"the federation's router (known: {sorted(known)})")
        self._classes = {cls: _ClassState(config)
                         for cls in config.classes}
        self._decisions: deque[dict] = deque(maxlen=128)
        self._group = f"{fed.prefix}-agents"
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.ticks = 0
        metrics = fed.home.broker.metrics
        self._c_spill = metrics.counter(
            "ksa_spillover_decisions_total",
            "Spillover decisions, by class, site and direction",
            labels=("cls", "site", "action"))
        self._g_bridges = metrics.gauge(
            "ksa_spill_bridges", "Active spill bridges per resource class",
            labels=("cls",))

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "SpilloverController":
        self._thread = threading.Thread(target=self._loop,
                                        name="spillover-loop", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 5.0) -> None:
        """Stop the control loop. Bridges stay registered on the facade —
        the federation's own teardown stops them."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                self.tick()
            except Exception:  # pragma: no cover - defensive
                log.exception("spillover tick failed")
            self._stop.wait(self.config.interval_s)

    # -- sense / decide / act ----------------------------------------------

    def tick(self) -> None:
        """One pass: sample depth/drain per class, raise a spill bridge at
        the cheapest reachable site under pressure, drain idle bridges."""
        now = time.time()
        cfg = self.config
        topics = {cls: class_topic(self.fed.prefix, cls)
                  for cls in self._classes}
        qs = self.fed.home.broker.queue_stats(self._group,
                                              list(topics.values()))
        with self._lock:
            self.ticks += 1
            for cls, st in self._classes.items():
                self._reap(st)
                stats = qs[topics[cls]]
                depth = stats["depth"]
                st.consumed.sample(now, stats["consumed"])
                rate = st.consumed.rate(now)
                in_flight = sum(b.stats()["in_flight"] for b in st.bridges)
                if depth > 0 or in_flight > 0:
                    st.idle_since = None
                elif st.idle_since is None:
                    st.idle_since = now
                pressure = depth >= cfg.min_backlog and (
                    rate <= 0.0 or depth / rate > cfg.horizon_s)
                if pressure and \
                        len(st.bridges) < cfg.max_bridges_per_class and \
                        now - st.last_spill >= cfg.cooldown_s:
                    self._spill(cls, st, depth, rate)
                elif st.bridges and st.idle_since is not None and \
                        now - st.idle_since >= cfg.drain_idle_s:
                    self._release(cls, st)
                self._g_bridges.labels(cls=cls).set(len(st.bridges))

    def _reap(self, st: _ClassState) -> None:
        for b in list(st.draining):
            if not b.alive:
                st.draining.remove(b)
                self.fed._forget_bridge(b)
        for b in list(st.bridges):
            if not b.alive:  # crashed / externally stopped
                st.bridges.remove(b)
                self.fed._forget_bridge(b)

    def _spill(self, cls: str, st: _ClassState, depth: int,
               rate: float) -> None:
        score, site = min(
            ((self.fed.router.spill_score(s,
                                          est_run_s=self.config.est_run_s),
              s) for s in self.fed.remote_sites),
            key=lambda pair: pair[0])
        if score == float("inf"):
            return  # every candidate site is partitioned
        bridge = self.fed._start_spill_bridge(
            site, cls, slots=self.config.bridge_slots)
        st.bridges.append(bridge)
        st.last_spill = time.time()
        st.spills += 1
        self._record(cls, site.name, "spill",
                     f"backlog {depth} vs drain {rate:.1f}/s "
                     f"(score {score:.3f})")

    def _release(self, cls: str, st: _ClassState) -> None:
        for b in list(st.bridges):
            st.bridges.remove(b)
            b.request_drain()
            st.draining.append(b)
            st.releases += 1
            self._record(cls, b.site.name, "release",
                         f"idle {self.config.drain_idle_s:.2f}s")
        st.idle_since = None

    def _record(self, cls: str, site: str, action: str, reason: str) -> None:
        self._decisions.append({"ts": time.time(), "cls": cls, "site": site,
                                "action": action, "reason": reason})
        self._c_spill.labels(cls=cls, site=site, action=action).inc()
        # blackbox: spill decisions are exactly the context a post-mortem
        # of a WAN incident needs next to the revocations
        self.fed.home.broker.blackbox.record(
            "spill_decision", cls=cls, site=site, action=action,
            reason=reason)
        log.info("spillover %s: %s -> %s (%s)", cls, action, site, reason)

    # -- observability -----------------------------------------------------

    def bridge_count(self, cls: str) -> int:
        with self._lock:
            return len(self._classes[cls].bridges)

    def status(self) -> dict:
        """The spillover slice of the ``GET /sites`` payload."""
        now = time.time()
        with self._lock:
            classes = {
                cls: {
                    "bridges": [{"site": b.site.name,
                                 "agent_id": b.agent_id}
                                for b in st.bridges],
                    "draining": [b.agent_id for b in st.draining],
                    "drain_rate": st.consumed.rate(now),
                    "spills": st.spills,
                    "releases": st.releases,
                }
                for cls, st in self._classes.items()}
            return {
                "ticks": self.ticks,
                "horizon_s": self.config.horizon_s,
                "classes": classes,
                "decisions": list(self._decisions),
            }
