"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every
(architecture × shape) dry-run cell — weak-type-correct, shardable, zero
device allocation.

Each cell resolves to a :class:`CellSpec`: the step callable, its abstract
arguments, in/out shardings, and donation — everything ``dryrun.py`` needs to
``jit(...).lower(...).compile()``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import Shape, get_config
from repro.models.config import ModelConfig
from repro.models.transformer import init_caches
from repro.optim import OptimizerConfig
from repro.sharding import DistContext, state_axes
from repro.train.step import (make_prefill_step, make_serve_step,
                              make_train_step, train_state_shapes)


@dataclass
class CellSpec:
    fn: Callable
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple[int, ...] = ()
    static_notes: dict = field(default_factory=dict)


def optimizer_analytic_costs(cfg: ModelConfig, ocfg: OptimizerConfig,
                             accum_dtype: str, n_devices: int) -> dict:
    """Per-device FLOPs/bytes of the AdamW apply (pure elementwise over
    sharded state — no collectives). Counted analytically because the
    costing compiles cover only the fwd/bwd microbatch."""
    n = cfg.param_count(active_only=False)
    p_b = jnp.dtype(cfg.dtype).itemsize
    m_b = jnp.dtype(ocfg.moment_dtype).itemsize
    g_b = jnp.dtype(accum_dtype).itemsize
    v_b = 0.01 * m_b if ocfg.factored_v else m_b
    mst_b = (0 if ocfg.master_dtype == "none"
             else jnp.dtype(ocfg.master_dtype).itemsize)
    per_param_bytes = (g_b            # read grads
                       + 2 * p_b      # read + write params
                       + 2 * m_b      # read + write m
                       + 2 * v_b      # read + write v
                       + 2 * mst_b)   # read + write master
    return {
        "flops_per_device": 12.0 * n / n_devices,
        "bytes_per_device": per_param_bytes * n / n_devices,
        "collective_bytes": 0.0,
    }


def optimizer_for(cfg: ModelConfig) -> OptimizerConfig:
    """Memory policy per scale (see DESIGN.md / EXPERIMENTS.md §Dry-run):
    the 671B config uses bf16 moments + factored second moment and no
    separate master copy — plain fp32 Adam does not fit 256×16 GB."""
    if cfg.name.startswith("deepseek"):
        return OptimizerConfig(moment_dtype="bfloat16", factored_v=True,
                               master_dtype="none")
    return OptimizerConfig()


def train_knobs(cfg: ModelConfig) -> dict:
    """remat / microbatch / accum dtype per arch for the train_4k cell.

    µ is sized so the per-microbatch fp32 logits working set (the CE loss
    block, ~15-19 logit-sized buffers live through backward — measured via
    memory_analysis bisection) stays within HBM: large-vocab/small-d archs
    (Gemma-3, InternVL) need µ=16."""
    if cfg.name.startswith("deepseek"):
        return {"remat": "full", "microbatch": 16, "accum_dtype": "bfloat16"}
    if cfg.name.startswith(("moonshot",)):
        return {"remat": "full", "microbatch": 8, "accum_dtype": "float32"}
    if cfg.padded_vocab >= 128_000 and cfg.d_model <= 4096:
        return {"remat": "full", "microbatch": 16, "accum_dtype": "float32"}
    return {"remat": "full", "microbatch": 4, "accum_dtype": "float32"}


def resolve_knobs(cfg: ModelConfig, dist: DistContext, global_batch: int,
                  overrides: dict | None = None) -> dict:
    """Clamp µ so each microbatch still shards over *all* batch axes —
    µ=16 on a 2×16×16 mesh would leave microbatches of 16 shardable over
    the pod axis only (16× per-device activation blowup, caught by the
    multi-pod dry-run)."""
    knobs = dict(train_knobs(cfg), **(overrides or {}))
    from repro.sharding.context import _size
    n_shards = _size(dist.mesh, dist.batch_axes)
    mu_max = max(1, global_batch // n_shards)
    mu = min(int(knobs.get("microbatch") or 1), mu_max)
    while mu > 1 and (global_batch // mu) % n_shards != 0:
        mu -= 1
    knobs["microbatch"] = mu
    return knobs


# ---------------------------------------------------------------------------
# batch construction
# ---------------------------------------------------------------------------

def _i32(shape):  # tokens / labels
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def batch_specs(cfg: ModelConfig, b: int, s: int) -> dict:
    """Abstract training/prefill batch for one global step."""
    if cfg.frontend is not None and cfg.frontend.kind == "audio_frames":
        return {"embeds": _f32((b, s, cfg.frontend.input_dim)),
                "labels": _i32((b, s))}
    if cfg.frontend is not None and cfg.frontend.kind == "vit_patches":
        n_p = cfg.frontend.n_positions
        s_txt = max(s - n_p, 8)
        return {"embeds": _f32((b, n_p, cfg.frontend.input_dim)),
                "tokens": _i32((b, s_txt)),
                "labels": _i32((b, s_txt))}
    return {"tokens": _i32((b, s)), "labels": _i32((b, s))}


def batch_shardings(dist: DistContext, batch: dict, b: int) -> dict:
    return {k: dist.named(dist.batch_pspec(v.ndim, b))
            for k, v in batch.items()}


# ---------------------------------------------------------------------------
# cache shardings
# ---------------------------------------------------------------------------

def cache_sharding_tree(dist: DistContext, cfg: ModelConfig,
                        shapes: Any, batch: int) -> Any:
    """Shard caches: batch over data axes (dim 1 under stacked 'periods',
    dim 0 under 'tail'); kv-head dims over model when divisible, otherwise
    the cache *sequence* dim is sharded over model (a 32k×128 GQA cache with
    8 kv-heads would otherwise replicate 16× over the model axis and blow the
    HBM budget — caught by the dry-run memory analysis)."""
    tp = dist.tp_axis

    def one(path, sds):
        keys = [getattr(p, "key", None) for p in path]
        stacked = "periods" in keys
        bdim = 1 if stacked else 0
        shape = sds.shape
        spec: list = [None] * len(shape)
        from repro.sharding.rules import batch_spec as _bs
        bs = _bs(1, dist.batch_axes, shape[bdim], dist.mesh)[0]
        spec[bdim] = bs
        is_kv = len(shape) >= 4 and keys[-1] in ("k", "v")
        is_mla = keys[-1] in ("c_kv", "k_rope") and len(shape) >= 3
        if is_kv and cfg.n_kv_heads and shape[-2] == cfg.n_kv_heads:
            if cfg.n_kv_heads % dist.tp_size == 0:
                spec[-2] = tp
            elif shape[-3] % dist.tp_size == 0:  # seq dim
                spec[-3] = tp
        elif is_mla and shape[bdim + 1] % dist.tp_size == 0:
            spec[bdim + 1] = tp  # MLA latent cache: seq over model
        return dist.named(P(*spec))

    return jax.tree_util.tree_map_with_path(one, shapes)


def decode_cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    return jax.eval_shape(
        lambda: init_caches(cfg, batch, max_len, jnp.dtype(cfg.dtype)))


# ---------------------------------------------------------------------------
# the cells
# ---------------------------------------------------------------------------


def reduced_depth(cfg: ModelConfig, n_periods: int) -> ModelConfig:
    """Same arch at ``n_periods`` scan periods (remainder layers preserved) —
    the costing-compile trick: FLOPs/bytes/collectives are *exactly* linear in
    the period count, so two shallow unrolled compiles extrapolate to full
    depth (XLA's cost_analysis counts while bodies once; see dryrun.py)."""
    return cfg.with_(n_layers=cfg.period * n_periods + cfg.n_remainder)


def make_cell(arch: str, shape: Shape, dist: DistContext, *,
              overrides: dict | None = None,
              costing_periods: int | None = None) -> CellSpec:
    """``costing_periods``: build the reduced-depth, fully-unrolled costing
    variant instead of the deliverable rolled-scan program. For train cells
    the costing program is value_and_grad of ONE microbatch (the per-step
    totals are reassembled in dryrun.py as µ × fb + analytic optimizer)."""
    cfg = get_config(arch)
    overrides = dict(overrides or {})
    # generic ModelConfig knob overrides (hillclimb variants)
    for key in ("score_dtype", "kv_chunk"):
        if key in overrides:
            cfg = cfg.with_(**{key: overrides.pop(key)})
    b, s = shape.global_batch, shape.seq_len
    costing = costing_periods is not None
    if costing:
        cfg = reduced_depth(cfg, costing_periods)
    unroll = True if costing else 1

    if shape.step == "train":
        ocfg = optimizer_for(cfg)
        knobs = resolve_knobs(cfg, dist, b, overrides)
        if costing:
            import jax as _jax
            mb = max(1, knobs.get("microbatch") or 1)
            b_mb = max(b // mb, 1)
            batch = batch_specs(cfg, b_mb, s)
            batch_sh = batch_shardings(dist, batch, b_mb)
            from repro.models.params import param_shapes as pshapes
            from repro.models.transformer import model_spec as mspec
            from repro.sharding.state import params_axes as paxes
            p_shapes = pshapes(mspec(cfg), jnp.dtype(cfg.dtype))
            p_sh = dist.param_shardings(p_shapes, paxes(cfg))
            from repro.train.step import _loss_fn
            aux_w = (cfg.moe.router_aux_weight if cfg.moe is not None
                     else 0.0)

            def fb(params, bt):
                (loss, m), g = _jax.value_and_grad(
                    lambda p: _loss_fn(p, cfg, bt, dist, knobs["remat"],
                                       aux_w, True),
                    has_aux=True)(params)
                return loss, g

            return CellSpec(fn=fb, args=(p_shapes, batch),
                            in_shardings=(p_sh, batch_sh),
                            out_shardings=None,
                            static_notes={"step": "train-fb",
                                          "microbatch": mb})
        state_shapes = train_state_shapes(cfg, ocfg)
        st_axes = state_axes(cfg, ocfg)
        state_sh = dist.param_shardings(state_shapes, st_axes)
        batch = batch_specs(cfg, b, s)
        batch_sh = batch_shardings(dist, batch, b)
        fn = make_train_step(cfg, ocfg, dist=dist, unroll=unroll, **knobs)
        return CellSpec(
            fn=fn, args=(state_shapes, batch),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
            static_notes={"knobs": knobs, "step": "train"},
        )

    # inference cells share abstract params (no optimizer)
    from repro.models.params import param_shapes as pshapes
    from repro.models.transformer import model_spec
    from repro.sharding.state import params_axes
    p_shapes = pshapes(model_spec(cfg), jnp.dtype(cfg.dtype))
    p_sh = dist.param_shardings(p_shapes, params_axes(cfg))

    if shape.step == "prefill":
        batch = batch_specs(cfg, b, s)
        batch_sh = batch_shardings(dist, batch, b)
        fn = make_prefill_step(cfg, dist=dist, unroll=unroll)
        if cfg.encoder_only:
            return CellSpec(fn=fn, args=(p_shapes, batch),
                            in_shardings=(p_sh, batch_sh),
                            out_shardings=None,
                            static_notes={"step": "prefill"})
        batch.pop("labels", None)
        batch_sh.pop("labels", None)
        caches = decode_cache_shapes(cfg, b, s)
        caches_sh = cache_sharding_tree(dist, cfg, caches, b)
        return CellSpec(fn=fn, args=(p_shapes, batch, caches),
                        in_shardings=(p_sh, batch_sh, caches_sh),
                        out_shardings=(None, caches_sh),
                        donate_argnums=() if costing else (2,),
                        static_notes={"step": "prefill"})

    # decode: one new token against a seq_len cache
    caches = decode_cache_shapes(cfg, b, s)
    caches_sh = cache_sharding_tree(dist, cfg, caches, b)
    tokens = _i32((b, 1))
    tokens_sh = dist.named(dist.batch_pspec(2, b))
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    idx_sh = dist.named(P())
    fn = make_serve_step(cfg, dist=dist, unroll=unroll)
    return CellSpec(fn=fn, args=(p_shapes, tokens, caches, idx),
                    in_shardings=(p_sh, tokens_sh, caches_sh, idx_sh),
                    out_shardings=(None, None, caches_sh),
                    donate_argnums=() if costing else (2,),
                    static_notes={"step": "decode"})
