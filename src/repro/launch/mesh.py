"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis joins
the FSDP/data-parallel group (gradient all-reduce crosses DCN/pod links,
tensor parallelism never leaves a pod).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS *before* any jax import; see dryrun.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(n_data: int = 2, n_model: int = 2,
                    pods: int = 0) -> jax.sharding.Mesh:
    """Small mesh for in-CI island tests (requires host-device override)."""
    if pods:
        return jax.make_mesh((pods, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
