import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY jax import: jax locks the device
# count on first init. 512 placeholder host devices back the production mesh.

"""Multi-pod dry-run: lower + compile every (architecture × shape) cell on
the production meshes and extract memory / cost / collective analyses.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--multi-pod | --single-pod | --both] [--out results/dryrun]

Each cell writes an incremental JSON (results survive interruptions; rerun
skips completed cells unless --force). Failures here are bugs in the
framework's sharding config — fix, rerun, iterate.
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import ARCHS, SHAPES, cells_for, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import make_cell
from repro.sharding import DistContext

# TPU v5e constants (target hardware; see ROOFLINE ANALYSIS spec)
PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link (per-chip effective, one direction)

_COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=?")


def _parse_shape_bytes(shape_str: str) -> int:
    """'bf16[8,128,2048]{...}' -> byte count. Tuples handled by caller."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    sizes = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
             "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
             "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
    b = sizes.get(dt)
    if b is None:
        return 0
    if not dims:
        return b
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-operand bytes of every collective op in (post-SPMD,
    per-device) HLO. Returns {kind: bytes, 'total': bytes, 'count': n}."""
    out: dict = {}
    count = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(
            r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        shape_part, kind = m.groups()
        if shape_part.startswith("("):
            nbytes = sum(_parse_shape_bytes(s)
                         for s in re.findall(r"[a-z0-9]+\[[0-9,]*\]",
                                             shape_part))
        else:
            nbytes = _parse_shape_bytes(shape_part)
        out[kind] = out.get(kind, 0) + nbytes
        count += 1
    out["total"] = sum(v for k, v in out.items() if k != "count")
    out["count"] = count
    return out


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode D = new tokens only."""
    n = cfg.param_count(active_only=True)
    if shape.step == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.step == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def _compile_cell(arch, shape, multi_pod, overrides, costing_periods=None):
    """-> (compiled, mesh, cell) for one program variant."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    overrides = dict(overrides or {})
    flags = frozenset(overrides.pop("dist_flags", ()))
    dist = DistContext(mesh, flags=flags)
    cell = make_cell(arch, shape, dist, overrides=overrides,
                     costing_periods=costing_periods)
    with mesh:
        jitted = jax.jit(cell.fn,
                         in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    return compiled, mesh, cell


def _costs_of(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # jax < 0.6 returns [dict] per computation
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": collective_bytes(hlo),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path, force: bool = False,
             overrides: dict | None = None, tag: str = "",
             costing: bool | None = None) -> dict:
    """Full rolled compile (lowering proof + memory analysis) plus — on the
    single-pod mesh — two shallow *unrolled* costing compiles at L∈{2,4}
    periods, linearly extrapolated to full depth (exact: the scan body is
    identical per period). Train totals = µ × fwd/bwd(microbatch) + analytic
    optimizer apply. This sidesteps XLA cost_analysis counting while-loop
    bodies once."""
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    name = f"{arch}__{shape_name}__{mesh_tag}" + (f"__{tag}" if tag else "")
    out_path = out_dir / f"{name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    if costing is None:
        costing = not multi_pod  # roofline table is single-pod only

    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                 "step": shape.step, "tag": tag,
                 "seq_len": shape.seq_len, "global_batch": shape.global_batch}
    t0 = time.time()
    try:
        compiled, mesh, cell = _compile_cell(arch, shape, multi_pod,
                                             overrides)
        t_full = time.time()
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        }
        per_dev_bytes = sum(v for v in [
            rec["memory"]["argument_bytes"], rec["memory"]["temp_bytes"],
            rec["memory"]["output_bytes"]] if v) - (
                rec["memory"]["alias_bytes"] or 0)
        rec["memory"]["per_device_total_bytes"] = per_dev_bytes
        rec["memory"]["fits_16gb"] = bool(per_dev_bytes < 16e9)
        rec["full_compile_hlo_bytes"] = len(compiled.as_text())
        rec["timings"] = {"full_compile_s": t_full - t0}
        rec["ok"] = True

        if costing:
            knobs = {}
            mb = 1
            if shape.step == "train":
                from repro.launch.specs import resolve_knobs
                from repro.sharding import DistContext as _DC
                knobs = resolve_knobs(
                    cfg, _DC(make_production_mesh(multi_pod=multi_pod)),
                    shape.global_batch,
                    {k: v for k, v in (overrides or {}).items()
                     if k != "dist_flags"})
                mb = max(1, knobs.get("microbatch") or 1)
            n_p = cfg.n_periods
            l1, l2 = (2, 4) if n_p >= 4 else (1, max(2, n_p))
            c1, _, _ = _compile_cell(arch, shape, multi_pod, overrides,
                                     costing_periods=l1)
            k1 = _costs_of(c1)
            if l2 != l1 and n_p != l1:
                c2, _, _ = _compile_cell(arch, shape, multi_pod, overrides,
                                         costing_periods=l2)
                k2 = _costs_of(c2)
            else:
                k2, l2 = k1, l1
            t_cost = time.time()

            def extrap(a, b):
                if l2 == l1:
                    return b
                return b + (b - a) / (l2 - l1) * (n_p - l2)

            flops = extrap(k1["flops"], k2["flops"]) * mb
            byts = extrap(k1["bytes"], k2["bytes"]) * mb
            coll_kinds = set(k1["coll"]) | set(k2["coll"])
            coll = {kk: extrap(k1["coll"].get(kk, 0), k2["coll"].get(kk, 0))
                    * mb for kk in coll_kinds}
            if shape.step == "train":
                from repro.launch.specs import (optimizer_analytic_costs,
                                                optimizer_for)
                oc = optimizer_analytic_costs(
                    cfg, optimizer_for(cfg), knobs.get("accum_dtype",
                                                       "float32"), mesh.size)
                flops += oc["flops_per_device"]
                byts += oc["bytes_per_device"]
            rec["cost"] = {"flops_per_device": flops,
                           "bytes_accessed_per_device": byts,
                           "costing_periods": [l1, l2],
                           "microbatch": mb}
            rec["collectives"] = {k: v for k, v in coll.items()}
            rec["timings"]["costing_s"] = t_cost - t_full

            n_dev = mesh.size
            mf = model_flops(cfg, shape)
            comp_t = flops / PEAK_FLOPS
            mem_t = byts / HBM_BW
            # floor: every resident byte (params/opt/caches/IO) streamed once
            floor_bytes = (rec["memory"]["argument_bytes"] or 0) + \
                          (rec["memory"]["output_bytes"] or 0) - \
                          (rec["memory"]["alias_bytes"] or 0)
            mem_floor_t = floor_bytes / HBM_BW
            coll_t = coll.get("total", 0.0) / ICI_BW
            dominant = max((("compute", comp_t), ("memory", mem_t),
                            ("collective", coll_t)), key=lambda kv: kv[1])[0]
            bound = max(comp_t, mem_t, coll_t)
            rec["roofline"] = {
                "compute_s": comp_t,
                "memory_s": mem_t,
                "memory_floor_s": mem_floor_t,
                "collective_s": coll_t,
                "dominant": dominant,
                "model_flops_total": mf,
                "model_flops_per_device": mf / n_dev,
                "useful_flops_ratio": (mf / n_dev) / flops if flops else 0.0,
                "step_time_bound_s": bound,
                "mfu_bound": (mf / n_dev / PEAK_FLOPS) / bound
                             if bound > 0 else 0.0,
            }
    except Exception as exc:  # noqa: BLE001 — record the failure, keep going
        rec["ok"] = False
        rec["error"] = repr(exc)
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["elapsed_s"] = time.time() - t0
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    status = "OK " if rec.get("ok") else "FAIL"
    r = rec.get("roofline", {})
    print(f"[{status}] {name}  "
          f"compute={r.get('compute_s', 0):.4f}s mem={r.get('memory_s', 0):.4f}s "
          f"coll={r.get('collective_s', 0):.4f}s dom={r.get('dominant', '-')} "
          f"mfu_bound={r.get('mfu_bound', 0):.3f} "
          f"({rec['elapsed_s']:.0f}s)", flush=True)
    if not rec.get("ok"):
        print(rec.get("error"), flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--both", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    meshes = []
    if args.both or (not args.multi_pod and not args.single_pod):
        meshes = [False, True]
    else:
        if args.single_pod:
            meshes.append(False)
        if args.multi_pod:
            meshes.append(True)

    archs = [args.arch] if args.arch else list(ARCHS)
    out_dir = Path(args.out)
    results = []
    for arch in archs:
        shapes = cells_for(arch)
        if args.shape:
            shapes = [s for s in shapes if s.name == args.shape]
        for shape in shapes:
            for mp in meshes:
                results.append(run_cell(arch, shape.name, mp, out_dir,
                                        force=args.force))
    ok = sum(r.get("ok", False) for r in results)
    print(f"\n{ok}/{len(results)} cells compiled successfully")
    if ok < len(results):
        sys.exit(1)


if __name__ == "__main__":
    main()
