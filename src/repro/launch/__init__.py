"""Launchers: production mesh, per-cell input specs, multi-pod dry-run.

NOTE: do not import dryrun from here — it sets XLA_FLAGS at import time and
must be the process's first jax-touching import."""
from .mesh import make_production_mesh, make_smoke_mesh

__all__ = ["make_production_mesh", "make_smoke_mesh"]
