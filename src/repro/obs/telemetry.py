"""Broker-streamed telemetry: publisher and collector for ``PREFIX-telemetry``.

The paper's thesis is that the broker is the asynchronous backbone
between components — so telemetry rides the same broker instead of a
side channel. A :class:`TelemetryPublisher` periodically snapshots the
shared :class:`~repro.obs.metrics.MetricsRegistry`, drains new spans
from the :class:`~repro.obs.trace.SpanStore` and new lifecycle events
from the :class:`~repro.obs.blackbox.FlightRecorder`, and produces one
self-describing record per tick onto a durable ``PREFIX-telemetry``
topic (infinite retention, like the campaign journal). A
:class:`TelemetryCollector` — attached to the monitor, or run by a test
— replays that topic via the group-less ``Broker.read_from`` API and
folds the samples into a :class:`~repro.obs.series.TimeSeriesStore`.

Because the topic is the source of truth, the plane is loss-tolerant by
construction: killing the collector (the monitor) loses nothing — a
restarted collector replays from offset 0 and rebuilds the exact same
store. And because a collector can hold *feeds* into several brokers,
the federation home folds every remote site's telemetry into one store
whose series carry a ``site`` label, so ``sum_by("site")`` queries are
answered at home with no merge protocol.

Telemetry record schema (topic ``PREFIX-telemetry``, keyed by source)::

    {"kind": "telemetry", "v": 1,
     "source": "<publisher id>",         # e.g. "cluster" / site name
     "site":   "<site name or ''>",
     "seq":    <per-publisher counter>,
     "ts":     <float unix time>,
     "metrics": [{"name", "type", "labels", "value"}          # counter/gauge
                 | {"name", "type": "histogram", "labels",
                    "count", "sum", "p50", "p95", "p99"}],
     "spans":  [<span dict>, ...],       # new since last tick
     "events": [<blackbox event>, ...]}  # new since last tick

Histogram samples fold into recording-rule-style series:
``{name}_count`` / ``{name}_sum`` (counters) and ``{name}:p50`` /
``:p95`` / ``:p99`` (gauges) — e.g. an SLO on queue-wait p95 targets
``ksa_task_queue_wait_seconds:p95``.
"""

from __future__ import annotations

import logging
import threading
import time
from typing import Any, Callable

from .series import TimeSeriesStore

__all__ = ["TelemetryPublisher", "TelemetryCollector"]

log = logging.getLogger("repro.obs.telemetry")

_QUANTS = ("p50", "p95", "p99")


class TelemetryPublisher:
    """Periodically emits metric/span/event snapshots as broker records.

    One publisher per cluster (it snapshots the broker-owned registry
    that every co-located component — agents, monitor, pipeline,
    autoscaler — already writes into, so "a publisher on every
    component" costs one thread, not N). Extra per-component sample
    callables can be attached with :meth:`add_source`.
    """

    def __init__(self, broker: Any, topic: str, *, source: str = "cluster",
                 site: str = "", interval_s: float = 0.5,
                 recorder: Any | None = None) -> None:
        self.broker = broker
        self.topic = topic
        self.source = source
        self.site = site or getattr(broker, "site", "") or ""
        self.interval_s = float(interval_s)
        self.recorder = recorder if recorder is not None else getattr(
            broker, "blackbox", None)
        self._sources: list[Callable[[], list]] = []
        self._span_seq = 0
        self._event_seq = 0
        self._seq = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._c_pub = broker.metrics.counter(
            "ksa_telemetry_publishes_total",
            "Telemetry records produced onto the telemetry topic.",
            ["source"]).labels(source=source)
        # telemetry must survive component death: pin infinite retention
        # (same contract as the campaign journal topic)
        broker.create_topic(topic, retention_records=None)

    def add_source(self, fn: Callable[[], list]) -> None:
        """Attach a callable returning extra sample dicts (same shape as
        ``MetricsRegistry.sample()`` rows), merged into every tick."""
        self._sources.append(fn)

    def publish_once(self) -> Any | None:
        """Snapshot + produce one telemetry record (None if closed).

        Public so tests and examples can drive the plane
        deterministically instead of sleeping through intervals.
        """
        try:
            samples = self.broker.metrics.sample()
            for fn in self._sources:
                try:
                    samples.extend(fn() or [])
                except Exception:  # noqa: BLE001 — a bad source must not
                    pass           # starve the rest of the snapshot
            self._span_seq, spans = self.broker.spans.since(self._span_seq)
            events: list = []
            if self.recorder is not None:
                self._event_seq, events = self.recorder.since(
                    self._event_seq)
            self._seq += 1
            value = {"kind": "telemetry", "v": 1, "source": self.source,
                     "site": self.site, "seq": self._seq,
                     "ts": time.time(), "metrics": samples,
                     "spans": spans, "events": events}
            rec = self.broker.produce(self.topic, value, key=self.source)
            self._c_pub.inc()
            return rec
        except Exception:  # noqa: BLE001 — broker closing mid-publish
            log.debug("telemetry publish failed", exc_info=True)
            return None

    # ------------------------------------------------------------ thread

    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, name=f"telemetry-pub-{self.source}",
            daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.publish_once()

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=timeout)
            self._thread = None
        # final flush so short-lived runs still land one snapshot
        self.publish_once()


class _Feed:
    """One broker/topic to drain: per-partition replay watermarks."""

    __slots__ = ("broker", "topic", "site", "local", "offsets")

    def __init__(self, broker: Any, topic: str, site: str,
                 local: bool) -> None:
        self.broker = broker
        self.topic = topic
        self.site = site
        self.local = local
        self.offsets: dict[int, int] = {}


class TelemetryCollector:
    """Folds telemetry records from one or more brokers into a store.

    The default feed is the collector's own broker. The federation home
    adds one feed per remote site (:meth:`add_feed`), which is how
    site-labelled series from every site end up in one queryable store.
    Spans and blackbox events from *remote* feeds are folded into the
    local span store / flight recorder (stamped with the site), so the
    home pane also answers traces and post-mortems across the WAN;
    local-feed spans/events are skipped — they are already in the local
    stores, folding them back would double-count.
    """

    def __init__(self, broker: Any, topic: str, *,
                 store: TimeSeriesStore | None = None, site: str = "",
                 recorder: Any | None = None) -> None:
        self.broker = broker
        self.topic = topic
        self.site = site or getattr(broker, "site", "") or ""
        self.store = store if store is not None else TimeSeriesStore()
        self.recorder = recorder if recorder is not None else getattr(
            broker, "blackbox", None)
        self._lock = threading.Lock()
        self._feeds: list[_Feed] = [_Feed(broker, topic, self.site,
                                          local=True)]
        self._c_recs = broker.metrics.counter(
            "ksa_telemetry_records_total",
            "Telemetry records folded into the time-series store.",
            ["site"])
        broker.create_topic(topic, retention_records=None)

    def add_feed(self, broker: Any, topic: str, site: str) -> None:
        """Drain another broker's telemetry topic (federation home)."""
        with self._lock:
            self._feeds.append(_Feed(broker, topic, site, local=False))

    def poll(self) -> int:
        """Drain every feed from its watermark; returns records folded."""
        with self._lock:
            feeds = list(self._feeds)
        folded = 0
        for feed in feeds:
            try:
                nparts = feed.broker.partitions_for(feed.topic)
            except Exception:  # noqa: BLE001 — remote broker gone/closed
                continue
            for p in range(nparts):
                off = feed.offsets.get(p, 0)
                try:
                    recs = feed.broker.read_from(feed.topic, off,
                                                 partition=p)
                except Exception:  # noqa: BLE001
                    continue
                for rec in recs:
                    val = rec.value
                    if isinstance(val, dict) and val.get(
                            "kind") == "telemetry":
                        self._fold(val, feed)
                        folded += 1
                    feed.offsets[p] = rec.offset + 1
        return folded

    def _fold(self, rec: dict, feed: _Feed) -> None:
        site = rec.get("site") or feed.site or ""
        ts = float(rec.get("ts") or time.time())
        samples = []
        for m in rec.get("metrics", ()):
            name = m.get("name")
            if not name:
                continue
            labels = dict(m.get("labels") or {})
            if site:
                labels["site"] = site
            mtype = m.get("type", "gauge")
            if mtype == "histogram":
                samples.append((f"{name}_count", labels, ts,
                                m.get("count", 0), "counter"))
                samples.append((f"{name}_sum", labels, ts,
                                m.get("sum", 0.0), "counter"))
                for qn in _QUANTS:
                    qv = m.get(qn)
                    if qv is not None:
                        samples.append((f"{name}:{qn}", labels, ts, qv,
                                        "gauge"))
            else:
                samples.append((name, labels, ts, m.get("value", 0.0),
                                mtype))
        if samples:
            self.store.ingest_many(samples)
        if not feed.local:
            spans = rec.get("spans") or ()
            if spans:
                self.broker.spans.add_batch(
                    [(s.get("task_id"), dict(s, site=site))
                     for s in spans])
            if self.recorder is not None:
                for ev in rec.get("events", ()):
                    attrs = {k: v for k, v in ev.items()
                             if k not in ("kind", "seq")}
                    attrs["site"] = site
                    self.recorder.record(ev.get("kind", "event"), **attrs)
        self._c_recs.labels(site=site or "local").inc()

    def stats(self) -> dict[str, Any]:
        with self._lock:
            feeds = [{"site": f.site or "local", "local": f.local,
                      "offsets": dict(f.offsets)} for f in self._feeds]
        return {"feeds": feeds, "store": self.store.stats()}
