"""Bounded in-memory time-series store for the telemetry plane.

The :class:`TimeSeriesStore` is the query surface of the telemetry plane:
the :class:`~repro.obs.telemetry.TelemetryCollector` folds metric samples
from the ``PREFIX-telemetry`` topic into it, and the monitor's ``/query``
endpoint, ``KsaCluster.query(...)``, the SLO engine, and the autoscale
controller's sensing all read from it.

Design points mirroring the rest of the repo:

- **Bounded everywhere.** Series are keyed by ``(name, labels)``; each
  series is a ring of *aligned* buckets (bucket index = ``ts //
  resolution_s``), so a series occupies O(max_buckets) regardless of
  sample rate — high-frequency publishers downsample into the same
  bucket instead of growing the ring.
- **Counter-friendly.** Buckets keep the *last* sample (cumulative
  counters), plus min/max/sum/count for gauges, so ``rate()`` can
  reproduce the autoscaler's ``RateTracker`` slope semantics (first
  usable sample inside the window vs. the newest sample) and
  ``quantile()`` has per-bucket samples to rank.
- **Label-filter queries.** All reads accept a partial ``labels`` filter
  (subset match), so ``rate("ksa_pool_consumed_total", {"pool": "gpu"})``
  and ``sum_by("site")`` across federated feeds are both one call.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Iterable

__all__ = ["TimeSeriesStore"]

_LabelKey = tuple[tuple[str, str], ...]


def _label_key(labels: dict[str, str] | None) -> _LabelKey:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Series:
    """One bounded ring of aligned buckets.

    Each bucket is a mutable list ``[idx, ts, last, vmin, vmax, vsum,
    count]`` where ``ts`` is the timestamp of the newest sample folded
    into the bucket and ``last`` its value.
    """

    __slots__ = ("kind", "buckets")

    def __init__(self, kind: str, max_buckets: int) -> None:
        self.kind = kind
        self.buckets: deque[list] = deque(maxlen=max_buckets)

    def add(self, idx: int, ts: float, value: float) -> None:
        if self.buckets:
            cur = self.buckets[-1]
            if cur[0] == idx:
                if ts >= cur[1]:
                    cur[1], cur[2] = ts, value
                if value < cur[3]:
                    cur[3] = value
                if value > cur[4]:
                    cur[4] = value
                cur[5] += value
                cur[6] += 1
                return
            if idx < cur[0]:
                # late sample from a lagging feed — fold into the
                # matching bucket if it is still in the ring, else drop
                for b in reversed(self.buckets):
                    if b[0] == idx:
                        if value < b[3]:
                            b[3] = value
                        if value > b[4]:
                            b[4] = value
                        b[5] += value
                        b[6] += 1
                        return
                    if b[0] < idx:
                        break
                return
        self.buckets.append([idx, ts, value, value, value, value, 1])


class TimeSeriesStore:
    """Bounded per-series rings with aligned windows and rollup queries."""

    def __init__(self, resolution_s: float = 0.25, max_buckets: int = 4096,
                 max_series: int = 8192) -> None:
        if resolution_s <= 0:
            raise ValueError("resolution_s must be > 0")
        self.resolution_s = float(resolution_s)
        self.max_buckets = int(max_buckets)
        self.max_series = int(max_series)
        self._series: dict[tuple[str, _LabelKey], _Series] = {}
        self._lock = threading.Lock()
        self._dropped = 0

    # ------------------------------------------------------------- ingest

    def ingest(self, name: str, labels: dict[str, str] | None, ts: float,
               value: float, kind: str = "gauge") -> None:
        """Fold one sample into the ring for ``(name, labels)``."""
        key = (name, _label_key(labels))
        idx = int(ts // self.resolution_s)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                if len(self._series) >= self.max_series:
                    self._dropped += 1
                    return
                s = self._series[key] = _Series(kind, self.max_buckets)
            s.add(idx, ts, float(value))

    def ingest_many(self, samples: Iterable[tuple]) -> None:
        """Fold ``(name, labels, ts, value, kind)`` tuples in one lock hold."""
        with self._lock:
            for name, labels, ts, value, kind in samples:
                key = (name, _label_key(labels))
                s = self._series.get(key)
                if s is None:
                    if len(self._series) >= self.max_series:
                        self._dropped += 1
                        continue
                    s = self._series[key] = _Series(kind, self.max_buckets)
                s.add(int(ts // self.resolution_s), ts, float(value))

    # ------------------------------------------------------------ queries

    def _match(self, name: str,
               labels: dict[str, str] | None) -> list[tuple[_LabelKey, _Series]]:
        want = _label_key(labels)
        out = []
        for (n, lk), s in self._series.items():
            if n != name:
                continue
            if want and not set(want).issubset(lk):
                continue
            out.append((lk, s))
        return out

    def points(self, name: str, labels: dict[str, str] | None = None,
               window_s: float | None = None,
               now: float | None = None) -> list[tuple[float, float]]:
        """Time-ordered ``(ts, last)`` samples merged across matching
        series (one point per bucket per series)."""
        now = time.time() if now is None else now
        lo = (now - window_s) if window_s is not None else None
        with self._lock:
            matched = self._match(name, labels)
            pts = [(b[1], b[2]) for _, s in matched for b in s.buckets
                   if lo is None or b[1] >= lo]
        pts.sort(key=lambda p: p[0])
        return pts

    def latest(self, name: str, labels: dict[str, str] | None = None) -> float | None:
        """Newest sample value across matching series (``None`` if none)."""
        best = None
        with self._lock:
            for _, s in self._match(name, labels):
                if s.buckets:
                    b = s.buckets[-1]
                    if best is None or b[1] > best[0]:
                        best = (b[1], b[2])
        return best[1] if best else None

    def rate(self, name: str, labels: dict[str, str] | None = None,
             window_s: float = 60.0, now: float | None = None) -> float:
        """Per-second slope of a cumulative counter over ``window_s``,
        summed across matching series — the ``RateTracker`` semantics the
        autoscaler used to keep privately: slope between the first usable
        sample inside the window and the newest sample; 0.0 when fewer
        than two usable samples exist."""
        now = time.time() if now is None else now
        lo = now - window_s
        total = 0.0
        with self._lock:
            matched = self._match(name, labels)
            for _, s in matched:
                samples = [(b[1], b[2]) for b in s.buckets if b[1] >= lo]
                if len(samples) < 2:
                    continue
                (t0, v0), (t1, v1) = samples[0], samples[-1]
                if t1 <= t0:
                    continue
                total += max(0.0, (v1 - v0) / (t1 - t0))
        return total

    def quantile(self, name: str, q: float,
                 labels: dict[str, str] | None = None,
                 window_s: float = 60.0,
                 now: float | None = None) -> float | None:
        """Nearest-rank quantile over windowed bucket samples across
        matching series (``None`` when the window is empty)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("q must be in [0, 1]")
        vals = [v for _, v in self.points(name, labels, window_s, now)]
        if not vals:
            return None
        vals.sort()
        k = min(len(vals) - 1, max(0, int(q * len(vals) + 0.5) - 1))
        return vals[k]

    def sum_by(self, name: str, by: str,
               labels: dict[str, str] | None = None,
               window_s: float | None = None,
               now: float | None = None) -> dict[str, float]:
        """Sum of each matching series' newest windowed sample, grouped by
        the value of label ``by`` (series missing the label group under
        ``""``)."""
        now = time.time() if now is None else now
        lo = (now - window_s) if window_s is not None else None
        out: dict[str, float] = {}
        with self._lock:
            for lk, s in self._match(name, labels):
                if not s.buckets:
                    continue
                b = s.buckets[-1]
                if lo is not None and b[1] < lo:
                    continue
                group = dict(lk).get(by, "")
                out[group] = out.get(group, 0.0) + b[2]
        return out

    def sum(self, name: str, labels: dict[str, str] | None = None,
            window_s: float | None = None,
            now: float | None = None) -> float:
        """Sum of each matching series' newest windowed sample."""
        return float(sum(self.sum_by(name, "", labels, window_s,
                                     now).values()))

    # -------------------------------------------------------- query façade

    def query(self, name: str, agg: str = "latest",
              labels: dict[str, str] | None = None,
              window_s: float = 60.0, q: float | None = None,
              by: str | None = None,
              now: float | None = None) -> dict[str, Any]:
        """One-call dispatcher used by ``GET /query`` and
        ``KsaCluster.query(...)``. Raises ``ValueError`` on a malformed
        request (unknown ``agg``, missing ``q``/``by``) so HTTP callers
        can map it to a structured 400."""
        if agg == "latest":
            result: Any = self.latest(name, labels)
        elif agg == "rate":
            result = self.rate(name, labels, window_s, now)
        elif agg == "quantile":
            if q is None:
                raise ValueError("agg=quantile requires q")
            result = self.quantile(name, q, labels, window_s, now)
        elif agg == "sum_by":
            if not by:
                raise ValueError("agg=sum_by requires by=<label>")
            result = self.sum_by(name, by, labels, window_s, now)
        elif agg == "sum":
            result = sum(self.sum_by(name, "", labels, window_s,
                                     now).values())
        elif agg == "points":
            result = [[round(t, 6), v] for t, v in
                      self.points(name, labels, window_s, now)]
        else:
            raise ValueError(f"unknown agg {agg!r}")
        out = {"name": name, "agg": agg, "window_s": window_s,
               "result": result}
        if labels:
            out["labels"] = dict(labels)
        if q is not None:
            out["q"] = q
        if by:
            out["by"] = by
        return out

    # -------------------------------------------------------------- admin

    def series_names(self) -> list[str]:
        with self._lock:
            return sorted({n for n, _ in self._series})

    def stats(self) -> dict[str, Any]:
        with self._lock:
            return {"series": len(self._series),
                    "buckets": sum(len(s.buckets)
                                   for s in self._series.values()),
                    "resolution_s": self.resolution_s,
                    "dropped_series": self._dropped}
