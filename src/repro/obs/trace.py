"""Bounded in-memory span store: per-task lifecycle traces.

Every task carries a trace context in its :class:`~repro.core.messages.TaskMessage`
(``trace={"trace_id": ..., "parent": <campaign_id>}``) and each control-plane
hop records a *span* — a named, timestamped interval attached to the task id:

    submit → route → grant → claim → run → commit
                                   ↘ revoke → (journal) → submit(attempt+1) …

Spans survive across attempts (retries, preemptions): every span carries the
``attempt`` it belongs to, so ``trace(task_id)`` returns the full linked
chain of all attempts of one logical task, and
:meth:`repro.cluster.KsaCluster.campaign_report` can split a campaign's wall
time into queue vs run vs retry per stage.

The store is deliberately *lossy at the edges* — a fixed number of tasks
(LRU-evicted) and a fixed number of spans per task — so tracing a week-long
campaign cannot exhaust broker memory. Eviction counters are exposed via
:meth:`stats` so silently dropped history is visible.
"""
from __future__ import annotations

import threading
from collections import OrderedDict, deque

__all__ = ["SpanStore", "NullSpanStore"]


class SpanStore:
    """Thread-safe bounded map ``task_id -> [span dict, ...]``.

    A span is a plain dict (JSON/REST friendly) with at least ``name``,
    ``task_id``, ``start``, ``end``, ``dur_s`` and ``seq`` (a store-wide
    monotonic tiebreaker for same-timestamp ordering); extra keyword
    arguments to :meth:`add` become span attributes (``attempt``,
    ``holder``, ``reason``, ...).
    """

    def __init__(self, max_tasks: int = 4096,
                 max_spans_per_task: int = 128,
                 max_recent: int = 2048) -> None:
        self.max_tasks = max_tasks
        self.max_spans_per_task = max_spans_per_task
        self._lock = threading.Lock()
        self._spans: OrderedDict = OrderedDict()
        self._seq = 0
        self.evicted_tasks = 0
        self.dropped_spans = 0
        self.enabled = True
        # side ring of recently accepted spans, in seq order — the
        # telemetry publisher drains this incrementally via since()
        # without walking the whole per-task map
        self._recent: deque = deque(maxlen=max_recent)

    def add(self, task_id: str, name: str, start: float,
            end: float | None = None, **attrs) -> None:
        if not task_id:
            return
        end = start if end is None else end
        span = {"name": name, "task_id": task_id, "start": float(start),
                "end": float(end), "dur_s": max(0.0, float(end) - float(start))}
        span.update(attrs)
        with self._lock:
            self._seq += 1
            span["seq"] = self._seq
            spans = self._spans.get(task_id)
            if spans is None:
                spans = self._spans[task_id] = []
                while len(self._spans) > self.max_tasks:
                    self._spans.popitem(last=False)
                    self.evicted_tasks += 1
            if len(spans) >= self.max_spans_per_task:
                self.dropped_spans += 1
                return
            spans.append(span)
            self._recent.append(span)

    def add_batch(self, items) -> None:
        """Batched :meth:`add`: one lock hold for N spans. ``items`` is an
        iterable of ``(task_id, span_dict)`` pairs where each span dict is
        *prebuilt* by the caller — ``name``, ``task_id``, ``start``,
        ``end``, ``dur_s`` plus any attributes; the store only stamps
        ``seq`` and takes ownership of the dicts. LRU eviction runs once
        per flush (the store may transiently exceed ``max_tasks`` by the
        batch size mid-flush). The broker's vectorized grant/claim/commit
        paths flush a whole lease batch's spans here instead of re-entering
        the lock (and rebuilding each dict) per record."""
        with self._lock:
            spans_map = self._spans
            max_spans = self.max_spans_per_task
            recent = self._recent
            seq = self._seq
            for task_id, span in items:
                if not task_id:
                    continue
                seq += 1
                span["seq"] = seq
                spans = spans_map.get(task_id)
                if spans is None:
                    spans_map[task_id] = [span]
                    recent.append(span)
                    continue
                if len(spans) >= max_spans:
                    self.dropped_spans += 1
                    continue
                spans.append(span)
                recent.append(span)
            self._seq = seq
            n_over = len(spans_map) - self.max_tasks
            if n_over > 0:
                for _ in range(n_over):
                    spans_map.popitem(last=False)
                self.evicted_tasks += n_over

    def since(self, seq: int, limit: int = 1024) -> tuple[int, list]:
        """Spans with ``seq`` greater than the watermark, oldest first,
        plus the new watermark — the telemetry publisher's incremental
        drain. Only the bounded recent ring is scanned, so a publisher
        that falls further behind than ``max_recent`` spans loses the
        oldest (the ring is the retention contract, same as the per-task
        bounds)."""
        with self._lock:
            out = [dict(s) for s in self._recent if s["seq"] > seq][:limit]
            new_seq = out[-1]["seq"] if out else max(seq, 0)
        return new_seq, out

    def trace(self, task_id: str) -> list:
        """All spans of a task (every attempt), ordered by start time then
        insertion order. Returns copies; ``[]`` for unknown tasks."""
        with self._lock:
            spans = list(self._spans.get(task_id, ()))
        return [dict(s) for s in
                sorted(spans, key=lambda s: (s["start"], s["seq"]))]

    def tasks(self) -> list:
        with self._lock:
            return list(self._spans)

    def stats(self) -> dict:
        with self._lock:
            return {"tasks": len(self._spans),
                    "spans": sum(len(v) for v in self._spans.values()),
                    "evicted_tasks": self.evicted_tasks,
                    "dropped_spans": self.dropped_spans}


class NullSpanStore:
    """Drop-in stand-in when tracing is disabled (``obs=False``)."""

    enabled = False
    evicted_tasks = 0
    dropped_spans = 0

    def add(self, task_id: str, name: str, start: float,
            end: float | None = None, **attrs) -> None:
        pass

    def add_batch(self, items) -> None:
        pass

    def since(self, seq: int, limit: int = 1024) -> tuple[int, list]:
        return max(seq, 0), []

    def trace(self, task_id: str) -> list:
        return []

    def tasks(self) -> list:
        return []

    def stats(self) -> dict:
        return {"tasks": 0, "spans": 0, "evicted_tasks": 0,
                "dropped_spans": 0}
