"""Process RSS sampling for memory-overage policing.

The agents' memory watchdog (:meth:`repro.core.agents.AgentBase._police_mem`)
originally trusted each task to *self-report* its usage via
``ClusterComputing.report_mem()`` — fine for cooperative tests, useless
against a genuinely misbehaving task. This module reads the real resident
set from ``/proc/self/status`` (``VmRSS``), falling back to
``resource.getrusage`` where procfs is unavailable (macOS), so policing is
grounded in what the kernel actually accounts.

Reads are cached for a short TTL because the sampler runs inside every
agent's poll loop; a 0.2 s staleness bound is far below the watchdog's
reaction time and keeps the procfs cost negligible.
"""
from __future__ import annotations

import resource
import threading
import time

__all__ = ["sample_rss_mb"]

_CACHE_TTL_S = 0.2
_lock = threading.Lock()
_cached: tuple = (0.0, None)  # (monotonic ts, value_mb)


def _read_proc_vmrss_mb() -> float | None:
    try:
        with open("/proc/self/status", "rb") as fh:
            for line in fh:
                if line.startswith(b"VmRSS:"):
                    # "VmRSS:   123456 kB"
                    return int(line.split()[1]) / 1024.0
    except (OSError, ValueError, IndexError):
        pass
    return None


def _read_rusage_mb() -> float:
    # ru_maxrss is KB on Linux, bytes on macOS; we only hit this fallback
    # off-Linux, but normalizing per-platform keeps it honest everywhere.
    peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    import sys
    if sys.platform == "darwin":
        return peak / (1024.0 * 1024.0)
    return peak / 1024.0


def sample_rss_mb(cached: bool = True) -> float:
    """Current resident set size of this process, in MB."""
    global _cached
    now = time.monotonic()
    if cached:
        ts, val = _cached
        if val is not None and now - ts < _CACHE_TTL_S:
            return val
    val = _read_proc_vmrss_mb()
    if val is None:
        val = _read_rusage_mb()
    with _lock:
        _cached = (now, val)
    return val
