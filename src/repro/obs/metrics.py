"""In-process metrics registry for the KSA control plane.

The paper's monitor agent answers "how many tasks are done?"; operating the
control plane at proteome scale (ISSUE 6) additionally needs "where does the
time go *per task*" — queue wait vs claim latency vs run time vs commit
latency, broken down by resource class. This module is the substrate: a
single :class:`MetricsRegistry` that every subsystem (broker, lease table,
agents, monitor, pipeline agent, autoscale controller) registers counters,
gauges and histograms into, rendered on demand as Prometheus text exposition
(``GET /metrics`` on the monitor).

Design constraints, in order:

1. **Counters and gauges are always live**, even with observability disabled
   — the legacy ``stats()`` / ``status()`` / ``/summary`` dictionaries are
   now *views* over registry values, so zeroing them would break the control
   plane's own bookkeeping. Only histograms (and trace spans, see
   :mod:`repro.obs.trace`) honour the ``enabled`` switch, because they are
   the part with a per-observation cost.
2. **Low overhead**: one short lock hold per observation, no allocation on
   the counter hot path, a bounded sample ring per histogram child for exact
   p50/p95/p99 (Prometheus buckets alone only bound quantiles).
3. **Prometheus conventions**: metric families carry a fixed label-name
   tuple; ``labels(**kv)`` interns a child per label-value combination;
   ``render()`` emits ``# HELP`` / ``# TYPE`` plus cumulative ``_bucket``
   lines with an ``+Inf`` terminator for histograms.

Naming/label conventions used across the repo (documented for scrapers):

- every metric is prefixed ``ksa_`` and timed metrics end in ``_seconds``;
- per-resource-class latencies carry a ``cls`` label whose value is the
  suffix of the class topic (``PREFIX-new.gpu`` → ``gpu``; the flat
  single-topic layout reports ``flat``) — see :func:`topic_class`;
- lifecycle event counters are one family with an ``event`` label
  (``ksa_agent_events_total{agent=...,event=...}``) rather than one family
  per event, mirroring the revocation counter's ``reason`` label.
"""
from __future__ import annotations

import bisect
import functools
import threading
from collections import deque
from typing import Callable, Iterable, Mapping, Sequence

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_BUCKETS",
    "inject_label",
    "merge_renders",
    "topic_class",
]

# Spans the range of latencies the control plane actually exhibits: sub-ms
# broker ops through multi-minute campaign stages.
DEFAULT_BUCKETS: tuple = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 300.0,
)

# Exact-quantile sample ring size per histogram child. 512 recent samples
# give stable p50/p95 and a usable p99 while bounding memory.
_SAMPLE_RING = 512


@functools.lru_cache(maxsize=4096)
def topic_class(topic: str) -> str:
    """Resource-class label for a task topic.

    Per-class topics are ``PREFIX-new.<cls>`` (see
    :func:`repro.core.scheduling.class_topic`); the paper's flat layout uses
    the bare ``PREFIX-new``, which we label ``"flat"``.

    Cached per topic name: the broker grant path and the queue-stat/metric
    label sites call this per record, and a deployment has a handful of
    distinct topics — the parse should run once per topic, not once per
    task (the cache bound only matters for pathological topic churn).
    """
    base, sep, cls = topic.rpartition("-new.")
    if sep and base and cls:
        return cls
    return "flat"


class Counter:
    """A monotonically increasing integer. Starts at ``0`` (an ``int``), so
    legacy ``stats()`` views built on top keep their integer arithmetic."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, amount: int = 1) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """A value that can go up and down."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value -= amount

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Cumulative-bucket histogram plus a bounded ring of recent raw samples
    for exact quantiles (:meth:`quantile` / :meth:`percentiles`)."""

    __slots__ = ("_lock", "_uppers", "_counts", "_sum", "_count", "_ring")

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS) -> None:
        self._lock = threading.Lock()
        self._uppers = tuple(sorted(float(b) for b in buckets))
        self._counts = [0] * (len(self._uppers) + 1)  # +1 = +Inf
        self._sum = 0.0
        self._count = 0
        self._ring: deque = deque(maxlen=_SAMPLE_RING)

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            self._counts[bisect.bisect_left(self._uppers, v)] += 1
            self._sum += v
            self._count += 1
            self._ring.append(v)

    def observe_many(self, values: Sequence[float]) -> None:
        """Batched observe: one lock hold for N samples. The broker's
        vectorized grant path records a whole lease batch's queue waits
        here instead of re-entering the lock per record."""
        if not values:
            return
        vs = [float(v) for v in values]
        with self._lock:
            counts, uppers = self._counts, self._uppers
            total = 0.0
            for v in vs:
                counts[bisect.bisect_left(uppers, v)] += 1
                total += v
            self._sum += total
            self._count += len(vs)
            self._ring.extend(vs)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> float | None:
        """Exact quantile over the sample ring; ``None`` when empty."""
        with self._lock:
            samples = sorted(self._ring)
        if not samples:
            return None
        idx = min(len(samples) - 1, max(0, round(q * (len(samples) - 1))))
        return samples[idx]

    def percentiles(self) -> dict:
        return {"p50": self.quantile(0.50),
                "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}

    def snapshot(self) -> dict:
        with self._lock:
            cum, acc = [], 0
            for c in self._counts:
                acc += c
                cum.append(acc)
            return {"buckets": dict(zip(self._uppers, cum)),
                    "inf": cum[-1] if cum else 0,
                    "sum": self._sum, "count": self._count}


class _NullHistogram:
    """Histogram stand-in when observability is disabled: observations are
    dropped, reads report empty."""

    __slots__ = ()

    def observe(self, value: float) -> None:
        pass

    def observe_many(self, values: Sequence[float]) -> None:
        pass

    count = 0
    sum = 0.0

    def quantile(self, q: float) -> None:
        return None

    def percentiles(self) -> dict:
        return {"p50": None, "p95": None, "p99": None}

    def snapshot(self) -> dict:
        return {"buckets": {}, "inf": 0, "sum": 0.0, "count": 0}


class Family:
    """A named metric family: fixed label names, one child per label-value
    combination. Label-less families proxy ``inc``/``set``/``observe`` to a
    single default child for convenience."""

    def __init__(self, name: str, help_: str, label_names: tuple,
                 make_child: Callable[[], object]) -> None:
        self.name = name
        self.help = help_
        self.label_names = label_names
        self._make_child = make_child
        self._lock = threading.Lock()
        self._children: dict = {}
        if not label_names:
            self._children[()] = make_child()

    def labels(self, **kv: str) -> object:
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, got "
                f"{tuple(sorted(kv))}")
        key = tuple(str(kv[n]) for n in self.label_names)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, self._make_child())
        return child

    def items(self) -> Iterable:
        with self._lock:
            return list(self._children.items())

    # -- label-less convenience ------------------------------------------
    def _default(self) -> object:
        return self._children[()]

    def inc(self, amount=1) -> None:
        self._default().inc(amount)

    def dec(self, amount=1) -> None:
        self._default().dec(amount)

    def set(self, value) -> None:
        self._default().set(value)

    def observe(self, value) -> None:
        self._default().observe(value)

    def observe_many(self, values) -> None:
        self._default().observe_many(values)

    @property
    def value(self):
        return self._default().value

    @property
    def count(self):
        return self._default().count

    @property
    def sum(self):
        return self._default().sum

    def quantile(self, q: float):
        return self._default().quantile(q)

    def percentiles(self) -> dict:
        return self._default().percentiles()


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


def _esc(v) -> str:
    """Escape a label value per the exposition format: backslash, quote
    and newline are the three characters the spec requires escaping."""
    return (str(v).replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def _series(name: str, label_names: tuple, label_values: tuple,
            value, suffix: str = "", extra: Mapping | None = None) -> str:
    pairs = [f'{n}="{_esc(v)}"' for n, v in zip(label_names, label_values)]
    if extra:
        pairs += [f'{n}="{_esc(v)}"' for n, v in extra.items()]
    labels = ("{" + ",".join(pairs) + "}") if pairs else ""
    return f"{name}{suffix}{labels} {_fmt(value)}"


def inject_label(text: str, **labels: str) -> str:
    """Rewrite a Prometheus exposition so every sample line carries the
    given label(s) — the federation aggregator's tool for merging N
    per-site registries into one ``/metrics`` page with a ``site`` label
    (the Prometheus federation convention). ``# HELP`` / ``# TYPE`` lines
    and blanks pass through untouched; existing labels are preserved and
    the injected pairs are appended (or prepended into ``name value``
    lines). Injected values are escaped per the exposition format."""
    pairs = ",".join(f'{k}="{_esc(v)}"' for k, v in labels.items())
    if not pairs:
        return text
    out = []
    for line in text.splitlines():
        if not line or line.startswith("#"):
            out.append(line)
            continue
        # sample lines are `name{labels} value` or `name value`
        head, _, value = line.rpartition(" ")
        if not head:
            out.append(line)
            continue
        if head.endswith("}"):
            base = head[:-1]
            sep = "" if base.endswith("{") else ","
            out.append(f"{base}{sep}{pairs}}} {value}")
        else:
            out.append(f"{head}{{{pairs}}} {value}")
    return "\n".join(out) + ("\n" if text.endswith("\n") else "")


def merge_renders(renders: Mapping[str, str], label: str = "site") -> str:
    """Concatenate per-site :meth:`MetricsRegistry.render` outputs into one
    exposition: every sample gains ``{label}="<site>"`` and duplicate
    ``# HELP`` / ``# TYPE`` headers (the same family exists on every site)
    are emitted once, on first sight."""
    lines: list = []
    seen_meta: set = set()
    for site, text in renders.items():
        tagged = inject_label(text, **{label: site})
        for ln in tagged.splitlines():
            if ln.startswith("#"):
                if ln in seen_meta:
                    continue
                seen_meta.add(ln)
            lines.append(ln)
    return "\n".join(lines) + "\n"


class MetricsRegistry:
    """Process-wide (well, broker-wide) metric store.

    ``enabled=False`` keeps counters and gauges fully functional — the
    legacy stats views depend on them — but replaces histograms with no-op
    nulls so the per-observation cost disappears (benchmarked in
    ``benchmarks/bench_obs.py``).
    """

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = enabled
        self._lock = threading.Lock()
        self._families: dict = {}
        self._types: dict = {}
        self._callbacks: dict = {}

    # -- family constructors ---------------------------------------------
    def _family(self, name: str, help_: str, labels: tuple, type_: str,
                make_child: Callable[[], object]) -> Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if self._types[name] != type_ or fam.label_names != labels:
                    raise ValueError(
                        f"metric {name!r} re-registered as {type_}{labels}, "
                        f"was {self._types[name]}{fam.label_names}")
                return fam
            fam = Family(name, help_, labels, make_child)
            self._families[name] = fam
            self._types[name] = type_
            return fam

    def counter(self, name: str, help_: str = "",
                labels: Sequence[str] = ()) -> Family:
        return self._family(name, help_, tuple(labels), "counter", Counter)

    def gauge(self, name: str, help_: str = "",
              labels: Sequence[str] = ()) -> Family:
        return self._family(name, help_, tuple(labels), "gauge", Gauge)

    def histogram(self, name: str, help_: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Family:
        if not self.enabled:
            return self._family(name, help_, tuple(labels), "histogram",
                                _NullHistogram)
        return self._family(name, help_, tuple(labels), "histogram",
                            lambda: Histogram(buckets))

    def register_callback(self, name: str, fn: Callable[[], float],
                          help_: str = "") -> None:
        """A gauge whose value is computed at render time (e.g. live lease
        count straight from the lease table)."""
        with self._lock:
            self._callbacks[name] = (help_, fn)

    # -- export ----------------------------------------------------------
    def render(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: list = []
        with self._lock:
            families = list(self._families.items())
            callbacks = list(self._callbacks.items())
        for name, fam in sorted(families):
            type_ = self._types[name]
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {type_}")
            for key, child in sorted(fam.items()):
                if type_ in ("counter", "gauge"):
                    lines.append(_series(name, fam.label_names, key,
                                         child.value))
                    continue
                snap = child.snapshot()
                for upper, cum in snap["buckets"].items():
                    lines.append(_series(name, fam.label_names, key, cum,
                                         "_bucket", {"le": _fmt(upper)}))
                lines.append(_series(name, fam.label_names, key,
                                     snap["inf"], "_bucket", {"le": "+Inf"}))
                lines.append(_series(name, fam.label_names, key,
                                     snap["sum"], "_sum"))
                lines.append(_series(name, fam.label_names, key,
                                     snap["count"], "_count"))
        for name, (help_, fn) in sorted(callbacks):
            if help_:
                lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} gauge")
            try:
                value = float(fn())
            except Exception:
                continue
            lines.append(_series(name, (), (), value))
        return "\n".join(lines) + "\n"

    def describe(self) -> list:
        """Registered family descriptors — ``{name, type, labels, help}``
        rows (callback gauges included). Feeds the ``docs/METRICS.md``
        catalog generator and its lint test."""
        with self._lock:
            families = list(self._families.items())
            callbacks = list(self._callbacks.items())
        rows = [{"name": name, "type": self._types[name],
                 "labels": list(fam.label_names), "help": fam.help}
                for name, fam in families]
        rows += [{"name": name, "type": "gauge", "labels": [],
                  "help": help_} for name, (help_, fn) in callbacks]
        return sorted(rows, key=lambda r: r["name"])

    def sample(self) -> list:
        """Flat telemetry samples, one dict per live child series — the
        :class:`~repro.obs.telemetry.TelemetryPublisher` payload. Counters
        and gauges carry ``value``; histograms are pre-digested into
        ``count``/``sum`` plus ring quantiles (p50/p95/p99), which is what
        the time-series store folds into recording-rule-style series."""
        out: list = []
        with self._lock:
            families = list(self._families.items())
            callbacks = list(self._callbacks.items())
        for name, fam in families:
            type_ = self._types[name]
            for key, child in fam.items():
                labels = dict(zip(fam.label_names, key))
                if type_ in ("counter", "gauge"):
                    out.append({"name": name, "type": type_,
                                "labels": labels, "value": child.value})
                else:
                    snap = child.snapshot()
                    pct = child.percentiles()
                    out.append({"name": name, "type": "histogram",
                                "labels": labels, "count": snap["count"],
                                "sum": snap["sum"], "p50": pct["p50"],
                                "p95": pct["p95"], "p99": pct["p99"]})
        for name, (help_, fn) in callbacks:
            try:
                value = float(fn())
            except Exception:
                continue
            out.append({"name": name, "type": "gauge", "labels": {},
                        "value": value})
        return out

    def snapshot(self) -> dict:
        """Programmatic dump (tests): ``{name: {labels_tuple: value}}`` with
        histogram children rendered as their snapshot dict."""
        out: dict = {}
        with self._lock:
            families = list(self._families.items())
        for name, fam in families:
            type_ = self._types[name]
            series = {}
            for key, child in fam.items():
                series[key] = (child.value if type_ in ("counter", "gauge")
                               else child.snapshot())
            out[name] = {"type": type_, "series": series}
        return out
