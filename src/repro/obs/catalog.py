"""Metrics catalog generator — ``docs/METRICS.md`` from the live registry.

The catalog is generated, not hand-written: :func:`render_catalog` walks
:meth:`MetricsRegistry.describe` and emits one markdown table row per
``ksa_`` family (name, type, labels, help). ``tests/test_obs.py`` builds a
full deployment (telemetry + autoscale + pipeline + federation so every
lazily-registered family exists), renders the catalog, and fails if a
registered family is missing from the committed ``docs/METRICS.md`` — so
adding a metric without documenting it breaks the build.

Regenerate with::

    PYTHONPATH=src python -m repro.obs.catalog > docs/METRICS.md
"""
from __future__ import annotations

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .metrics import MetricsRegistry

__all__ = ["render_catalog", "catalog_names"]

_HEADER = """\
# Metrics catalog

All `ksa_` metric families exported on `GET /metrics` (Prometheus text
format 0.0.4). This file is generated from the live registry by
`repro.obs.catalog` — do not edit rows by hand; regenerate with
`PYTHONPATH=src python -m repro.obs.catalog > docs/METRICS.md`.
`tests/test_obs.py` fails if a registered family is missing here.

Histogram families additionally publish recording-rule-style series on the
telemetry plane: `{name}_count`, `{name}_sum`, and `{name}:p50/:p95/:p99`
gauges (see the `PREFIX-telemetry` record schema in
`examples/knot_campaign.py`).

| Metric | Type | Labels | Help |
|---|---|---|---|
"""


def render_catalog(registry: "MetricsRegistry") -> str:
    """Markdown catalog of every registered family, sorted by name."""
    rows = []
    for fam in registry.describe():
        labels = ", ".join(f"`{label}`" for label in fam["labels"]) or "—"
        rows.append(f"| `{fam['name']}` | {fam['type']} | {labels} "
                    f"| {fam['help']} |")
    return _HEADER + "\n".join(rows) + "\n"


def catalog_names(text: str) -> set:
    """Family names present in a rendered catalog (for the lint test)."""
    names = set()
    for line in text.splitlines():
        if line.startswith("| `ksa_"):
            names.add(line.split("`")[1])
    return names


def _full_registry() -> "MetricsRegistry":
    """Spin up one of everything so every lazily-registered family exists,
    then hand back the home registry (federation families included)."""
    from repro.autoscale import AutoscaleConfig, PoolSpec
    from repro.federation import FederatedCluster, Site, SpilloverConfig
    from repro.pipeline import PipelineSpec, Stage
    from repro.serve.metrics import register_serve_metrics

    fed = FederatedCluster(
        [Site("home", workers=1,
              autoscale=AutoscaleConfig(
                  pools=(PoolSpec("cpu", min_agents=1, max_agents=2),))),
         Site("edge", workers=1)],
        prefix="catalog", telemetry=True,
        spillover=SpilloverConfig(classes=("cpu",)))
    with fed:
        fed.wait_all([fed.submit("sleep", params={"duration": 0.01})],
                     timeout=30)
        fed.run_campaign(
            PipelineSpec("catalog", [Stage("s", "sleep",
                                           params={"duration": 0.01})]),
            items=[1], timeout_s=30)
        fed.home.autoscaler.tick()
        fed.spillover.tick()
        register_serve_metrics(fed.home.broker.metrics)
        return fed.home.broker.metrics


if __name__ == "__main__":  # pragma: no cover - generator entry point
    print(render_catalog(_full_registry()), end="")
