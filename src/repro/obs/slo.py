"""SLO specs and multi-window burn-rate alert rules over the store.

An :class:`SloSpec` names a telemetry series and an objective; an
:class:`AlertRule` wraps one with the SRE-workbook *multi-window* burn
test: the alert fires only when the **long** window burn rate and the
**short** window burn rate are both at or above the threshold (so a
sustained breach fires, a blip does not), and resolves as soon as the
short window drops back below (fast recovery detection). The
:class:`AlertEngine` evaluates every rule against the
:class:`~repro.obs.series.TimeSeriesStore`, keeps a bounded transition
history, counts transitions as ``ksa_alerts_total{rule,state}``, and
invokes an ``on_fire`` hook — which the cluster wires to the
:class:`~repro.obs.blackbox.FlightRecorder` so a firing alert latches a
post-mortem dump.

Burn-rate semantics per SLO ``kind``:

- ``"threshold"`` — gauge/latency series vs. an upper bound. With ``q``
  set, burn = ``quantile(metric, q, window) / objective`` (e.g. "queue
  wait p95 ≤ 2s"); without ``q``, burn = breach-ratio of windowed points
  over ``objective``, divided by the error ``budget`` fraction.
- ``"rate"`` — cumulative counter vs. an allowed events/second budget:
  burn = ``rate(metric, window) / objective`` (e.g. "≤ 0.5 lease
  revocations/s").
- ``"ratio"`` — two counters: burn = ``(rate(metric) /
  rate(total_metric)) / objective`` (e.g. "campaign task error ratio
  ≤ 5%"). A zero denominator reads as zero burn.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

__all__ = ["SloSpec", "AlertRule", "AlertEngine"]


@dataclass(frozen=True)
class SloSpec:
    """What good looks like for one telemetry series."""

    name: str
    metric: str
    objective: float
    kind: str = "threshold"          # "threshold" | "rate" | "ratio"
    labels: dict[str, str] | None = None
    q: float | None = None           # quantile for kind="threshold"
    total_metric: str | None = None  # denominator for kind="ratio"
    budget: float = 0.01             # breach budget for plain thresholds

    def __post_init__(self) -> None:
        if self.kind not in ("threshold", "rate", "ratio"):
            raise ValueError(f"unknown SLO kind {self.kind!r}")
        if self.kind == "ratio" and not self.total_metric:
            raise ValueError("kind='ratio' requires total_metric")
        if self.objective <= 0:
            raise ValueError("objective must be > 0")

    def burn(self, store: Any, window_s: float,
             now: float | None = None) -> float:
        """Burn rate over one window: 1.0 means exactly at objective."""
        if self.kind == "rate":
            return store.rate(self.metric, self.labels, window_s,
                              now) / self.objective
        if self.kind == "ratio":
            total = store.rate(self.total_metric, self.labels, window_s, now)
            if total <= 0.0:
                return 0.0
            bad = store.rate(self.metric, self.labels, window_s, now)
            return (bad / total) / self.objective
        if self.q is not None:
            val = store.quantile(self.metric, self.q, self.labels,
                                 window_s, now)
            return 0.0 if val is None else val / self.objective
        pts = store.points(self.metric, self.labels, window_s, now)
        if not pts:
            return 0.0
        breach = sum(1 for _, v in pts if v > self.objective) / len(pts)
        return breach / self.budget if self.budget > 0 else float(breach > 0)


@dataclass(frozen=True)
class AlertRule:
    """Multi-window burn-rate test over one :class:`SloSpec`."""

    slo: SloSpec
    long_window_s: float = 60.0
    short_window_s: float = 10.0
    burn_threshold: float = 1.0
    name: str = ""

    def __post_init__(self) -> None:
        if not self.name:
            object.__setattr__(self, "name", self.slo.name)
        if self.short_window_s > self.long_window_s:
            raise ValueError("short_window_s must be <= long_window_s")

    def evaluate(self, store: Any, now: float | None = None) -> dict[str, Any]:
        long_burn = self.slo.burn(store, self.long_window_s, now)
        short_burn = self.slo.burn(store, self.short_window_s, now)
        return {
            "rule": self.name,
            "metric": self.slo.metric,
            "kind": self.slo.kind,
            "objective": self.slo.objective,
            "burn_long": round(long_burn, 6),
            "burn_short": round(short_burn, 6),
            "threshold": self.burn_threshold,
            "breach": (long_burn >= self.burn_threshold
                       and short_burn >= self.burn_threshold),
            "recovered": short_burn < self.burn_threshold,
        }


class AlertEngine:
    """Evaluates rules against the store; tracks firing/resolved state."""

    def __init__(self, store: Any, rules: list[AlertRule] | tuple = (),
                 registry: Any | None = None,
                 on_fire: Callable[[str, dict], None] | None = None,
                 max_history: int = 256) -> None:
        self.store = store
        self.rules: list[AlertRule] = list(rules)
        self.on_fire = on_fire
        self._state: dict[str, dict[str, Any]] = {}
        self._history: deque[dict[str, Any]] = deque(maxlen=max_history)
        self._lock = threading.Lock()
        self._c_alerts = None
        if registry is not None:
            self._c_alerts = registry.counter(
                "ksa_alerts_total",
                "SLO alert transitions by rule and state.",
                ["rule", "state"])

    def add_rule(self, rule: AlertRule) -> None:
        with self._lock:
            self.rules.append(rule)

    def evaluate(self, now: float | None = None) -> list[dict[str, Any]]:
        """Run every rule once; returns the full evaluation list."""
        now = time.time() if now is None else now
        with self._lock:
            rules = list(self.rules)
        fired: list[tuple[str, dict]] = []
        evals = []
        for rule in rules:
            ev = rule.evaluate(self.store, now)
            evals.append(ev)
            with self._lock:
                st = self._state.setdefault(
                    rule.name, {"state": "ok", "since": now, "firings": 0})
                prev = st["state"]
                if ev["breach"] and prev != "firing":
                    st.update(state="firing", since=now)
                    st["firings"] += 1
                    self._transition(rule.name, "firing", ev, now)
                    fired.append((rule.name, ev))
                elif prev == "firing" and ev["recovered"]:
                    st.update(state="resolved", since=now)
                    self._transition(rule.name, "resolved", ev, now)
                st["last"] = ev
        for name, ev in fired:
            if self.on_fire is not None:
                try:
                    self.on_fire(name, ev)
                except Exception:  # noqa: BLE001 — alerting must not kill
                    pass           # the monitor loop
        return evals

    def _transition(self, rule: str, state: str, ev: dict,
                    now: float) -> None:
        self._history.append({"rule": rule, "state": state, "ts": now,
                              "burn_long": ev["burn_long"],
                              "burn_short": ev["burn_short"]})
        if self._c_alerts is not None:
            self._c_alerts.labels(rule=rule, state=state).inc()

    def active(self) -> list[dict[str, Any]]:
        """Currently-firing alerts (the ``status()["alerts"]`` payload)."""
        with self._lock:
            return [dict(rule=name, **{k: v for k, v in st.items()})
                    for name, st in sorted(self._state.items())
                    if st["state"] == "firing"]

    def status(self) -> dict[str, Any]:
        """The ``GET /alerts`` payload: every rule's state + history."""
        with self._lock:
            return {
                "rules": [r.name for r in self.rules],
                "states": {name: dict(st)
                           for name, st in sorted(self._state.items())},
                "firing": [name for name, st in self._state.items()
                           if st["state"] == "firing"],
                "history": list(self._history),
            }
