"""Crash flight recorder: a bounded blackbox ring of lifecycle events.

The :class:`FlightRecorder` lives on the broker (always on — event
appends are one deque op) and records the control-plane moments that
matter in a post-mortem: lease grants, revocations with reasons, agent
drains, spillover decisions, and journal repairs. On a *trigger
condition* — a revocation storm, a campaign entering FAILED, or an SLO
alert firing — it latches a **dump**: a snapshot of the recent event
ring plus optional caller-supplied context (lease table state, active
alerts). Dumps are bounded too, and served on ``GET /blackbox`` /
``KsaCluster.dump_blackbox()``.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable

__all__ = ["FlightRecorder"]


class FlightRecorder:
    """Bounded blackbox of lifecycle events with auto-dump triggers.

    Parameters
    ----------
    max_events:
        Ring size for the raw event log.
    max_dumps:
        How many post-mortem dumps to retain (oldest evicted).
    storm_threshold / storm_window_s:
        ``record("revocation", ...)`` calls arriving at or above
        ``storm_threshold`` within ``storm_window_s`` auto-dump with
        trigger ``"revocation_storm"``.
    storm_cooldown_s:
        Minimum spacing between two storm auto-dumps, so one sustained
        storm produces one dump, not one per revocation.
    """

    def __init__(self, max_events: int = 2048, max_dumps: int = 8,
                 storm_threshold: int = 10, storm_window_s: float = 5.0,
                 storm_cooldown_s: float = 30.0) -> None:
        self._events: deque[dict[str, Any]] = deque(maxlen=max_events)
        self._dumps: deque[dict[str, Any]] = deque(maxlen=max_dumps)
        self._lock = threading.Lock()
        self._seq = 0
        self._counts: dict[str, int] = {}
        self.storm_threshold = int(storm_threshold)
        self.storm_window_s = float(storm_window_s)
        self.storm_cooldown_s = float(storm_cooldown_s)
        self._revocation_ts: deque[float] = deque(maxlen=max(1, storm_threshold))
        self._last_storm_dump = 0.0
        # context_fn is injected by the owning cluster/monitor so dumps
        # carry live state (lease stats, alerts) without the recorder
        # importing any of it
        self.context_fn: Callable[[], dict[str, Any]] | None = None

    # ------------------------------------------------------------- record

    def record(self, kind: str, **attrs: Any) -> None:
        """Append one lifecycle event; may latch a storm auto-dump."""
        now = time.time()
        ev = {"seq": 0, "ts": now, "kind": kind}
        ev.update(attrs)
        storm = False
        with self._lock:
            self._seq += 1
            ev["seq"] = self._seq
            self._events.append(ev)
            self._counts[kind] = self._counts.get(kind, 0) + 1
            if kind == "revocation":
                self._revocation_ts.append(now)
                if (len(self._revocation_ts) >= self.storm_threshold
                        and now - self._revocation_ts[0]
                        <= self.storm_window_s
                        and now - self._last_storm_dump
                        >= self.storm_cooldown_s):
                    self._last_storm_dump = now
                    storm = True
        if storm:
            self.dump("revocation_storm")

    # -------------------------------------------------------------- reads

    def since(self, seq: int, limit: int = 512) -> tuple[int, list[dict]]:
        """Events with ``seq`` greater than the given watermark, oldest
        first, plus the new watermark — the publisher's drain API."""
        with self._lock:
            out = [e for e in self._events if e["seq"] > seq][:limit]
            new_seq = out[-1]["seq"] if out else max(seq, 0)
        return new_seq, out

    def events(self, limit: int = 256,
               kind: str | None = None) -> list[dict[str, Any]]:
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e["kind"] == kind]
        return evs[-limit:]

    # -------------------------------------------------------------- dumps

    def dump(self, trigger: str, context: dict[str, Any] | None = None,
             limit: int = 256) -> dict[str, Any]:
        """Latch a post-mortem snapshot of the recent ring and return it."""
        ctx = dict(context) if context else {}
        fn = self.context_fn
        if fn is not None:
            try:
                ctx.update(fn() or {})
            except Exception:  # noqa: BLE001 — a dump must never raise
                pass
        with self._lock:
            snap = {
                "trigger": trigger,
                "ts": time.time(),
                "seq": self._seq,
                "counts": dict(self._counts),
                "events": list(self._events)[-limit:],
                "context": ctx,
            }
            self._dumps.append(snap)
        return snap

    def dumps(self) -> list[dict[str, Any]]:
        with self._lock:
            return list(self._dumps)

    def stats(self) -> dict[str, Any]:
        with self._lock:
            return {"events": len(self._events), "seq": self._seq,
                    "dumps": len(self._dumps), "counts": dict(self._counts)}

    def snapshot(self, limit: int = 256) -> dict[str, Any]:
        """The ``GET /blackbox`` payload: ring stats + recent events +
        retained dumps."""
        with self._lock:
            return {"seq": self._seq,
                    "counts": dict(self._counts),
                    "events": list(self._events)[-limit:],
                    "dumps": list(self._dumps)}
