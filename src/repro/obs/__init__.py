"""repro.obs — observability substrate + telemetry plane for KSA.

In-process substrate (ISSUE 6):

- :class:`MetricsRegistry` — counters / gauges / histograms (with exact
  p50/p95/p99 over a bounded sample ring) that the broker, lease table,
  agents, monitor, pipeline agent and autoscale controller all register
  into. Rendered as Prometheus text by the monitor's ``GET /metrics``.
- :class:`SpanStore` — a bounded in-memory per-task span store on the
  broker; the trace context rides in ``TaskMessage.trace`` and every
  control-plane hop (submit → route → grant → claim → run → commit /
  revoke → journal) records a span, linked across attempts. Surfaced via
  ``GET /trace/<task_id>`` and :meth:`repro.cluster.KsaCluster.trace` /
  ``campaign_report``.
- :func:`sample_rss_mb` — kernel-accounted process RSS for the agents'
  memory watchdog (self-reporting via ``report_mem`` stays as an
  override).

Telemetry plane (ISSUE 9) — streamed over the broker itself:

- :class:`TelemetryPublisher` / :class:`TelemetryCollector` — periodic
  metric/span/event snapshots as durable records on ``PREFIX-telemetry``,
  replayed (``Broker.read_from``) into a…
- :class:`TimeSeriesStore` — bounded per-series rings with aligned
  windows and ``rate()`` / ``quantile()`` / ``sum_by(label)`` queries,
  served on ``GET /query`` and ``KsaCluster.query(...)``; federation
  feeds merge site-labelled series at the home store.
- :class:`SloSpec` / :class:`AlertRule` / :class:`AlertEngine` —
  multi-window burn-rate alerting over the store (``GET /alerts``,
  ``status()["alerts"]``, ``ksa_alerts_total{rule,state}``).
- :class:`FlightRecorder` — an always-on bounded blackbox of lifecycle
  events (grants, revocations with reasons, drains, spills, journal
  repairs) that auto-dumps a post-mortem on revocation storms, campaign
  FAILED or alert firing (``GET /blackbox``,
  ``KsaCluster.dump_blackbox()``).

The in-process layer stays switchable: ``KsaCluster(obs=False)`` nulls
histograms and spans while keeping counters/gauges live. The telemetry
plane is opt-in (``KsaCluster(telemetry=True)``) and budgeted at ≤10%
end-to-end overhead on a no-op DAG (``benchmarks/bench_obs.py`` →
``BENCH_obs.json``).
"""
from .blackbox import FlightRecorder
from .metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry, inject_label, merge_renders,
                      topic_class)
from .rss import sample_rss_mb
from .series import TimeSeriesStore
from .slo import AlertEngine, AlertRule, SloSpec
from .telemetry import TelemetryCollector, TelemetryPublisher
from .trace import NullSpanStore, SpanStore

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_BUCKETS",
    "inject_label",
    "merge_renders",
    "topic_class",
    "SpanStore",
    "NullSpanStore",
    "sample_rss_mb",
    "TimeSeriesStore",
    "TelemetryPublisher",
    "TelemetryCollector",
    "SloSpec",
    "AlertRule",
    "AlertEngine",
    "FlightRecorder",
]
