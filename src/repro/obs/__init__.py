"""repro.obs — observability substrate for the KSA control plane.

Three pieces (ISSUE 6):

- :class:`MetricsRegistry` — counters / gauges / histograms (with exact
  p50/p95/p99 over a bounded sample ring) that the broker, lease table,
  agents, monitor, pipeline agent and autoscale controller all register
  into. Rendered as Prometheus text by the monitor's ``GET /metrics``.
- :class:`SpanStore` — a bounded in-memory per-task span store on the
  broker; the trace context rides in ``TaskMessage.trace`` and every
  control-plane hop (submit → route → grant → claim → run → commit /
  revoke → journal) records a span, linked across attempts. Surfaced via
  ``GET /trace/<task_id>`` and :meth:`repro.cluster.KsaCluster.trace` /
  ``campaign_report``.
- :func:`sample_rss_mb` — kernel-accounted process RSS for the agents'
  memory watchdog (self-reporting via ``report_mem`` stays as an
  override).

The whole layer is switchable: ``KsaCluster(obs=False)`` (or
``Broker(obs=False)``) nulls out histograms and spans while keeping
counters/gauges live, since the legacy ``stats()`` dictionaries are views
over them. Overhead with ``obs=True`` is budgeted at ≤5% wall on a no-op
DAG (``benchmarks/bench_obs.py`` → ``BENCH_obs.json``).
"""
from .metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry, inject_label, merge_renders,
                      topic_class)
from .rss import sample_rss_mb
from .trace import NullSpanStore, SpanStore

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_BUCKETS",
    "inject_label",
    "merge_renders",
    "topic_class",
    "SpanStore",
    "NullSpanStore",
    "sample_rss_mb",
]
