"""LM losses. The plain path materializes per-token log-probs with a gather;
the vocab-parallel path (Megatron-style, used under a mesh) lives in
``repro.sharding.context`` because it needs axis names."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lm_loss(logits: jax.Array, labels: jax.Array,
            weights: jax.Array | None = None,
            z_weight: float = 1e-4) -> tuple[jax.Array, dict]:
    """logits: (B, S, V) (any float dtype, upcast here); labels: (B, S) int.
    ``weights``: optional (B, S) mask. Returns (scalar loss, metrics)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    nll = lse - ll
    if weights is None:
        weights = jnp.ones_like(nll)
    weights = weights.astype(jnp.float32)
    denom = jnp.maximum(weights.sum(), 1.0)
    ce = (nll * weights).sum() / denom
    z = (jnp.square(lse) * weights).sum() / denom
    loss = ce + z_weight * z
    return loss, {"ce": ce, "z_loss": z,
                  "tokens": weights.sum()}
