from .loss import lm_loss
from .step import (TrainState, init_train_state, make_prefill_step,
                   make_serve_step, make_train_step, train_state_shapes)

__all__ = ["TrainState", "init_train_state", "lm_loss", "make_prefill_step",
           "make_serve_step", "make_train_step", "train_state_shapes"]
