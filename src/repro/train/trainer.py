"""The fault-tolerant trainer as a KSA task — the paper's technique applied
to training.

A training run is a campaign of **step-chunk tasks** on the ``PREFIX-new``
topic: chunk k = "advance from checkpoint at step s_k by n steps, write a
checkpoint, report metrics". Chunks are idempotent (deterministic data via
``repro.data.synthetic``; state via ``repro.checkpoint``), so the KSA
at-least-once machinery — watchdog timeout → resubmit, attempt fencing at the
monitor — gives end-to-end fault tolerance: kill any agent mid-chunk and the
campaign completes with bit-identical results.

``TrainChunkComputing`` is the paper's Fig. 3 user class; ``TrainCampaign``
is the Submitter-side driver that chains chunks (and is itself stateless —
it can be restarted from the monitor's task table).
"""
from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import (Broker, ClusterComputing, MonitorAgent, Submitter,
                        register_script)
from repro.data import batch_at
from repro.models.config import ModelConfig
from repro.optim import OptimizerConfig
from .step import TrainState, init_train_state, make_train_step


def _cfg_from_params(params: dict) -> ModelConfig:
    from repro.configs import get_config, smoke_config
    if params.get("smoke", True):
        return smoke_config(params["arch"])
    return get_config(params["arch"])


def _ocfg_from_params(params: dict) -> OptimizerConfig:
    o = params.get("optimizer", {})
    return OptimizerConfig(lr=o.get("lr", 2e-3),
                           warmup_steps=o.get("warmup_steps", 0),
                           total_steps=o.get("total_steps", 1000),
                           schedule=o.get("schedule", "constant"),
                           weight_decay=o.get("weight_decay", 0.0),
                           grad_clip=o.get("grad_clip", 1.0))


@register_script("train_chunk")
class TrainChunkComputing(ClusterComputing):
    """params: arch, ckpt_dir, start_step, n_steps, batch, seq, data_seed,
    smoke (reduced config), optimizer{...}. Result: final_step, ckpt_path,
    loss, throughput."""

    # cache the jitted step across chunks within one agent process
    _step_cache: dict = {}

    def run(self) -> Any:
        p = self.params
        cfg = _cfg_from_params(p)
        ocfg = _ocfg_from_params(p)
        start = int(p["start_step"])
        n_steps = int(p["n_steps"])
        batch_size = int(p.get("batch", 8))
        seq = int(p.get("seq", 64))
        seed = int(p.get("data_seed", 0))
        mgr = CheckpointManager(p["ckpt_dir"], keep=int(p.get("keep", 3)))

        key = (cfg.name, seq, batch_size)
        if key not in self._step_cache:
            self._step_cache[key] = jax.jit(make_train_step(cfg, ocfg))
        step_fn = self._step_cache[key]

        # restore (or cold start) — never trust start_step blindly: the
        # chunk must begin from a checkpoint at exactly `start`.
        state = init_train_state(cfg, ocfg, jax.random.PRNGKey(seed))
        if start > 0:
            restored = mgr.restore_latest(jax.eval_shape(lambda: state))
            if restored is None:
                raise RuntimeError(f"chunk starts at {start} but no "
                                   f"checkpoint exists")
            ck_step, state, _ = restored
            if ck_step != start:
                # redelivered stale chunk: resume from what actually exists
                start = ck_step
        t0 = time.time()
        loss = float("nan")
        for s in range(start, start + n_steps):
            self.check_cancel()
            b = jax.tree.map(jnp.asarray,
                             batch_at(cfg, seed, s, batch=batch_size,
                                      seq=seq))
            state, metrics = step_fn(state, b)
            if (s - start) % max(n_steps // 4, 1) == 0:
                loss = float(metrics["loss"])
                self.send_status("RUNNING", step=s, loss=loss)
        loss = float(metrics["loss"])
        final_step = start + n_steps
        handle = mgr.async_save(final_step, state,
                                extra={"loss": loss, "arch": cfg.name})
        ckpt_path = handle.result(timeout=120)
        dt = time.time() - t0
        return {
            "final_step": final_step,
            "ckpt_path": ckpt_path,
            "loss": loss,
            "steps_per_s": n_steps / max(dt, 1e-9),
        }


class TrainCampaign:
    """Submitter-side driver: chains step-chunks through the broker until
    ``total_steps`` is reached. Tolerant of agent death (monitor resubmits)
    and of its own restart (progress is derived from the monitor table)."""

    def __init__(self, broker: Broker, submitter: Submitter,
                 monitor: MonitorAgent, *, arch: str, ckpt_dir: str,
                 total_steps: int, chunk_steps: int, batch: int = 8,
                 seq: int = 64, data_seed: int = 0,
                 timeout_s: float = 120.0):
        self.submitter = submitter
        self.monitor = monitor
        self.arch = arch
        self.ckpt_dir = ckpt_dir
        self.total_steps = total_steps
        self.chunk_steps = chunk_steps
        self.batch = batch
        self.seq = seq
        self.data_seed = data_seed
        self.timeout_s = timeout_s
        self.chunk_results: list[dict] = []

    def _submit_chunk(self, start: int) -> str:
        n = min(self.chunk_steps, self.total_steps - start)
        return self.submitter.submit(
            "train_chunk",
            task_id=f"train-{self.arch}-s{start:06d}",
            params={"arch": self.arch, "ckpt_dir": self.ckpt_dir,
                    "start_step": start, "n_steps": n, "batch": self.batch,
                    "seq": self.seq, "data_seed": self.data_seed},
            timeout_s=self.timeout_s)

    def run(self, wait_timeout: float = 300.0) -> dict:
        start = 0
        while start < self.total_steps:
            tid = self._submit_chunk(start)
            ok = self.monitor.wait_all([tid], timeout=wait_timeout)
            if not ok:
                raise TimeoutError(f"chunk {tid} did not complete")
            entry = self.monitor.task(tid)
            res = entry.result
            self.chunk_results.append(res)
            start = int(res["final_step"])
        return {"final_step": start,
                "final_loss": self.chunk_results[-1]["loss"],
                "chunks": len(self.chunk_results)}
