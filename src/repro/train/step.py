"""Step builders: train / prefill / serve — shared by smoke tests, the KSA
trainer tasks, and the multi-pod dry-run.

``dist=None`` gives the single-device path; with a
:class:`repro.sharding.DistContext` the same builders emit the sharded
program (vocab-parallel loss, MoE expert-parallel island, activation
constraints)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import init_params, param_shapes
from repro.models.transformer import forward, init_caches, model_spec
from repro.optim import (OptimizerConfig, adamw_init, adamw_update,
                         lr_at_step)
from .loss import lm_loss


@dataclass
class TrainState:
    params: Any
    opt: dict
    step: jnp.ndarray

    def tree_flatten(self):  # registered below
        return (self.params, self.opt, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.step), None),
    lambda aux, ch: TrainState(*ch))


def init_train_state(cfg: ModelConfig, ocfg: OptimizerConfig,
                     rng: jax.Array) -> TrainState:
    spec = model_spec(cfg)
    params = init_params(spec, rng, jnp.dtype(cfg.dtype))
    return TrainState(params=params, opt=adamw_init(params, ocfg),
                      step=jnp.zeros((), jnp.int32))


def train_state_shapes(cfg: ModelConfig, ocfg: OptimizerConfig) -> TrainState:
    """abstract TrainState (dry-run input spec, no allocation)."""
    return jax.eval_shape(
        lambda: init_train_state(cfg, ocfg, jax.random.PRNGKey(0)))


def _loss_fn(params, cfg: ModelConfig, batch: dict, dist, remat: str,
             aux_weight: float, unroll: int | bool = 1):
    weights = batch.get("weights")
    fused = (dist is not None and dist.has("chunked_ce")
             and cfg.padded_vocab % dist.tp_size == 0)
    if fused:
        hidden, _, aux = forward(params, cfg, batch, dist=dist, remat=remat,
                                 unroll=unroll, return_hidden=True)
        loss, metrics = dist.fused_ce(hidden, params["embed"],
                                      cfg.tie_embeddings, batch["labels"],
                                      weights)
    else:
        logits, _, aux = forward(params, cfg, batch, dist=dist, remat=remat,
                                 unroll=unroll)
        if dist is not None:
            loss, metrics = dist.vocab_parallel_loss(logits, batch["labels"],
                                                     weights)
        else:
            loss, metrics = lm_loss(logits, batch["labels"], weights)
    loss = loss + aux_weight * aux
    metrics["aux_loss"] = aux
    return loss, metrics


def make_train_step(cfg: ModelConfig, ocfg: OptimizerConfig, *,
                    dist: Any = None, remat: str = "none",
                    microbatch: int | None = None,
                    accum_dtype: str = "float32",
                    unroll: int | bool = 1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``microbatch``: split the batch into this many sequential chunks with
    gradient accumulation (a ``lax.scan``, so HLO stays small).
    ``accum_dtype``: gradient-accumulator dtype — bf16 halves the accumulator
    footprint (needed to fit the 671B config on a single pod)."""
    aux_w = cfg.moe.router_aux_weight if cfg.moe is not None else 0.0
    adt = jnp.dtype(accum_dtype)

    grad_fn = jax.value_and_grad(
        lambda p, b: _loss_fn(p, cfg, b, dist, remat, aux_w, unroll),
        has_aux=True)

    def compute_grads(params, batch):
        if not microbatch or microbatch <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        def reshape(x):
            return x.reshape((microbatch, x.shape[0] // microbatch)
                             + x.shape[1:])
        mb = jax.tree.map(reshape, batch)

        def body(carry, b_i):
            acc, loss_acc = carry
            (loss, metrics), g = grad_fn(params, b_i)
            acc = jax.tree.map(lambda a, x: a + x.astype(adt), acc, g)
            return (acc, loss_acc + loss), metrics

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
        (gacc, loss_sum), ms = jax.lax.scan(body, (zero, 0.0), mb)
        grads = jax.tree.map(lambda g: g / microbatch, gacc)
        metrics = jax.tree.map(lambda m: m[-1], ms)
        return loss_sum / microbatch, metrics, grads

    def train_step(state: TrainState, batch: dict):
        loss, metrics, grads = compute_grads(state.params, batch)
        lr = lr_at_step(state.step, base_lr=ocfg.lr,
                        warmup_steps=ocfg.warmup_steps,
                        total_steps=ocfg.total_steps, schedule=ocfg.schedule)
        params, opt, stats = adamw_update(state.params, grads, state.opt,
                                          ocfg, lr)
        metrics = dict(metrics, loss=loss, **stats)
        return TrainState(params, opt, state.step + 1), metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, *, dist: Any = None,
                      unroll: int | bool = 1) -> Callable:
    """prefill(params, batch, caches) -> (last-token logits, caches).
    Encoder-only models take no caches and return per-frame logits."""
    if cfg.encoder_only:
        def prefill_enc(params, batch):
            logits, _, _ = forward(params, cfg, batch, dist=dist,
                                   unroll=unroll)
            return logits
        return prefill_enc

    def prefill(params, batch, caches):
        logits, new_caches, _ = forward(
            params, cfg, batch, caches=caches,
            cache_index=jnp.zeros((), jnp.int32), dist=dist, unroll=unroll)
        return logits[:, -1], new_caches

    return prefill


def make_serve_step(cfg: ModelConfig, *, dist: Any = None,
                    unroll: int | bool = 1, paged: bool = False,
                    decode_kernel: str | None = None) -> Callable:
    """serve_step(params, tokens (B,1), caches, cache_index[, pages]) ->
    (next-token logits (B, V), new caches). One decode step against the
    cache; greedy next-token id is returned alongside for convenience.

    ``decode_kernel`` overrides ``cfg.decode_kernel`` ("chunked" reference |
    "flash" split-KV kernel). ``paged=True`` compiles the paged-cache step,
    which takes the (B, pages_per_slot) page table as a fifth argument
    (caches from ``init_paged_caches``)."""
    if decode_kernel is not None:
        cfg = cfg.with_(decode_kernel=decode_kernel)

    def _finish(logits):
        logits = logits[:, -1]
        if cfg.padded_vocab != cfg.vocab_size:  # mask vocab padding
            pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad_mask[None, :], -1e30, logits)
        next_id = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, next_id

    if paged:
        def serve_step(params, tokens, caches, cache_index, pages):
            logits, new_caches, _ = forward(
                params, cfg, {"tokens": tokens}, caches=caches,
                cache_index=cache_index, dist=dist, unroll=unroll,
                pages=pages)
            logits, next_id = _finish(logits)
            return logits, next_id, new_caches
        return serve_step

    def serve_step(params, tokens, caches, cache_index):
        logits, new_caches, _ = forward(params, cfg, {"tokens": tokens},
                                        caches=caches,
                                        cache_index=cache_index, dist=dist,
                                        unroll=unroll)
        logits, next_id = _finish(logits)
        return logits, next_id, new_caches

    return serve_step


def make_decode_caches(cfg: ModelConfig, batch: int, max_len: int):
    return init_caches(cfg, batch, max_len, jnp.dtype(cfg.dtype))
