"""DistContext — everything the model/step builders need to emit a sharded
program: activation constraints, the expert-parallel MoE island, and the
vocab-parallel (Megatron-style) cross-entropy island.

Design (DESIGN.md §5): GSPMD (pjit + with_sharding_constraint) is the global
strategy — FSDP/ZeRO-3 parameter sharding over ``(pod, data)``, tensor
parallelism over ``model`` — with two explicit ``shard_map`` islands where
GSPMD's inferred collectives would be wrong or wasteful:

* **MoE island**: experts live on the ``model`` axis; activations arrive
  replicated over ``model`` (they are, after the attention psum), every rank
  routes all of its data-shard's tokens, computes its local experts, and one
  ``psum`` combines — the same collective footprint as a dense TP FFN, with
  no (T, E, C) one-hot and no all-to-all. Expert weights are FSDP-gathered
  inside the island (manual ZeRO-3; the backward all-gather→reduce-scatter
  transposition is automatic).
* **CE island**: logits stay vocab-sharded; per-shard logsumexp and the
  label-hit logit are psum'd, so the full (B, S, V) logits never materialize
  replicated.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # promoted out of experimental in jax 0.6
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map

from repro.models.config import ModelConfig
from repro.models.moe import moe_capacity, shared_expert
from .rules import batch_spec, resolve_spec, tree_shardings


@dataclass(frozen=True)
class DistContext:
    mesh: Mesh
    tp_axis: str = "model"
    # opt-in beyond-baseline optimizations (§Perf hillclimbs):
    #   "flash_decode" — sequence-parallel decode attention island (partial
    #                    softmax merge via psum instead of cache all-gather),
    #   "chunked_ce"   — fused unembed+CE island, scanned over token chunks
    #                    (full fp32 logits never materialize),
    #   "fp8_gather"   — FSDP expert-weight gathers in float8_e4m3.
    flags: frozenset = frozenset()

    def has(self, flag: str) -> bool:
        return flag in self.flags

    @property
    def fsdp_axes(self) -> tuple[str, ...]:
        return tuple(n for n in self.mesh.axis_names if n != self.tp_axis)

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return self.fsdp_axes

    @property
    def tp_size(self) -> int:
        return int(self.mesh.shape[self.tp_axis])

    @property
    def n_devices(self) -> int:
        return int(self.mesh.size)

    # -- spec helpers ---------------------------------------------------------

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def batch_pspec(self, ndim: int, batch_size: int) -> P:
        return batch_spec(ndim, self.batch_axes, batch_size, self.mesh)

    def param_shardings(self, shapes_tree: Any, axes_tree: Any) -> Any:
        return tree_shardings(shapes_tree, axes_tree, self.mesh,
                              fsdp_axes=self.fsdp_axes, tp_axis=self.tp_axis)

    # -- activation constraint ---------------------------------------------------

    def constrain_activation(self, x: jax.Array) -> jax.Array:
        """(B, S, d) activations: batch over data axes, replicated elsewhere."""
        spec = self.batch_pspec(x.ndim, x.shape[0])
        return jax.lax.with_sharding_constraint(x, self.named(spec))

    # -- MoE island ------------------------------------------------------------------

    def moe_island(self, params: dict, cfg: ModelConfig, x: jax.Array, *,
                   decode: bool = False) -> tuple[jax.Array, jax.Array]:
        """x: (B, S, d) -> (y, aux). Experts sharded over ``model``."""
        e = cfg.moe
        tp, fsdp = self.tp_axis, self.fsdp_axes
        if e.n_experts % self.tp_size == 0:
            n_local = e.n_experts // self.tp_size
            expert_sh = tp
        else:  # tiny smoke meshes: replicate experts
            n_local = e.n_experts
            expert_sh = None
        b, s, d = x.shape
        bspec = self.batch_pspec(3, b)
        bax = bspec[0]
        # expert weights: (E, d, f) — E over model, d over fsdp (if divisible)
        d_sh = fsdp if d % _size(self.mesh, fsdp) == 0 else None
        if d_sh is not None and len(d_sh) == 1:
            d_sh = d_sh[0]
        w_spec = P(expert_sh, d_sh, None)
        capacity = None
        tokens_local = (b * s) // _size(self.mesh, _axes_of(bspec[0]))
        if decode:
            capacity = tokens_local
        else:
            capacity = max(1, -(-int(e.top_k * tokens_local *
                                     e.capacity_factor) // e.n_experts))

        if decode and self.has("weight_stationary"):
            return self._moe_ws_island(params, cfg, x, n_local=n_local,
                                       expert_sh=expert_sh, d_sh=d_sh,
                                       capacity=capacity, bax=bax)
        fp8 = self.has("fp8_gather")

        def gathered(w, axis):
            if fp8:
                # fp8 weight gather (DeepSeek-V3 trains in fp8): halves FSDP
                # gather bytes; the transpose reduce-scatter of grads is then
                # also fp8 — acceptable for expert weights per DSv3, noted in
                # EXPERIMENTS.md §Perf.
                w8 = w.astype(jnp.float8_e4m3fn)
                return jax.lax.all_gather(w8, fsdp, axis=axis,
                                          tiled=True).astype(w.dtype)
            return jax.lax.all_gather(w, fsdp, axis=axis, tiled=True)

        def island(router, w_gate, w_up, w_down, xl):
            if d_sh is not None:
                w_gate = gathered(w_gate, 1)
                w_up = gathered(w_up, 1)
                w_down_g = gathered(w_down, 2)
            else:
                w_down_g = w_down
            e0 = (jax.lax.axis_index(tp) * n_local if expert_sh is not None
                  else 0)
            flat = xl.reshape(-1, d)
            y, aux = moe_capacity(
                {"router": router, "w_gate": w_gate, "w_up": w_up,
                 "w_down": w_down_g}, cfg, flat,
                e0=e0, n_local=n_local, capacity=capacity)
            y = jax.lax.psum(y, tp)
            # aux is invariant over `model` (same router, same tokens on every
            # tp rank); mean over exactly the axes the batch is sharded on.
            if _axes_of(bax):
                aux = jax.lax.pmean(aux, _axes_of(bax))
            return y.reshape(xl.shape), aux

        # w_down: (E, f, d) — d is axis 2
        wd_spec = P(expert_sh, None, d_sh)
        y, aux = shard_map(
            island, mesh=self.mesh,
            in_specs=(P(None, None), w_spec, w_spec, wd_spec,
                      P(bax, None, None)),
            out_specs=(P(bax, None, None), P()),
        )(params["router"], params["w_gate"], params["w_up"],
          params["w_down"], x)
        if e.n_shared:
            y = y + shared_expert(params, cfg, x.reshape(-1, d)).reshape(x.shape)
        return y, aux

    # -- weight-stationary decode MoE --------------------------------------------

    def _moe_ws_island(self, params: dict, cfg: ModelConfig, x: jax.Array, *,
                       n_local: int, expert_sh, d_sh, capacity: int, bax
                       ) -> tuple[jax.Array, jax.Array]:
        """Decode-time MoE that never gathers expert weights: tokens are tiny
        at decode (B ≤ a few hundred), so the island all-gathers the *token*
        activations over the FSDP axes, computes with the local d-slice of
        each expert weight, and psums the (E_local, C, f) partials — per-layer
        traffic drops from O(expert-weight bytes) to O(token-activation
        bytes), a ~40× cut on the 671B decode cell (EXPERIMENTS.md §Perf)."""
        from repro.models.moe import router_topk
        e = cfg.moe
        tp, fsdp = self.tp_axis, self.fsdp_axes
        b, s, d = x.shape
        n_fsdp = _size(self.mesh, fsdp)
        d_local = d // n_fsdp if d_sh is not None else d

        def island(router, w_gate, w_up, w_down, xl):
            # gather all tokens (decode: a few hundred rows) over FSDP axes
            flat = xl.reshape(-1, d)
            xg = (jax.lax.all_gather(flat, fsdp, axis=0, tiled=True)
                  if _axes_of(bax) else flat)
            t_g = xg.shape[0]
            gates, idx, aux = router_topk(
                {"router": router}, cfg, xg)
            e0 = (jax.lax.axis_index(tp) * n_local if expert_sh is not None
                  else 0)
            if d_sh is not None:
                di = jnp.zeros((), jnp.int32)
                mul = 1
                for ax in reversed(fsdp):
                    di = di + jax.lax.axis_index(ax) * mul
                    mul *= self.mesh.shape[ax]
                x_slice = jax.lax.dynamic_slice_in_dim(
                    xg, di * d_local, d_local, axis=1)
            else:
                x_slice = xg
            cap = max(capacity, t_g)  # decode: dropless
            # dispatch into (E_local * cap, d_local) buffers
            buf = jnp.zeros((n_local * cap, d_local), x_slice.dtype)
            carry = jnp.zeros((e.n_experts,), jnp.int32)
            slots = []
            for j in range(e.top_k):
                oh = jax.nn.one_hot(idx[:, j], e.n_experts, dtype=jnp.int32)
                within = jnp.cumsum(oh, axis=0) - oh
                pos_j = jnp.sum((within + carry[None, :]) * oh, axis=-1)
                carry = carry + oh.sum(0)
                local_e = idx[:, j] - e0
                ok = (local_e >= 0) & (local_e < n_local) & (pos_j < cap)
                slot = jnp.where(ok, local_e * cap + pos_j, n_local * cap)
                slots.append((slot, ok))
                buf = buf.at[slot].add(
                    x_slice * ok[:, None].astype(x_slice.dtype), mode="drop")
            h = buf.reshape(n_local, cap, d_local)
            # partial contractions over the local d-slice, psum'd over FSDP
            g_p = jnp.einsum("ecd,edf->ecf", h, w_gate.astype(h.dtype))
            u_p = jnp.einsum("ecd,edf->ecf", h, w_up.astype(h.dtype))
            if d_sh is not None:
                g_p = jax.lax.psum(g_p, fsdp)
                u_p = jax.lax.psum(u_p, fsdp)
            act = jax.nn.silu(g_p) * u_p
            out_slice = jnp.einsum("ecf,efd->ecd", act,
                                   w_down.astype(h.dtype))  # (E_l, cap, d_l)
            out_flat = out_slice.reshape(n_local * cap, d_local)
            y = jnp.zeros((t_g, d_local), x_slice.dtype)
            for j, (slot, ok) in enumerate(slots):
                picked = jnp.take(out_flat,
                                  jnp.minimum(slot, n_local * cap - 1),
                                  axis=0)
                w = gates[:, j].astype(y.dtype) * ok.astype(y.dtype)
                y = y + picked * w[:, None]
            # reassemble full-d rows, slice back this rank's tokens
            if d_sh is not None:
                y = jax.lax.all_gather(y, fsdp, axis=1, tiled=True)  # (t_g, d)
            if _axes_of(bax):
                bi = jnp.zeros((), jnp.int32)
                mul = 1
                for ax in reversed(_axes_of(bax)):
                    bi = bi + jax.lax.axis_index(ax) * mul
                    mul *= self.mesh.shape[ax]
                t_loc = flat.shape[0]
                y = jax.lax.dynamic_slice_in_dim(y, bi * t_loc, t_loc, axis=0)
            y = jax.lax.psum(y, tp)
            # aux is numerically identical on every rank (router ran on the
            # gathered token set); pmean just marks it replicated for VMA.
            if _axes_of(bax):
                aux = jax.lax.pmean(aux, _axes_of(bax))
            return y.reshape(xl.shape), aux

        w_spec = P(expert_sh, d_sh, None)
        wd_spec = P(expert_sh, None, d_sh)
        y, aux = shard_map(
            island, mesh=self.mesh,
            in_specs=(P(None, None), w_spec, w_spec, wd_spec,
                      P(bax, None, None)),
            out_specs=(P(bax, None, None), P()),
        )(params["router"], params["w_gate"], params["w_up"],
          params["w_down"], x)
        if cfg.moe.n_shared:
            from repro.models.moe import shared_expert
            y = y + shared_expert(params, cfg,
                                  x.reshape(-1, d)).reshape(x.shape)
        return y, aux

    # -- flash-decode: sequence-parallel attention over a seq-sharded cache ----

    def decode_attention(self, q: jax.Array, k: jax.Array, v: jax.Array,
                         k_positions: jax.Array, k_valid: jax.Array, *,
                         window: int | None = None,
                         kv_chunk: int = 1024,
                         q_offset: jax.Array | int = 0,
                         scale: float | None = None) -> jax.Array:
        """q: (B, 1, H, Dk) replicated over ``model``; k/v: (B, S, K, D*)
        sharded over ``model`` on the sequence dim. Each rank attends over its
        local S/tp cache slice; partial (out, m, l) softmax stats merge with
        one tiny psum — the cache never crosses the interconnect (the
        flash-decode pattern, replacing GSPMD's per-layer cache all-gather).
        """
        from repro.models.attention import chunked_attention
        tp = self.tp_axis
        b = q.shape[0]
        bspec = self.batch_pspec(4, b)
        bax = bspec[0]

        def island(ql, kl, vl, kpos, kval, qoff):
            out, m, l = chunked_attention(
                ql, kl, vl, q_offset=qoff, k_positions=kpos, k_valid=kval,
                causal=True, window=window, kv_chunk=kv_chunk, scale=scale,
                return_stats=True)
            m_g = jax.lax.pmax(m, tp)
            alpha = jnp.exp(m - m_g) * l                     # (B, 1, H)
            l_g = jax.lax.psum(alpha, tp)
            o = jax.lax.psum(out.astype(jnp.float32) * alpha[..., None], tp)
            return (o / jnp.maximum(l_g, 1e-37)[..., None]).astype(q.dtype)

        qoff = (jnp.asarray(q_offset, jnp.int32)
                if not isinstance(q_offset, int) else
                jnp.full((b,), q_offset, jnp.int32))
        if qoff.ndim == 0:
            qoff = jnp.broadcast_to(qoff[None], (b,))
        return shard_map(
            island, mesh=self.mesh,
            in_specs=(P(bax, None, None, None), P(bax, tp, None, None),
                      P(bax, tp, None, None), P(bax, tp), P(bax, tp),
                      P(bax)),
            out_specs=P(bax, None, None, None),
        )(q, k, v, k_positions, k_valid, qoff)

    # -- chunked fused CE: unembed + loss without materializing logits ---------

    def fused_ce(self, hidden: jax.Array, embed_params: dict,
                 tie_embeddings: bool, labels: jax.Array,
                 weights: jax.Array | None = None,
                 z_weight: float = 1e-4, chunk: int = 512
                 ) -> tuple[jax.Array, dict]:
        """hidden: (B, S, d) batch-sharded; unembed weight vocab-sharded.
        Scans token chunks inside the island with remat, so the live logits
        working set is (chunk × V/tp) fp32 instead of (S × V/tp) × ~15 copies
        (measured via memory_analysis bisection — see EXPERIMENTS.md §Perf).
        """
        tp = self.tp_axis
        b, s, d = hidden.shape
        w = (embed_params["embedding"].T if tie_embeddings
             else embed_params["unembed"])
        v = w.shape[-1]
        if v % self.tp_size != 0:
            from repro.train.loss import lm_loss
            from repro.models.layers import unembed as _unembed
            raise ValueError("fused_ce requires vocab divisible by tp")
        bspec = self.batch_pspec(3, b)
        bax = bspec[0]
        if weights is None:
            weights = jnp.ones((b, s), jnp.float32)
        fsdp = self.fsdp_axes
        d_sharded = d % _size(self.mesh, fsdp) == 0

        def island(h, wl, lb, wt):
            if d_sharded:
                wl = jax.lax.all_gather(wl, fsdp, axis=0, tiled=True)
            v_local = wl.shape[-1]
            v0 = jax.lax.axis_index(tp) * v_local
            # token-chunk scan over the flattened local tokens
            hb = h.reshape(-1, d)
            lbf = lb.reshape(-1)
            wtf = wt.reshape(-1).astype(jnp.float32)
            t = hb.shape[0]
            cc = min(chunk, t)
            n = -(-t // cc)
            padt = n * cc - t
            if padt:
                hb = jnp.pad(hb, ((0, padt), (0, 0)))
                lbf = jnp.pad(lbf, (0, padt))
                wtf = jnp.pad(wtf, (0, padt))

            def body(carry, i):
                ce_acc, z_acc = carry
                hc = jax.lax.dynamic_slice_in_dim(hb, i * cc, cc, 0)
                lc = jax.lax.dynamic_slice_in_dim(lbf, i * cc, cc, 0)
                wc = jax.lax.dynamic_slice_in_dim(wtf, i * cc, cc, 0)
                lg = (hc @ wl).astype(jnp.float32)
                m_local = jax.lax.stop_gradient(lg.max(-1))
                m = jax.lax.stop_gradient(jax.lax.pmax(m_local, tp))
                lse = m + jnp.log(jax.lax.psum(
                    jnp.exp(lg - m[:, None]).sum(-1), tp))
                idx = jnp.clip(lc.astype(jnp.int32) - v0, 0, v_local - 1)
                hit = (lc >= v0) & (lc < v0 + v_local)
                ll = jax.lax.psum(
                    jnp.where(hit, jnp.take_along_axis(
                        lg, idx[:, None], axis=-1)[:, 0], 0.0), tp)
                nll = lse - ll
                ce_acc = ce_acc + (nll * wc).sum()
                z_acc = z_acc + (jnp.square(lse) * wc).sum()
                return (ce_acc, z_acc), None

            body = jax.checkpoint(body, prevent_cse=False)
            # initial accumulators must carry the same varying-axes type as
            # the body outputs (they vary per data shard)
            zero = jax.lax.pcast(jnp.zeros((), jnp.float32),
                                 _axes_of(bax), to="varying")
            (ce_sum, z_sum), _ = jax.lax.scan(
                body, (zero, zero), jnp.arange(n, dtype=jnp.int32))
            denom = jnp.maximum(jax.lax.psum(wtf.sum(), bax), 1.0)
            ce = jax.lax.psum(ce_sum, bax) / denom
            z = jax.lax.psum(z_sum, bax) / denom
            return ce, z, denom

        w_spec = P(fsdp if len(fsdp) > 1 else fsdp[0], tp) if d_sharded \
            else P(None, tp)
        ce, z, denom = shard_map(
            island, mesh=self.mesh,
            in_specs=(P(bax, None, None), w_spec, P(bax, None), P(bax, None)),
            out_specs=(P(), P(), P()),
        )(hidden, w, labels, weights)
        loss = ce + z_weight * z
        return loss, {"ce": ce, "z_loss": z, "tokens": denom}

    # -- vocab-parallel CE ---------------------------------------------------------------

    def vocab_parallel_loss(self, logits: jax.Array, labels: jax.Array,
                            weights: jax.Array | None = None,
                            z_weight: float = 1e-4
                            ) -> tuple[jax.Array, dict]:
        """logits: (B, S, V) vocab-sharded over ``model``; labels: (B, S)."""
        b, s, v = logits.shape
        tp = self.tp_axis
        if v % self.tp_size != 0:
            from repro.train.loss import lm_loss
            return lm_loss(logits, labels, weights)
        bspec = self.batch_pspec(3, b)
        bax = bspec[0]
        if weights is None:
            weights = jnp.ones((b, s), jnp.float32)

        def island(lg, lb, wt):
            v_local = lg.shape[-1]
            v0 = jax.lax.axis_index(tp) * v_local
            lg = lg.astype(jnp.float32)
            m_local = lg.max(axis=-1)
            # stabilizer only — gradients cancel analytically, so detach
            # (pmax has no differentiation rule).
            m = jax.lax.stop_gradient(
                jax.lax.pmax(jax.lax.stop_gradient(m_local), tp))
            sumexp = jnp.exp(lg - m[..., None]).sum(-1)
            lse = m + jnp.log(jax.lax.psum(sumexp, tp))
            idx_local = jnp.clip(lb.astype(jnp.int32) - v0, 0, v_local - 1)
            hit = (lb.astype(jnp.int32) >= v0) & \
                  (lb.astype(jnp.int32) < v0 + v_local)
            ll_local = jnp.take_along_axis(lg, idx_local[..., None],
                                           axis=-1)[..., 0]
            ll = jax.lax.psum(jnp.where(hit, ll_local, 0.0), tp)
            nll = lse - ll
            wt = wt.astype(jnp.float32)
            denom = jnp.maximum(jax.lax.psum(wt.sum(), bax), 1.0)
            ce = jax.lax.psum((nll * wt).sum(), bax) / denom
            z = jax.lax.psum((jnp.square(lse) * wt).sum(), bax) / denom
            return ce, z, denom

        ce, z, denom = shard_map(
            island, mesh=self.mesh,
            in_specs=(P(bax, None, tp), P(bax, None), P(bax, None)),
            out_specs=(P(), P(), P()),
        )(logits, labels, weights)
        loss = ce + z_weight * z
        return loss, {"ce": ce, "z_loss": z, "tokens": denom}


def _size(mesh: Mesh, axes: tuple[str, ...] | str | None) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return int(mesh.shape[axes])
    n = 1
    for a in axes:
        n *= int(mesh.shape[a])
    return n


def _axes_of(entry) -> tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)
