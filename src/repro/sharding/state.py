"""Logical-axis trees for the full TrainState (params + optimizer state) and
decode caches — ZeRO: optimizer moments/master inherit parameter shardings;
Adafactor-factored second moments drop the corresponding axis.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.models.config import ModelConfig
from repro.models.params import logical_axes, param_shapes, is_spec
from repro.models.transformer import model_spec
from repro.optim import OptimizerConfig
from repro.optim.adamw import _can_factor
from repro.train.step import TrainState


def params_axes(cfg: ModelConfig) -> Any:
    return logical_axes(model_spec(cfg))


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                        for a in x)


def state_axes(cfg: ModelConfig, ocfg: OptimizerConfig) -> TrainState:
    """Axes tree with the same structure as TrainState."""
    p_axes = params_axes(cfg)
    spec_tree = model_spec(cfg)

    def v_axes(spec):
        axes = spec.axes
        if ocfg.factored_v and _can_factor(spec.shape):
            return {"row": axes[:-1], "col": axes[:-2] + axes[-1:]}
        if ocfg.factored_v:
            return {"full": axes}
        return axes

    opt = {
        "m": p_axes,
        "v": jax.tree.map(v_axes, spec_tree, is_leaf=is_spec),
        "count": None,
    }
    if ocfg.master_dtype != "none":
        opt["master"] = p_axes
    return TrainState(params=p_axes, opt=opt, step=None)


def cache_axes(cache_shapes_tree: Any) -> Any:
    """Decode caches: dim0 is batch everywhere except stacked period caches,
    where dim0 is layers and dim1 is batch. We mark every dim None here and
    shard caches with an explicit batch rule in launch/specs.py instead."""
    return jax.tree.map(lambda s: tuple([None] * len(s.shape)),
                        cache_shapes_tree)
