from .context import DistContext
from .rules import batch_spec, resolve_spec, tree_shardings
from .state import cache_axes, params_axes, state_axes

__all__ = ["DistContext", "batch_spec", "cache_axes", "params_axes",
           "resolve_spec", "state_axes", "tree_shardings"]
