"""Logical-axis → mesh-axis resolution with divisibility fallback.

Parameters and activations carry *logical* axis names (see
``repro.models.params``). This module maps them onto the physical mesh:

* the ``model`` axis carries tensor/expert parallelism — the first logical
  axis present in ``_MODEL_CANDIDATES`` priority order that is divisible by
  the axis size wins;
* the FSDP axes (``('pod', 'data')`` multi-pod, ``('data',)`` single-pod)
  shard the largest remaining dim (ZeRO-3: parameters, gradients and
  optimizer state all inherit this);
* anything indivisible falls back to replicated for that dim (MaxText-style)
  — e.g. gemma3-1b's 4 q-heads on a 16-way model axis.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MODEL_CANDIDATES = ("experts", "heads", "kv_heads", "vocab", "ff",
                     "expert_ff", "lora")
_FSDP_CANDIDATES = ("embed", "lora", "ff", "expert_ff", "head_dim", "vocab")


def _axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[n] for n in names]))


def resolve_spec(axes: tuple[str | None, ...], shape: tuple[int, ...],
                 mesh: Mesh, *, fsdp_axes: tuple[str, ...],
                 tp_axis: str = "model") -> P:
    """One tensor: logical axes + shape -> PartitionSpec."""
    assignment: list[Any] = [None] * len(axes)
    used_dims: set[int] = set()
    tp_size = mesh.shape[tp_axis]
    # 1. model axis
    for cand in _MODEL_CANDIDATES:
        hit = False
        for i, a in enumerate(axes):
            if a == cand and shape[i] % tp_size == 0 and shape[i] > 0:
                assignment[i] = tp_axis
                used_dims.add(i)
                hit = True
                break
        if hit:
            break
    # 2. fsdp axes
    fsdp_size = _axis_size(mesh, fsdp_axes)
    for cand in _FSDP_CANDIDATES:
        hit = False
        for i, a in enumerate(axes):
            if i in used_dims:
                continue
            if a == cand and shape[i] % fsdp_size == 0 and shape[i] > 0:
                assignment[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
                used_dims.add(i)
                hit = True
                break
        if hit:
            break
    return P(*assignment)


def tree_shardings(shapes_tree: Any, axes_tree: Any, mesh: Mesh, *,
                   fsdp_axes: tuple[str, ...], tp_axis: str = "model") -> Any:
    """Map a tree of ShapeDtypeStructs + a matching tree of logical-axis
    tuples (axes tuples are *leaves* of the axes tree) to NamedShardings."""
    leaves_s, treedef = jax.tree.flatten(shapes_tree)
    leaves_a = treedef.flatten_up_to(axes_tree)

    def one(sds, axes):
        if axes is None:
            return NamedSharding(mesh, P())
        spec = resolve_spec(tuple(axes), tuple(sds.shape), mesh,
                            fsdp_axes=fsdp_axes, tp_axis=tp_axis)
        return NamedSharding(mesh, spec)

    return jax.tree.unflatten(
        treedef, [one(s, a) for s, a in zip(leaves_s, leaves_a)])


def batch_spec(ndim: int, batch_axes: tuple[str, ...], batch_size: int,
               mesh: Mesh) -> P:
    """Shard dim 0 (batch) over the data axes, with divisibility fallback."""
    size = _axis_size(mesh, batch_axes)
    if batch_size % size == 0:
        first = batch_axes if len(batch_axes) > 1 else batch_axes[0]
        return P(first, *([None] * (ndim - 1)))
    # try pod-only / data-only prefixes before giving up
    for sub in (batch_axes[:1], batch_axes[1:]):
        if sub and batch_size % _axis_size(mesh, sub) == 0:
            return P(sub if len(sub) > 1 else sub[0], *([None] * (ndim - 1)))
    return P(*([None] * ndim))
