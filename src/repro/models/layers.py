"""Shared layers: RMSNorm, RoPE, gated MLPs, embeddings.

All layers are pure functions over explicit parameter pytrees (declared via
:class:`~repro.models.params.ParamSpec`), so they can be scanned, rematted,
and dry-run lowered without a module framework.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamSpec


# -- RMSNorm -----------------------------------------------------------------

def rmsnorm_spec(dim: int) -> dict:
    return {"scale": ParamSpec((dim,), ("embed",), init="ones")}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# -- RoPE ---------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with D even; positions: (S,) or (B, S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs  # (S, D/2)
        ang = ang[None, :, None, :]                           # (1, S, 1, D/2)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
        ang = ang[:, :, None, :]                                 # (B, S, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- MLP ------------------------------------------------------------------------

def mlp_spec(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "w_gate": ParamSpec((d, f), ("embed", "ff"), init="lecun"),
            "w_up": ParamSpec((d, f), ("embed", "ff"), init="lecun"),
            "w_down": ParamSpec((f, d), ("ff", "embed"), init="lecun"),
        }
    return {  # plain gelu MLP (hubert)
        "w_up": ParamSpec((d, f), ("embed", "ff"), init="lecun"),
        "w_down": ParamSpec((f, d), ("ff", "embed"), init="lecun"),
    }


def mlp(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.mlp_kind in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else (
            lambda u: jax.nn.gelu(u, approximate=True))
        g = act(x @ params["w_gate"])
        u = x @ params["w_up"]
        return (g * u) @ params["w_down"]
    h = jax.nn.gelu(x @ params["w_up"], approximate=True)
    return h @ params["w_down"]


# -- Embedding / head ---------------------------------------------------------------

def embedding_spec(cfg: ModelConfig) -> dict:
    v = cfg.padded_vocab
    d = {"embedding": ParamSpec((v, cfg.d_model),
                                ("vocab", "embed"), init="normal", scale=0.02)}
    if not cfg.tie_embeddings:
        d["unembed"] = ParamSpec((cfg.d_model, v),
                                 ("embed", "vocab"), init="lecun")
    return d


def embed(params: dict, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    x = params["embedding"].astype(jnp.dtype(cfg.dtype))[tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embedding"].astype(x.dtype).T
    else:
        w = params["unembed"]
    return jnp.einsum("bsd,dv->bsv", x, w,
                      preferred_element_type=jnp.dtype(cfg.logit_dtype))
