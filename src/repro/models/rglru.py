"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The recurrence is a gated linear RNN:

    r_t = sigmoid(W_a u_t)                 (recurrence gate)
    i_t = sigmoid(W_x u_t)                 (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t)

computed over chunks: sequential ``lax.scan`` across chunks carrying ``h``,
log-depth ``associative_scan`` within a chunk — O(S·w) memory at chunk
granularity instead of O(S·w) fp32 live for the whole sequence. Decode is the
O(1) single-step update; the layer's "KV cache" is just ``(h, conv_state)``
regardless of context length (this is why RecurrentGemma runs the 500k-token
cell).

Deviation from Griffin noted in DESIGN.md: gate projections W_a, W_x are full
``w×w`` matrices rather than block-diagonal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, RGLRUConfig
from .params import ParamSpec


def rglru_spec(cfg: ModelConfig) -> dict:
    r = cfg.rglru or RGLRUConfig()
    d = cfg.d_model
    w = r.lru_width or d
    return {
        "w_x": ParamSpec((d, w), ("embed", "ff"), init="lecun"),
        "w_gate_branch": ParamSpec((d, w), ("embed", "ff"), init="lecun"),
        "conv_w": ParamSpec((r.conv_width, w), ("conv", "ff"), init="lecun"),
        "conv_b": ParamSpec((w,), ("ff",), init="zeros"),
        "w_a": ParamSpec((w, w), ("ff", None), init="lecun"),
        "w_i": ParamSpec((w, w), ("ff", None), init="lecun"),
        "lam": ParamSpec((w,), ("ff",), init="lambda_rglru"),
        "w_out": ParamSpec((w, d), ("ff", "embed"), init="lecun"),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. u: (B, S, W); w: (K, W); state: (B, K-1, W).
    Returns (out, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)           # (B, K-1+S, W)
    out = sum(ext[:, i:i + u.shape[1]] * w[i] for i in range(k)) + b
    new_state = ext[:, -(k - 1):] if k > 1 else state
    return out.astype(u.dtype), new_state


def _gates(params: dict, cfg: ModelConfig, u: jax.Array
           ) -> tuple[jax.Array, jax.Array]:
    """-> (a (log-space f32), gated input), both (..., W) f32."""
    r = cfg.rglru or RGLRUConfig()
    rt = jax.nn.sigmoid(u @ params["w_a"].astype(u.dtype)).astype(jnp.float32)
    it = jax.nn.sigmoid(u @ params["w_i"].astype(u.dtype)).astype(jnp.float32)
    log_a = -r.c * jax.nn.softplus(params["lam"].astype(jnp.float32)) * rt
    a = jnp.exp(log_a)
    x_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * \
        (it * u.astype(jnp.float32))
    return a, x_in


def rglru_scan(params: dict, cfg: ModelConfig, u: jax.Array, *,
               h0: jax.Array | None = None, chunk: int = 512
               ) -> tuple[jax.Array, jax.Array]:
    """u: (B, S, W) -> (h_seq (B, S, W) in u.dtype, h_final (B, W) f32)."""
    b, s, w = u.shape
    a, x_in = _gates(params, cfg, u)
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)
    c = min(chunk, s)
    n = -(-s // c)
    pad = n * c - s
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        x_in = jnp.pad(x_in, ((0, 0), (0, pad), (0, 0)))
    a_c = a.reshape(b, n, c, w).transpose(1, 0, 2, 3)
    x_c = x_in.reshape(b, n, c, w).transpose(1, 0, 2, 3)

    def chunk_body(h, inp):
        ac, xc = inp
        # h_t within chunk: prefix-product/sum via associative scan
        def combine(p, q):
            (pa, pb), (qa, qb) = p, q
            return pa * qa, qa * pb + qb
        aa, bb = jax.lax.associative_scan(combine, (ac, xc), axis=1)
        hseq = aa * h[:, None, :] + bb
        return hseq[:, -1, :], hseq

    h_fin, chunks = jax.lax.scan(chunk_body, h0, (a_c, x_c))
    hs = chunks.transpose(1, 0, 2, 3).reshape(b, n * c, w)[:, :s]
    return hs.astype(u.dtype), h_fin


def rglru_step(params: dict, cfg: ModelConfig, u: jax.Array,
               h: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Decode: u (B, 1, W), h (B, W) f32 -> (out (B, 1, W), h_new)."""
    a, x_in = _gates(params, cfg, u)
    h_new = a[:, 0] * h + x_in[:, 0]
    return h_new[:, None, :].astype(u.dtype), h_new


def rglru_block(params: dict, cfg: ModelConfig, x: jax.Array, *,
                cache: dict | None = None
                ) -> tuple[jax.Array, dict | None]:
    """Full Griffin recurrent block: in-proj → conv → RG-LRU, gated, out-proj.

    x: (B, S, d). ``cache``: {"h": (B, W) f32, "conv": (B, K-1, W)}.
    """
    dt = x.dtype
    u = x @ params["w_x"].astype(dt)
    gate = jax.nn.gelu(x @ params["w_gate_branch"].astype(dt), approximate=True)
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(u, params["conv_w"].astype(dt),
                               params["conv_b"].astype(dt), conv_state)
    if cache is not None and x.shape[1] == 1:
        hs, h_new = rglru_step(params, cfg, u, cache["h"])
    else:
        h0 = cache["h"] if cache is not None else None
        hs, h_new = rglru_scan(params, cfg, u, h0=h0)
    y = (hs * gate) @ params["w_out"].astype(dt)
    new_cache = None
    if cache is not None:
        new_cache = {"h": h_new, "conv": new_conv}
    return y, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    r = cfg.rglru or RGLRUConfig()
    w = r.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, r.conv_width - 1, w), dtype),
    }
