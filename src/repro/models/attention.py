"""Attention: GQA with chunked online-softmax (the XLA-native flash analogue).

One code path serves training, prefill, and decode:

* KV is processed in chunks with running (max, sum, acc) statistics, so the
  live logits footprint is ``O(S_q × kv_chunk)`` instead of ``O(S_q × S_k)``
  — this is what keeps the HLO-bytes roofline term honest on 32k prefills.
* ``q_offset`` may be per-batch (continuous batching / decode).
* ``window`` enables sliding-window (local) attention. For training/prefill
  the *banded* fast path slices only the KV band each q-chunk needs, so FLOPs
  are ``O(S·(window+chunk))`` rather than ``O(S²)``. For decode, local layers
  use a **ring-buffer cache** of size ``window`` (a 500k-token context costs
  O(window) HBM on 5/6 of Gemma-3 layers and *all* RecurrentGemma layers).
* bidirectional (encoder) attention is ``causal=False, window=None``.

A Pallas TPU kernel (``repro.kernels.flash_attention``) implements the same
contract for the perf-critical path; this module is its reference and the
dry-run lowering target.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamSpec
from .layers import apply_rope, rmsnorm, rmsnorm_spec

NEG_INF = -1e30


def attention_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    spec = {
        "wq": ParamSpec((d, cfg.n_heads, cfg.head_dim),
                        ("embed", "heads", "head_dim"), init="lecun"),
        "wk": ParamSpec((d, cfg.n_kv_heads, cfg.head_dim),
                        ("embed", "kv_heads", "head_dim"), init="lecun"),
        "wv": ParamSpec((d, cfg.n_kv_heads, cfg.head_dim),
                        ("embed", "kv_heads", "head_dim"), init="lecun"),
        "wo": ParamSpec((cfg.n_heads, cfg.head_dim, d),
                        ("heads", "head_dim", "embed"), init="lecun"),
    }
    if cfg.use_qk_norm:
        spec["q_norm"] = {"scale": ParamSpec((cfg.head_dim,), (None,), init="ones")}
        spec["k_norm"] = {"scale": ParamSpec((cfg.head_dim,), (None,), init="ones")}
    return spec


def _expand_positions(q_offset: jax.Array | int, b: int, s: int) -> jax.Array:
    """-> (B, S) absolute positions."""
    base = jnp.arange(s, dtype=jnp.int32)
    if isinstance(q_offset, int):
        return jnp.broadcast_to(base[None, :] + q_offset, (b, s))
    q_offset = jnp.asarray(q_offset, jnp.int32)
    if q_offset.ndim == 0:
        return jnp.broadcast_to(base[None, :] + q_offset, (b, s))
    return q_offset[:, None] + base[None, :]


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      q_offset: jax.Array | int = 0,
                      k_positions: jax.Array | None = None,
                      causal: bool = True,
                      window: int | None = None,
                      kv_chunk: int = 1024,
                      k_valid: jax.Array | None = None,
                      scale: float | None = None,
                      return_stats: bool = False,
                      score_dtype=jnp.float32):
    """q: (B, Sq, H, Dk); k: (B, Sk, K, Dk); v: (B, Sk, K, Dv), H % K == 0.
    Dv may differ from Dk (MLA decodes attention in the compressed latent).

    ``k_positions``: (B, Sk) absolute positions of cache slots (ring caches);
    default is ``arange(Sk)``. ``k_valid``: (B, Sk) filled-slot mask.
    Returns (B, Sq, H, Dv); accumulates in f32.
    """
    b, sq, h, dh = q.shape
    _, sk, kh, _ = k.shape
    dv = v.shape[-1]
    g = h // kh
    if scale is None:
        scale = 1.0 / math.sqrt(dh)
    qh = q.reshape(b, sq, kh, g, dh)
    q_pos = _expand_positions(q_offset, b, sq)

    c = min(kv_chunk, sk)
    n_chunks = -(-sk // c)
    pad = n_chunks * c - sk
    if k_positions is None:
        k_positions = jnp.broadcast_to(
            jnp.arange(sk, dtype=jnp.int32)[None, :], (b, sk))
    if k_valid is None:
        k_valid = jnp.ones((b, sk), bool)
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, ((0, 0), (0, pad)),
                              constant_values=-1)
        k_valid = jnp.pad(k_valid, ((0, 0), (0, pad)))

    # banded fast path: training/prefill sliding-window attention touches only
    # the KV band [q_chunk_start - window, q_chunk_end).
    if (window is not None and causal and sq > 1 and sk == sq and sk > c
            and pad == 0 and dv == dh):
        return _banded_local_attention(qh, k, v, q_pos, window=window,
                                       chunk=c, scale=scale, sq=sq)

    # IMPORTANT: chunks are sliced inside the scan body (dynamic_slice on the
    # loop-invariant operand) rather than pre-stacked as scan xs — stacking
    # would materialize a transposed copy of the entire K/V (for decode, of
    # the entire cache: +2× cache HBM, caught by the dry-run memory analysis).
    def body(carry, i):
        m_run, l_run, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(k, i * c, c, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, i * c, c, axis=1)
        kpos_c = jax.lax.dynamic_slice_in_dim(k_positions, i * c, c, axis=1)
        kval_c = jax.lax.dynamic_slice_in_dim(k_valid, i * c, c, axis=1)
        sdt = jnp.dtype(score_dtype)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qh, kc,
                       preferred_element_type=sdt) * jnp.asarray(scale, sdt)
        qp = q_pos[:, :, None]           # (B, Sq, 1)
        kp = kpos_c[:, None, :]          # (B, 1, C)
        mask = kval_c[:, None, :] & (kp >= 0)
        if causal:
            mask &= kp <= qp
        if window is not None:
            mask &= kp > qp - window
        neg = NEG_INF if sdt == jnp.float32 else -6e4  # bf16-representable
        s = jnp.where(mask[:, :, None, None, :], s, jnp.asarray(neg, sdt))
        m_new = jnp.maximum(m_run, s.max(axis=-1).astype(jnp.float32))
        # probabilities stay in score_dtype (bf16 halves the two dominant
        # S×chunk buffers); running stats stay f32.
        p = jnp.exp(s - m_new[..., None].astype(sdt))
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(axis=-1, dtype=jnp.float32)
        pv = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(vc.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l_new, acc), None

    # tie the initial carries to the inputs so they inherit the inputs'
    # varying-axes type under shard_map (flash-decode island); constant-folds
    # to plain zeros outside shard_map.
    tie = (q.reshape(-1)[0] * 0 + k.reshape(-1)[0] * 0).astype(jnp.float32)
    m0 = jnp.full((b, sq, kh, g), NEG_INF, jnp.float32) + tie
    l0 = jnp.zeros((b, sq, kh, g), jnp.float32) + tie
    a0 = jnp.zeros((b, sq, kh, g, dv), jnp.float32) + tie
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      jnp.arange(n_chunks, dtype=jnp.int32))
    out = acc / jnp.maximum(l_f[..., None], 1e-37)
    out = out.reshape(b, sq, h, dv).astype(q.dtype)
    if return_stats:
        # (B, Sq, H) running max / normalizer — lets callers merge partial
        # attention across sequence shards (flash-decode island).
        return out, m_f.reshape(b, sq, h), l_f.reshape(b, sq, h)
    return out


def _banded_local_attention(qh: jax.Array, k: jax.Array, v: jax.Array,
                            q_pos: jax.Array, *, window: int, chunk: int,
                            scale: float, sq: int) -> jax.Array:
    """Sliding-window attention computing only the needed KV band per q-chunk.
    qh: (B, Sq, K, G, Dh), Sq divisible by ``chunk``."""
    b, _, kh, g, dh = qh.shape
    c = chunk
    n_q = sq // c
    band = -(-window // c) * c + c  # kv band length per q chunk (>= window+c)
    # left-pad k/v so the band slice is always in range
    kp = jnp.pad(k, ((0, 0), (band - c, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (band - c, 0), (0, 0), (0, 0)))

    def per_q_chunk(i):
        qc = jax.lax.dynamic_slice_in_dim(qh, i * c, c, axis=1)
        pos_c = jax.lax.dynamic_slice_in_dim(q_pos, i * c, c, axis=1)
        kc = jax.lax.dynamic_slice_in_dim(kp, i * c, band, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(vp, i * c, band, axis=1)
        k_pos = i * c - (band - c) + jnp.arange(band, dtype=jnp.int32)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qc, kc,
                       preferred_element_type=jnp.float32) * scale
        mask = (k_pos[None, None, :] <= pos_c[:, :, None]) & \
               (k_pos[None, None, :] > pos_c[:, :, None] - window) & \
               (k_pos[None, None, :] >= 0)
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        o = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(vc.dtype), vc,
                       preferred_element_type=jnp.float32)
        return (o / jnp.maximum(p.sum(-1)[..., None], 1e-37)).astype(k.dtype)

    outs = jax.lax.map(per_q_chunk, jnp.arange(n_q, dtype=jnp.int32))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, n_q * c, kh, g, dh)
    return out[:, :sq].reshape(b, sq, kh * g, dh)


# ---------------------------------------------------------------------------
# Attention block: projections + RoPE + cache management
# ---------------------------------------------------------------------------


def attention_block(params: dict, cfg: ModelConfig, x: jax.Array, *,
                    kind: str,
                    positions: jax.Array | int = 0,
                    cache: dict | None = None,
                    cache_index: jax.Array | None = None,
                    dist=None,
                    pages: jax.Array | None = None) -> tuple[jax.Array, dict | None]:
    """Projections + RoPE + attention (+ KV-cache update for decode).

    ``cache``: {"k": (B, S_cache, K, Dh), "v": ...}. If ``S_cache == window``
    for a local layer, the cache is treated as a **ring buffer**. A paged
    cache instead holds {"pool_k": (P, page_size, K, Dh), "pool_v": ...}
    and requires ``pages``: the (B, pages_per_slot) int32 page table
    (-1 = unbound; page 0 is the allocator's trash page).
    ``cache_index``: scalar int32 — count of tokens already cached.
    """
    b, s, d = x.shape
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.use_qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.rms_eps)
        k = rmsnorm(params["k_norm"], k, cfg.rms_eps)
    theta = cfg.rope_theta
    if kind == "attn" and cfg.rope_theta_global is not None:
        theta = cfg.rope_theta_global
    window = cfg.window_size if kind == "local" else None
    if not cfg.encoder_only:
        pos = _expand_positions(positions, b, s)
        q = apply_rope(q, pos, theta)
        k = apply_rope(k, pos, theta)

    if cache is None:
        out = chunked_attention(q, k, v, q_offset=0,
                                causal=not cfg.encoder_only,
                                window=window, kv_chunk=cfg.kv_chunk,
                                score_dtype=jnp.dtype(cfg.score_dtype))
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
        return y, None

    assert cache_index is not None
    cache_index = jnp.asarray(cache_index, jnp.int32)
    per_slot = cache_index.ndim == 1  # continuous batching: (B,) positions

    if "pool_k" in cache:  # paged KV cache (serving tier)
        assert per_slot and s == 1 and pages is not None
        new_cache, out = _paged_decode(cfg, q, k, v, cache, cache_index,
                                       pages, window)
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
        return y, new_cache

    s_cache = cache["k"].shape[1]
    is_ring = window is not None and s_cache == window
    cdt = cache["k"].dtype
    if is_ring:
        # ring write: token at absolute position p lands in slot p % window.
        take = min(s, window)
        if per_slot:
            rows = jnp.arange(b, dtype=jnp.int32)[:, None]
            slots = (cache_index[:, None] +
                     jnp.arange(s - take, s, dtype=jnp.int32)[None, :]) % window
            ck = cache["k"].at[rows, slots].set(k[:, s - take:].astype(cdt))
            cv = cache["v"].at[rows, slots].set(v[:, s - take:].astype(cdt))
            t_new = (cache_index + s)[:, None]                  # (B, 1)
        else:
            slots = (cache_index +
                     jnp.arange(s - take, s, dtype=jnp.int32)) % window
            ck = cache["k"].at[:, slots].set(k[:, s - take:].astype(cdt))
            cv = cache["v"].at[:, slots].set(v[:, s - take:].astype(cdt))
            t_new = jnp.full((b, 1), cache_index + s, jnp.int32)
        # slot j holds position t_new - 1 - ((t_new - 1 - j) mod window).
        j = jnp.arange(window, dtype=jnp.int32)[None, :]
        k_positions = t_new - 1 - jnp.mod(t_new - 1 - j, window)
        k_valid = k_positions >= 0
    else:
        if per_slot:
            rows = jnp.arange(b, dtype=jnp.int32)[:, None]
            slots = cache_index[:, None] + jnp.arange(s, dtype=jnp.int32)
            ck = cache["k"].at[rows, slots].set(k.astype(cdt))
            cv = cache["v"].at[rows, slots].set(v.astype(cdt))
            end = (cache_index + s)[:, None]
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cdt), cache_index, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cdt), cache_index, axis=1)
            end = jnp.full((b, 1), cache_index + s, jnp.int32)
        k_positions = jnp.broadcast_to(
            jnp.arange(s_cache, dtype=jnp.int32)[None, :], (b, s_cache))
        k_valid = k_positions < end
    new_cache = {"k": ck, "v": cv}
    if (dist is not None and dist.has("flash_decode") and s == 1
            and not is_ring):
        # sequence-parallel decode: cache stays seq-sharded on `model`;
        # partial softmax stats merge with one small psum per layer.
        out = dist.decode_attention(q, ck.astype(dt), cv.astype(dt),
                                    k_positions, k_valid, window=window,
                                    kv_chunk=cfg.kv_chunk,
                                    q_offset=positions)
    elif (cfg.decode_kernel == "flash" and s == 1 and per_slot
          and dist is None):
        # serving hot path: fused split-KV flash-decode. The -1-invalid
        # position encoding folds k_valid into k_positions; ring caches
        # (slot != position) disable the occupancy-bounded trip count.
        from repro.kernels.flash_decode import decode_attention
        out = decode_attention(
            q, ck.astype(dt), cv.astype(dt), cache_index,
            jnp.where(k_valid, k_positions, -1), window=window,
            interpret=cfg.kernel_interpret, bounded=not is_ring)
    else:
        out = chunked_attention(q, ck.astype(dt), cv.astype(dt),
                                q_offset=positions, k_positions=k_positions,
                                causal=True, window=window,
                                kv_chunk=cfg.kv_chunk, k_valid=k_valid)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return y, new_cache


def _paged_decode(cfg: ModelConfig, q: jax.Array, k: jax.Array,
                  v: jax.Array, cache: dict, positions: jax.Array,
                  pages: jax.Array, window: int | None
                  ) -> tuple[dict, jax.Array]:
    """One decode step against a paged KV cache.

    The new token is scattered into its slot's current page (slots whose
    table row is unbound clamp to the reserved trash page 0), then attention
    reads through the page table. ``decode_kernel="flash"`` uses the fused
    paged kernel; "chunked" gathers the logical view and runs the reference
    — pages are bound in logical order, so offsets past a slot's position
    hold garbage but are causally masked (``k_pos > q_pos``).
    """
    b = q.shape[0]
    dt = q.dtype
    cdt = cache["pool_k"].dtype
    page_size = cache["pool_k"].shape[1]
    rows = jnp.arange(b, dtype=jnp.int32)
    page = pages[rows, positions // page_size]
    page = jnp.maximum(page, 0)
    off = positions % page_size
    ck = cache["pool_k"].at[page, off].set(k[:, 0].astype(cdt))
    cv = cache["pool_v"].at[page, off].set(v[:, 0].astype(cdt))
    new_cache = {"pool_k": ck, "pool_v": cv}
    if cfg.decode_kernel == "flash":
        from repro.kernels.flash_decode import decode_attention_paged
        out = decode_attention_paged(q, ck.astype(dt), cv.astype(dt),
                                     positions, pages, window=window,
                                     interpret=cfg.kernel_interpret)
        return new_cache, out
    n_pages = pages.shape[1]
    tbl = jnp.maximum(pages, 0)
    kh, dk = ck.shape[2], ck.shape[3]
    dv = cv.shape[3]
    k_lin = ck[tbl].reshape(b, n_pages * page_size, kh, dk)
    v_lin = cv[tbl].reshape(b, n_pages * page_size, kh, dv)
    kp = (jnp.arange(n_pages, dtype=jnp.int32)[:, None] * page_size +
          jnp.arange(page_size, dtype=jnp.int32)[None, :])
    kp = jnp.where(pages[:, :, None] >= 0, kp[None], -1)
    kp = kp.reshape(b, n_pages * page_size)
    out = chunked_attention(q, k_lin.astype(dt), v_lin.astype(dt),
                            q_offset=positions, k_positions=kp,
                            causal=True, window=window,
                            kv_chunk=cfg.kv_chunk, k_valid=kp >= 0,
                            score_dtype=jnp.dtype(cfg.score_dtype))
    return new_cache, out


def init_kv_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                  dtype: Any) -> dict:
    """Per-layer KV cache prototype. Local layers get a ring buffer of size
    ``window`` (when max_len exceeds it)."""
    length = max_len
    if kind == "local":
        length = min(max_len, cfg.window_size)
    return {
        "k": jnp.zeros((batch, length, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, length, cfg.n_kv_heads, cfg.head_dim), dtype),
    }
