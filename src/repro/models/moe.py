"""Mixture-of-Experts FFN with top-k routing, shared experts, and
capacity-bounded dispatch.

Two numerically-matching implementations:

* :func:`moe_ref` — dense reference: every expert computes every token,
  outputs weighted by gates. Exact (dropless); used as the oracle in tests
  and for tiny smoke configs.
* :func:`moe_capacity` — production path: per-shard capacity buffers built by
  a loop-over-k scatter (no ``(T, E, C)`` one-hot tensor is ever
  materialized). This function is written **per-shard**: it computes experts
  ``[e0, e0 + n_local)`` only and returns a *partial* output, so the sharded
  wrapper can run it inside ``shard_map`` with experts on the ``model`` axis
  and ``psum`` the partials (EP with activation replication — the same
  collective footprint as Megatron TP). With ``e0=0, n_local=E`` it is the
  single-device implementation.

Router: softmax over experts in fp32, top-k, gates renormalized over the
selected experts; Switch-style load-balancing auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamSpec


def moe_spec(cfg: ModelConfig) -> dict:
    e = cfg.moe
    d = cfg.d_model
    spec = {
        # router stays replicated: it is tiny, read inside the EP island on
        # every rank, and sharding it would force a per-layer gather.
        "router": ParamSpec((d, e.n_experts), (None, None),
                            init="normal", scale=0.02),
        "w_gate": ParamSpec((e.n_experts, d, e.d_expert),
                            ("experts", "embed", "expert_ff"), init="lecun"),
        "w_up": ParamSpec((e.n_experts, d, e.d_expert),
                          ("experts", "embed", "expert_ff"), init="lecun"),
        "w_down": ParamSpec((e.n_experts, e.d_expert, d),
                            ("experts", "expert_ff", "embed"), init="lecun"),
    }
    if e.n_shared:
        f = e.n_shared * e.d_expert
        spec["shared"] = {
            "w_gate": ParamSpec((d, f), ("embed", "ff"), init="lecun"),
            "w_up": ParamSpec((d, f), ("embed", "ff"), init="lecun"),
            "w_down": ParamSpec((f, d), ("ff", "embed"), init="lecun"),
        }
    return spec


def router_topk(params: dict, cfg: ModelConfig, x: jax.Array
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (T, d) -> (gates (T, k) f32, idx (T, k) i32, aux_loss scalar)."""
    e = cfg.moe
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gates, idx = jax.lax.top_k(probs, e.top_k)                  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch load-balance loss: E * sum_e f_e * P_e
    t = x.shape[0]
    counts = jnp.zeros((e.n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f_e = counts / jnp.maximum(t * e.top_k, 1)
    p_e = probs.mean(0)
    aux = e.n_experts * jnp.sum(f_e * p_e)
    return gates, idx, aux


def _expert_ffn(w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
                h: jax.Array) -> jax.Array:
    """h: (E, C, d) -> (E, C, d), swiglu per expert."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, w_gate))
    u = jnp.einsum("ecd,edf->ecf", h, w_up)
    return jnp.einsum("ecf,efd->ecd", g * u, w_down)


def moe_capacity(params: dict, cfg: ModelConfig, x: jax.Array, *,
                 e0: int = 0, n_local: int | None = None,
                 capacity: int | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Capacity-bounded top-k MoE over local experts [e0, e0+n_local).

    x: (T, d). Returns (partial_out (T, d), aux_loss). Tokens overflowing an
    expert's capacity are dropped (contribute zero), the standard GShard
    bound; ``capacity_factor`` controls the drop rate.
    """
    e = cfg.moe
    t, d = x.shape
    n_local = e.n_experts if n_local is None else n_local
    if capacity is None:
        capacity = max(1, -(-int(e.top_k * t * e.capacity_factor) // e.n_experts))
    gates, idx, aux = router_topk(params, cfg, x)

    # position-in-expert per (token, choice), built k scatters at a time —
    # memory high-water is (T, E) int32, never (T, E, C).
    buf = jnp.zeros((n_local * capacity, d), x.dtype)
    carry = jnp.zeros((e.n_experts,), jnp.int32)
    slots = []
    for j in range(e.top_k):
        oh = jax.nn.one_hot(idx[:, j], e.n_experts, dtype=jnp.int32)  # (T, E)
        within = jnp.cumsum(oh, axis=0) - oh
        pos_j = jnp.sum((within + carry[None, :]) * oh, axis=-1)      # (T,)
        carry = carry + oh.sum(0)
        local_e = idx[:, j] - e0
        ok = (local_e >= 0) & (local_e < n_local) & (pos_j < capacity)
        slot = jnp.where(ok, local_e * capacity + pos_j, n_local * capacity)
        slots.append((slot, ok))
        buf = buf.at[slot].add(x * ok[:, None].astype(x.dtype),
                               mode="drop")
    h = buf.reshape(n_local, capacity, d)
    w_gate = params["w_gate"]
    w_up = params["w_up"]
    w_down = params["w_down"]
    if w_gate.shape[0] != n_local:  # single-device path slices nothing
        w_gate = jax.lax.dynamic_slice_in_dim(w_gate, e0, n_local, 0)
        w_up = jax.lax.dynamic_slice_in_dim(w_up, e0, n_local, 0)
        w_down = jax.lax.dynamic_slice_in_dim(w_down, e0, n_local, 0)
    out_buf = _expert_ffn(w_gate.astype(x.dtype), w_up.astype(x.dtype),
                          w_down.astype(x.dtype), h)
    out_flat = out_buf.reshape(n_local * capacity, d)
    y = jnp.zeros((t, d), x.dtype)
    for j, (slot, ok) in enumerate(slots):
        picked = jnp.take(out_flat, jnp.minimum(slot, n_local * capacity - 1),
                          axis=0)
        w = gates[:, j].astype(x.dtype) * ok.astype(x.dtype)
        y = y + picked * w[:, None]
    return y, aux


def moe_ref(params: dict, cfg: ModelConfig, x: jax.Array
            ) -> tuple[jax.Array, jax.Array]:
    """Dense dropless reference: all experts on all tokens. x: (T, d)."""
    e = cfg.moe
    gates, idx, aux = router_topk(params, cfg, x)
    g = jax.nn.silu(jnp.einsum("td,edf->tef", x, params["w_gate"].astype(x.dtype)))
    u = jnp.einsum("td,edf->tef", x, params["w_up"].astype(x.dtype))
    per_e = jnp.einsum("tef,efd->ted", g * u, params["w_down"].astype(x.dtype))
    # combine with top-k gates
    weights = jnp.zeros((x.shape[0], e.n_experts), x.dtype)
    for j in range(e.top_k):
        weights = weights.at[jnp.arange(x.shape[0]), idx[:, j]].add(
            gates[:, j].astype(x.dtype))
    y = jnp.einsum("ted,te->td", per_e, weights)
    return y, aux


def shared_expert(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Always-on shared expert(s): a plain swiglu FFN (DeepSeek-V3)."""
    p = params["shared"]
    g = jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
    u = x @ p["w_up"].astype(x.dtype)
    return (g * u) @ p["w_down"].astype(x.dtype)


def moe_block(params: dict, cfg: ModelConfig, x: jax.Array, *,
              impl: str = "capacity", e0: int = 0, n_local: int | None = None,
              dropless: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux). ``impl``: capacity | ref.
    ``dropless`` sets capacity = n_tokens (used at decode, where token counts
    are tiny and capacity-drops would corrupt generation)."""
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    capacity = b * s if dropless else None
    if impl == "ref":
        y, aux = moe_ref(params, cfg, flat)
    else:
        y, aux = moe_capacity(params, cfg, flat, e0=e0, n_local=n_local,
                              capacity=capacity)
    if cfg.moe.n_shared:
        y = y + shared_expert(params, cfg, flat)
    return y.reshape(b, s, d), aux
