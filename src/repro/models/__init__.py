from .config import (FrontendConfig, MLAConfig, ModelConfig, MoEConfig,
                     RGLRUConfig, SSMConfig)
from .params import (count_params, init_params, logical_axes, param_shapes,
                     ParamSpec)
from .transformer import (block_apply, block_spec, cache_shapes, forward,
                          init_caches, model_spec)

__all__ = [
    "FrontendConfig", "MLAConfig", "ModelConfig", "MoEConfig", "ParamSpec",
    "RGLRUConfig", "SSMConfig", "block_apply", "block_spec", "cache_shapes",
    "count_params", "forward", "init_caches", "init_params", "logical_axes",
    "model_spec", "param_shapes",
]
