"""Mamba-2 SSD — state-space duality block (arXiv:2405.21060).

The selective state space recurrence

    h_t = exp(dt_t · A) h_{t-1} + dt_t · B_t x_tᵀ        (state: (H, P, N))
    y_t = C_t h_t + D ⊙ x_t

is computed with the paper's **chunked block decomposition**: within a chunk
the output is an attention-like (L×L) causal matrix  M_ij = (C_i·B_j) ·
exp(cum_i − cum_j) · dt_j  applied to X; across chunks a small state (H, P, N)
is carried sequentially. This keeps everything MXU-shaped (the reason SSD
exists) and is exactly the structure the Pallas kernel
(``repro.kernels.ssd``) tiles into VMEM. Decode is the O(1) recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamSpec


def ssd_spec(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = cfg.ssd_inner
    nh = cfg.ssd_heads
    n = s.d_state
    return {
        # in_proj: [z (di), x (di), B (n), C (n), dt (nh)]  (n_groups = 1)
        "w_in": ParamSpec((d, 2 * di + 2 * n + nh), ("embed", "ff"),
                          init="lecun"),
        "conv_w": ParamSpec((s.d_conv, di + 2 * n), ("conv", "ff"),
                            init="lecun"),
        "conv_b": ParamSpec((di + 2 * n,), ("ff",), init="zeros"),
        "a_log": ParamSpec((nh,), ("heads",), init="a_log"),
        "dt_bias": ParamSpec((nh,), ("heads",), init="dt_bias"),
        "d_skip": ParamSpec((nh,), ("heads",), init="ones"),
        "norm": {"scale": ParamSpec((di,), ("ff",), init="ones")},
        "w_out": ParamSpec((di, d), ("ff", "embed"), init="lecun"),
    }


def _split_in(cfg: ModelConfig, proj: jax.Array):
    di = cfg.ssd_inner
    n = cfg.ssm.d_state
    nh = cfg.ssd_heads
    z, x, bmat, cmat, dt = jnp.split(proj, [di, 2 * di, 2 * di + n,
                                            2 * di + 2 * n], axis=-1)
    return z, x, bmat, cmat, dt


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
                cmat: jax.Array, *, chunk: int,
                h0: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """SSD scan. x: (B,S,H,P); dt: (B,S,H) (post-softplus); a: (H,) negative;
    bmat/cmat: (B,S,N) (single group). Returns (y (B,S,H,P), h (B,H,P,N))."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    c = min(chunk, s)
    nc = -(-s // c)
    pad = nc * c - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    sc = nc * c
    xc = x.reshape(b, nc, c, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, c, h).transpose(1, 0, 2, 3).astype(jnp.float32)
    bc = bmat.reshape(b, nc, c, n).transpose(1, 0, 2, 3)
    cc = cmat.reshape(b, nc, c, n).transpose(1, 0, 2, 3)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def body(hprev, inp):
        xk, dtk, bk, ck = inp                       # (B,c,H,P),(B,c,H),(B,c,N)
        la = dtk * a[None, None, :]                 # log decay per step (B,c,H)
        cum = jnp.cumsum(la, axis=1)                # (B,c,H)
        # intra-chunk: M_ij = (C_i·B_j) exp(cum_i - cum_j) dt_j   (i >= j)
        cb = jnp.einsum("bin,bjn->bij", ck, bk,
                        preferred_element_type=jnp.float32)      # (B,c,c)
        dec = cum[:, :, None, :] - cum[:, None, :, :]            # (B,i,j,H)
        mask = jnp.tril(jnp.ones((c, c), bool))
        m = jnp.where(mask[None, :, :, None], jnp.exp(dec), 0.0)
        m = m * cb[:, :, :, None] * dtk[:, None, :, :]           # (B,i,j,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", m, xk.astype(jnp.float32))
        # inter-chunk: y += C_i exp(cum_i) h_prev
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", ck.astype(jnp.float32),
                             hprev, jnp.exp(cum))
        # state update: h = exp(cum_L) h_prev + sum_j exp(cum_L - cum_j) dt_j B_j x_j^T
        decay_tail = jnp.exp(cum[:, -1, None, :] - cum)          # (B,c,H)
        h_new = jnp.einsum("bch,bcn,bchp->bhpn",
                           decay_tail * dtk, bk.astype(jnp.float32),
                           xk.astype(jnp.float32))
        h_new = h_new + jnp.exp(cum[:, -1])[:, :, None, None] * hprev
        return h_new, (y_intra + y_inter).astype(x.dtype)

    h_fin, ys = jax.lax.scan(body, h0, (xc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, sc, h, p)[:, :s]
    return y, h_fin


def ssd_step(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
             cmat: jax.Array, hprev: jax.Array
             ) -> tuple[jax.Array, jax.Array]:
    """Decode: x (B,1,H,P), dt (B,1,H), b/c (B,1,N), h (B,H,P,N)."""
    dtf = dt[:, 0].astype(jnp.float32)                   # (B,H)
    decay = jnp.exp(dtf * a[None, :])                    # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dtf, bmat[:, 0].astype(jnp.float32),
                     x[:, 0].astype(jnp.float32))
    h = decay[:, :, None, None] * hprev + upd
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), h)
    return y[:, None].astype(x.dtype), h


def _rmsnorm_gated(scale: jax.Array, x: jax.Array, z: jax.Array,
                   eps: float) -> jax.Array:
    xf = (x * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def ssd_block(params: dict, cfg: ModelConfig, x_in: jax.Array, *,
              cache: dict | None = None
              ) -> tuple[jax.Array, dict | None]:
    """Full Mamba-2 block. x_in: (B, S, d).
    ``cache``: {"h": (B,H,P,N) f32, "conv": (B, d_conv-1, di+2N)}."""
    s_cfg = cfg.ssm
    b, s, _ = x_in.shape
    dt_ = x_in.dtype
    di = cfg.ssd_inner
    nh = cfg.ssd_heads
    p = s_cfg.head_dim
    proj = x_in @ params["w_in"].astype(dt_)
    z, xbc_x, bmat, cmat, dtp = _split_in(cfg, proj)
    # conv over concat(x, B, C)
    xbc = jnp.concatenate([xbc_x, bmat, cmat], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    k = s_cfg.d_conv
    if conv_state is None:
        conv_state = jnp.zeros((b, k - 1, xbc.shape[-1]), dt_)
    ext = jnp.concatenate([conv_state, xbc], axis=1)
    conv_w = params["conv_w"].astype(dt_)
    xbc = sum(ext[:, i:i + s] * conv_w[i] for i in range(k)) + \
        params["conv_b"].astype(dt_)
    xbc = jax.nn.silu(xbc)
    new_conv = ext[:, -(k - 1):] if k > 1 else conv_state
    xs, bmat, cmat = jnp.split(xbc, [di, di + s_cfg.d_state], axis=-1)
    xh = xs.reshape(b, s, nh, p)
    dt_soft = jax.nn.softplus(dtp.astype(jnp.float32) +
                              params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    if cache is not None and s == 1:
        y, h_new = ssd_step(xh, dt_soft, a, bmat, cmat, cache["h"])
    else:
        h0 = cache["h"] if cache is not None else None
        y, h_new = ssd_chunked(xh, dt_soft, a, bmat, cmat,
                               chunk=s_cfg.chunk_size, h0=h0)
    y = y + params["d_skip"].astype(dt_)[None, None, :, None] * xh
    y = y.reshape(b, s, di)
    y = _rmsnorm_gated(params["norm"]["scale"], y, z, cfg.rms_eps)
    out = y @ params["w_out"].astype(dt_)
    new_cache = None
    if cache is not None:
        new_cache = {"h": h_new, "conv": new_conv}
    return out, new_cache


def init_ssd_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    s = cfg.ssm
    return {
        "h": jnp.zeros((batch, cfg.ssd_heads, s.head_dim, s.d_state),
                       jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, cfg.ssd_inner + 2 * s.d_state),
                          dtype),
    }
