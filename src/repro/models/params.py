"""Parameter-tree machinery: shapes + logical sharding axes, declared once.

Every parameter is declared as a :class:`ParamSpec` (shape, logical axes,
init). From the spec tree we derive, without ever materializing weights:

* ``init_params(rng)``        — materialized pytree (smoke tests / real runs),
* ``param_shapes()``          — ``jax.ShapeDtypeStruct`` tree (dry-run),
* ``logical_axes()``          — pytree of logical-axis tuples, mapped to mesh
                                ``PartitionSpec``s by ``repro.sharding.rules``.

Logical axis vocabulary (resolved in ``repro/sharding/rules.py``):
``embed`` (d_model), ``vocab``, ``heads``, ``kv_heads``, ``head_dim``, ``ff``,
``experts``, ``expert_ff``, ``lora``, ``state``, ``conv``, ``layers``
(scan-stacked leading axis), ``null`` (never sharded).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"        # normal | zeros | ones | lecun | lambda_rglru | dt_bias
    scale: float | None = None  # stddev override for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: tuple[int, ...]) -> int:
    # convention: last axis is the output axis for 2D+ weights
    if len(shape) == 1:
        return shape[0]
    return int(np.prod(shape[:-1]))


def _init_leaf(spec: ParamSpec, key: jax.Array, dtype: Any) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "normal":
        std = spec.scale if spec.scale is not None else 0.02
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
    if spec.init == "lecun":
        std = 1.0 / math.sqrt(max(_fan_in(spec.shape), 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
    if spec.init == "lambda_rglru":
        # Griffin init: a^2 = uniform in [0.81, 0.9801] => Lambda s.t.
        # sigmoid-free softplus parameterization lands in that band.
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        lam = jnp.log(jnp.expm1(-jnp.log(u) / 8.0))  # inverse softplus of -ln(u)/c
        return lam.astype(dtype)
    if spec.init == "dt_bias":
        # mamba dt bias init: softplus^-1 of uniform[1e-3, 1e-1]
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(dtype)
    if spec.init == "a_log":
        # mamba2 A in [1, 16], stored as log
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    raise ValueError(f"unknown init {spec.init}")


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs: Any, rng: jax.Array, dtype: Any) -> Any:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def param_shapes(specs: Any, dtype: Any) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs, is_leaf=is_spec)


def logical_axes(specs: Any) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def count_params(specs: Any) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))


def stack_specs(spec_tree: Any, n: int) -> Any:
    """Prepend a scan ``layers`` axis of length ``n`` to every spec — the
    parameter layout for ``lax.scan`` over a repeated layer period."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes,
                            init=s.init, scale=s.scale),
        spec_tree, is_leaf=is_spec)
