"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Queries go through a LoRA bottleneck (``q_lora_rank``); keys/values are
compressed into a small latent (``kv_lora_rank``) plus one shared RoPE head.
Training/prefill materializes per-head K/V; decode uses the **absorbed**
formulation — attention runs directly in the compressed latent, so the KV
cache is ``kv_lora_rank + rope_head_dim`` floats per token *total* (not per
head), the property that makes 128-head decode at 32k context cheap.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .attention import _expand_positions, chunked_attention
from .config import ModelConfig
from .layers import apply_rope, rmsnorm
from .params import ParamSpec


def mla_spec(cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.nope_head_dim + m.rope_head_dim
    return {
        "w_dq": ParamSpec((d, m.q_lora_rank), ("embed", "lora"), init="lecun"),
        "q_norm": {"scale": ParamSpec((m.q_lora_rank,), (None,), init="ones")},
        "w_uq": ParamSpec((m.q_lora_rank, h, qk), ("lora", "heads", "head_dim"),
                          init="lecun"),
        "w_dkv": ParamSpec((d, m.kv_lora_rank + m.rope_head_dim),
                           ("embed", "lora"), init="lecun"),
        "kv_norm": {"scale": ParamSpec((m.kv_lora_rank,), (None,), init="ones")},
        "w_uk": ParamSpec((m.kv_lora_rank, h, m.nope_head_dim),
                          ("lora", "heads", "head_dim"), init="lecun"),
        "w_uv": ParamSpec((m.kv_lora_rank, h, m.v_head_dim),
                          ("lora", "heads", "head_dim"), init="lecun"),
        "w_o": ParamSpec((h, m.v_head_dim, d), ("heads", "head_dim", "embed"),
                         init="lecun"),
    }


def _project_q(params: dict, cfg: ModelConfig, x: jax.Array,
               positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """-> (q_nope (B,S,H,nope), q_rope (B,S,H,rope))."""
    m = cfg.mla
    cq = rmsnorm(params["q_norm"], x @ params["w_dq"], cfg.rms_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"])
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _compress_kv(params: dict, cfg: ModelConfig, x: jax.Array,
                 positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """-> (c_kv (B,S,R), k_rope (B,S,1,rope)) — exactly what the cache holds."""
    m = cfg.mla
    dkv = x @ params["w_dkv"]
    c_kv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.rms_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return c_kv, k_rope


def mla_block(params: dict, cfg: ModelConfig, x: jax.Array, *,
              positions: jax.Array | int = 0,
              cache: dict | None = None,
              cache_index: jax.Array | None = None,
              dist=None) -> tuple[jax.Array, dict | None]:
    """MLA attention block. ``cache``: {"c_kv": (B, S, R), "k_rope": (B, S, rope)}."""
    m = cfg.mla
    b, s, _ = x.shape
    dt = x.dtype
    pos = _expand_positions(positions if cache is not None else 0, b, s)
    q_nope, q_rope = _project_q(params, cfg, x, pos)
    c_kv, k_rope = _compress_kv(params, cfg, x, pos)
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)

    if cache is None:
        # materialized path (training / full prefill)
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"])
        v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, cfg.n_heads, m.rope_head_dim))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_attention(q, k, v, causal=True, kv_chunk=cfg.kv_chunk,
                                scale=scale,
                                score_dtype=jnp.dtype(cfg.score_dtype))
        y = jnp.einsum("bshk,hkd->bsd", out, params["w_o"].astype(dt))
        return y, None

    # absorbed decode: attention in the compressed latent.
    assert cache_index is not None
    cache_index = jnp.asarray(cache_index, jnp.int32)
    cdt = cache["c_kv"].dtype
    if cache_index.ndim == 1:  # continuous batching: per-slot positions
        rows = jnp.arange(b, dtype=jnp.int32)[:, None]
        slots = cache_index[:, None] + jnp.arange(s, dtype=jnp.int32)
        ck = cache["c_kv"].at[rows, slots].set(c_kv.astype(cdt))
        cr = cache["k_rope"].at[rows, slots].set(
            k_rope[:, :, 0, :].astype(cdt))
        end = (cache_index + s)[:, None]
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cdt), cache_index, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope[:, :, 0, :].astype(cdt), cache_index,
            axis=1)
        end = None
    new_cache = {"c_kv": ck, "k_rope": cr}
    s_cache = ck.shape[1]
    # q_eff[h] = q_nope[h] @ w_uk[h]^T  -> query against c_kv directly
    q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"].astype(dt))
    q_cat = jnp.concatenate([q_eff, q_rope], axis=-1)          # (B,S,H,R+rope)
    k_cat = jnp.concatenate([ck, cr], axis=-1)[:, :, None, :]  # (B,Sc,1,R+rope)
    v_lat = ck[:, :, None, :]                                  # (B,Sc,1,R)
    if end is None:
        end = jnp.full((b, 1), cache_index + s, jnp.int32)
    k_valid = jnp.arange(s_cache, dtype=jnp.int32)[None, :] < end
    k_valid = jnp.broadcast_to(k_valid, (b, s_cache))
    if dist is not None and dist.has("flash_decode") and s == 1:
        k_positions = jnp.broadcast_to(
            jnp.arange(s_cache, dtype=jnp.int32)[None, :], (b, s_cache))
        ctx = dist.decode_attention(q_cat.astype(dt), k_cat.astype(dt),
                                    v_lat.astype(dt), k_positions, k_valid,
                                    kv_chunk=cfg.kv_chunk,
                                    q_offset=positions, scale=scale)
    else:
        ctx = chunked_attention(q_cat.astype(dt), k_cat.astype(dt),
                                v_lat.astype(dt), q_offset=positions,
                                causal=True, kv_chunk=cfg.kv_chunk,
                                k_valid=k_valid, scale=scale)   # (B,S,H,R)
    # absorb the value up-projection, then the output projection
    y = jnp.einsum("bshr,rhk,hkd->bsd", ctx, params["w_uv"].astype(dt),
                   params["w_o"].astype(dt))
    return y, new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
    }
