"""The composable model stack.

Layers are generated from ``cfg.layer_pattern`` cycled over ``n_layers``. The
repeating *period* (e.g. Gemma-3's ``(local×5, global)``; RecurrentGemma's
``(rglru, rglru, local)``) is the ``lax.scan`` unit: parameters (and caches)
for the full periods are stacked on a leading ``layers`` axis so the lowered
HLO contains **one** period body regardless of depth — this is what keeps
dry-run compiles of 61-layer models tractable and the compiled program small.
Remainder layers (``n_layers % period``) are applied unrolled after the scan.

``dist`` (a ``repro.sharding.DistContext`` or None) switches the MoE between
the single-device capacity path and the expert-parallel ``shard_map`` island.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .attention import attention_block, attention_spec, init_kv_cache
from .config import ModelConfig
from .layers import embed, embedding_spec, mlp, mlp_spec, rmsnorm, rmsnorm_spec, unembed
from .mla import init_mla_cache, mla_block, mla_spec
from .moe import moe_block, moe_spec
from .params import ParamSpec, stack_specs
from .rglru import init_rglru_cache, rglru_block, rglru_spec
from .ssd import init_ssd_cache, ssd_block, ssd_spec


def _has_mlp(cfg: ModelConfig, kind: str) -> bool:
    if kind in ("attn", "local"):
        return True
    return cfg.d_ff > 0


def block_spec(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    spec: dict = {"norm1": rmsnorm_spec(d)}
    if kind in ("attn", "local"):
        spec["mix"] = mla_spec(cfg) if cfg.mla is not None else attention_spec(cfg)
    elif kind == "ssd":
        spec["mix"] = ssd_spec(cfg)
    elif kind == "rglru":
        spec["mix"] = rglru_spec(cfg)
    else:
        raise ValueError(f"unknown layer kind {kind}")
    if _has_mlp(cfg, kind):
        spec["norm2"] = rmsnorm_spec(d)
        spec["ffn"] = moe_spec(cfg) if cfg.moe is not None else mlp_spec(cfg)
    return spec


def block_apply(params: dict, cfg: ModelConfig, kind: str, x: jax.Array, *,
                positions: jax.Array | int = 0,
                cache: dict | None = None,
                cache_index: jax.Array | None = None,
                dist: Any = None,
                decode: bool = False,
                pages: jax.Array | None = None) -> tuple[jax.Array, dict | None, jax.Array]:
    """One residual block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(params["norm1"], x, cfg.rms_eps)
    if kind in ("attn", "local"):
        if cfg.mla is not None:
            y, new_cache = mla_block(params["mix"], cfg, h,
                                     positions=positions, cache=cache,
                                     cache_index=cache_index, dist=dist)
        else:
            y, new_cache = attention_block(params["mix"], cfg, h, kind=kind,
                                           positions=positions, cache=cache,
                                           cache_index=cache_index,
                                           dist=dist, pages=pages)
    elif kind == "ssd":
        y, new_cache = ssd_block(params["mix"], cfg, h, cache=cache)
    else:  # rglru
        y, new_cache = rglru_block(params["mix"], cfg, h, cache=cache)
    x = x + y
    if _has_mlp(cfg, kind):
        h = rmsnorm(params["norm2"], x, cfg.rms_eps)
        if cfg.moe is not None:
            if dist is not None:
                f, aux = dist.moe_island(params["ffn"], cfg, h, decode=decode)
            else:
                f, aux = moe_block(params["ffn"], cfg, h, impl="capacity",
                                   dropless=decode)
        else:
            f = mlp(params["ffn"], cfg, h)
        x = x + f
    if dist is not None:
        x = dist.constrain_activation(x)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Full-model spec
# ---------------------------------------------------------------------------


def model_spec(cfg: ModelConfig) -> dict:
    period_spec = {str(i): block_spec(cfg, k)
                   for i, k in enumerate(cfg.layer_pattern)}
    spec: dict = {
        "embed": embedding_spec(cfg),
        "final_norm": rmsnorm_spec(cfg.d_model),
    }
    if cfg.n_periods > 0:
        spec["periods"] = stack_specs(period_spec, cfg.n_periods)
    if cfg.n_remainder:
        spec["tail"] = {str(i): block_spec(cfg, cfg.layer_pattern[i])
                        for i in range(cfg.n_remainder)}
    if cfg.frontend is not None:
        spec["frontend"] = {
            "w": ParamSpec((cfg.frontend.input_dim, cfg.d_model),
                           ("ff", "embed"), init="lecun"),
            "b": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        }
    return spec


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _apply_period(params_p: dict, cfg: ModelConfig, x: jax.Array, *,
                  positions, caches_p, cache_index, dist, decode=False,
                  pages=None):
    """Apply one period (len(layer_pattern) blocks). caches_p: dict per slot."""
    new_caches = {}
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(cfg.layer_pattern):
        c = caches_p.get(str(i)) if caches_p is not None else None
        x, nc, a = block_apply(params_p[str(i)], cfg, kind, x,
                               positions=positions, cache=c,
                               cache_index=cache_index, dist=dist,
                               decode=decode, pages=pages)
        aux = aux + a
        if nc is not None:
            new_caches[str(i)] = nc
    return x, new_caches, aux


def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            caches: dict | None = None,
            cache_index: jax.Array | None = None,
            dist: Any = None,
            remat: str = "none",
            unroll: int | bool = 1,
            return_hidden: bool = False,
            pages: jax.Array | None = None
            ) -> tuple[jax.Array, dict | None, jax.Array]:
    """Run the stack.

    ``batch``: {"tokens": (B, S) int32} and/or {"embeds": (B, S, input_dim)}
    for stub frontends; VLM concatenates projected patch embeds before text.
    ``caches``: {"periods": stacked-cache pytree, "tail": {...}} or None.
    ``pages``: (B, pages_per_slot) int32 page table when ``caches`` came
    from :func:`init_paged_caches` (shared by every paged layer — slot
    positions advance uniformly across the stack).
    Returns (logits (B, S, vocab) [text positions only for VLM], new_caches,
    aux_loss).
    """
    decode = caches is not None
    if cfg.frontend is not None and cfg.frontend.kind == "audio_frames":
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype)) @ params["frontend"]["w"] \
            + params["frontend"]["b"]
        n_prefix = 0
    elif cfg.frontend is not None and cfg.frontend.kind == "vit_patches":
        x_txt = embed(params["embed"], cfg, batch["tokens"])
        if "embeds" in batch and batch["embeds"] is not None:
            x_img = batch["embeds"].astype(jnp.dtype(cfg.dtype)) @ \
                params["frontend"]["w"] + params["frontend"]["b"]
            x = jnp.concatenate([x_img, x_txt], axis=1)
            n_prefix = x_img.shape[1]
        else:  # decode steps carry no image
            x = x_txt
            n_prefix = 0
    else:
        x = embed(params["embed"], cfg, batch["tokens"])
        n_prefix = 0
    if dist is not None:
        x = dist.constrain_activation(x)

    positions: jax.Array | int = cache_index if decode else 0
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: dict = {}

    if cfg.n_periods > 0:
        params_p = params["periods"]
        caches_p = caches.get("periods") if decode else None

        def body(carry, xs):
            h, auxc = carry
            p_i, c_i = xs
            h, nc, a = _apply_period(p_i, cfg, h, positions=positions,
                                     caches_p=c_i, cache_index=cache_index,
                                     dist=dist, decode=decode, pages=pages)
            return (h, auxc + a), nc

        if remat != "none":
            policy = {
                "full": None,
                "dots": jax.checkpoint_policies.checkpoint_dots,
                "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            }[remat]
            body = jax.checkpoint(body, policy=policy,
                                  prevent_cse=False)
        (x, aux_total), nc_stack = jax.lax.scan(body, (x, aux_total),
                                                (params_p, caches_p),
                                                unroll=unroll)
        if decode:
            new_caches["periods"] = nc_stack

    if cfg.n_remainder:
        caches_t = caches.get("tail") if decode else None
        new_tail = {}
        for i in range(cfg.n_remainder):
            kind = cfg.layer_pattern[i]
            c = caches_t.get(str(i)) if caches_t is not None else None
            x, nc, a = block_apply(params["tail"][str(i)], cfg, kind, x,
                                   positions=positions, cache=c,
                                   cache_index=cache_index, dist=dist,
                                   decode=decode, pages=pages)
            aux_total = aux_total + a
            if nc is not None:
                new_tail[str(i)] = nc
        if decode:
            new_caches["tail"] = new_tail

    x = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    if n_prefix:
        x = x[:, n_prefix:]  # loss/logits over text positions only (VLM)
    if return_hidden:  # fused-CE path computes unembed inside its island
        return x, (new_caches if decode else None), aux_total
    logits = unembed(params["embed"], cfg, x)
    return logits, (new_caches if decode else None), aux_total


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------


def _cache_for(cfg: ModelConfig, kind: str, batch: int, max_len: int,
               dtype) -> dict | None:
    if kind in ("attn", "local"):
        if cfg.mla is not None:
            return init_mla_cache(cfg, batch, max_len, dtype)
        return init_kv_cache(cfg, kind, batch, max_len, dtype)
    if kind == "ssd":
        return init_ssd_cache(cfg, batch, dtype)
    if kind == "rglru":
        return init_rglru_cache(cfg, batch, dtype)
    return None


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    """Decode cache pytree matching the scan layout of :func:`forward`."""
    out: dict = {}
    if cfg.n_periods > 0:
        per = {}
        for i, kind in enumerate(cfg.layer_pattern):
            c = _cache_for(cfg, kind, batch, max_len, dtype)
            if c is not None:
                per[str(i)] = jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (cfg.n_periods,) + a.shape).copy(), c)
        out["periods"] = per
    if cfg.n_remainder:
        tail = {}
        for i in range(cfg.n_remainder):
            kind = cfg.layer_pattern[i]
            c = _cache_for(cfg, kind, batch, max_len, dtype)
            if c is not None:
                tail[str(i)] = c
        out["tail"] = tail
    return out


def paged_layout(max_len: int, page_size: int, batch: int,
                 n_pages: int | None = None) -> tuple[int, int]:
    """(pages_per_slot, pool_pages) for a paged cache. The default pool is
    full-reservation-equivalent plus the reserved trash page; serving passes
    a smaller pool to oversubscribe (long-context slots no longer reserve
    ``max_len`` up front)."""
    pages_per_slot = -(-max_len // page_size)
    if n_pages is None:
        n_pages = batch * pages_per_slot + 1
    return pages_per_slot, n_pages


def _paged_cache_for(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype, *, page_size: int, n_pages: int) -> dict | None:
    if kind in ("attn", "local"):
        if kind == "local" and min(max_len, cfg.window_size) < max_len:
            # ring buffers are already O(window); keep them dense.
            return init_kv_cache(cfg, kind, batch, max_len, dtype)
        return {
            "pool_k": jnp.zeros((n_pages, page_size, cfg.n_kv_heads,
                                 cfg.head_dim), dtype),
            "pool_v": jnp.zeros((n_pages, page_size, cfg.n_kv_heads,
                                 cfg.head_dim), dtype),
        }
    return _cache_for(cfg, kind, batch, max_len, dtype)


def init_paged_caches(cfg: ModelConfig, batch: int, max_len: int, dtype, *,
                      page_size: int = 64,
                      n_pages: int | None = None) -> dict:
    """Decode cache pytree with paged KV for the full-context attention
    layers: physical pools ``(n_pages, page_size, K, Dh)`` indexed through
    the page table that :func:`forward` takes as ``pages``. Ring (local)
    and recurrent (ssd/rglru) caches keep their dense layout — they are
    already O(window) / O(1) per slot. Page 0 is reserved as the trash page
    for writes from unbound slots."""
    if cfg.mla is not None:
        raise NotImplementedError("paged KV cache with MLA latent caches")
    _, n_pages = paged_layout(max_len, page_size, batch, n_pages)
    kw = dict(page_size=page_size, n_pages=n_pages)
    out: dict = {}
    if cfg.n_periods > 0:
        per = {}
        for i, kind in enumerate(cfg.layer_pattern):
            c = _paged_cache_for(cfg, kind, batch, max_len, dtype, **kw)
            if c is not None:
                per[str(i)] = jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (cfg.n_periods,) + a.shape).copy(), c)
        out["periods"] = per
    if cfg.n_remainder:
        tail = {}
        for i in range(cfg.n_remainder):
            kind = cfg.layer_pattern[i]
            c = _paged_cache_for(cfg, kind, batch, max_len, dtype, **kw)
            if c is not None:
                tail[str(i)] = c
        out["tail"] = tail
    return out


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Any:
    """ShapeDtypeStruct tree of the decode cache (dry-run input spec)."""
    return jax.eval_shape(lambda: init_caches(cfg, batch, max_len, dtype))
