"""Model configuration schema for the architecture zoo.

One :class:`ModelConfig` describes every assigned architecture; the layer
stack is generated from ``layer_pattern`` (cycled across ``n_layers``), which
covers homogeneous transformers (pattern ``("attn",)``), Gemma-3's 5:1
local:global attention, RecurrentGemma's (rglru, rglru, local) hybrid, and
Mamba-2's attention-free ``("ssd",)`` stack.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Literal, Sequence

LayerKind = Literal["attn", "local", "ssd", "rglru"]
MlpKind = Literal["swiglu", "geglu", "gelu"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                  # expert FFN hidden dim
    n_shared: int = 0              # always-on shared experts (DeepSeek-V3)
    capacity_factor: float = 1.25  # dispatch capacity (dropped-token bound)
    router_aux_weight: float = 1e-3
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention dims (arXiv:2412.19437)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD (arXiv:2405.21060)."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256
    # n_heads = d_model * expand // head_dim, derived.


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU recurrent block (arXiv:2402.19427)."""
    lru_width: int | None = None   # default: d_model
    conv_width: int = 4
    c: float = 8.0                 # the fixed constant in a = exp(-c*softplus(L)*sigmoid(rx))


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend: inputs are *precomputed* frame/patch
    embeddings; the frontend is a learned projection into d_model."""
    kind: Literal["audio_frames", "vit_patches"]
    input_dim: int               # embedding dim delivered by the stub
    n_positions: int = 0         # patches prepended before text (vlm only)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    layer_pattern: tuple[str, ...] = ("attn",)
    window_size: int = 1024                 # for "local" layers
    mlp_kind: MlpKind = "swiglu"
    encoder_only: bool = False              # bidirectional, no decode step
    use_qk_norm: bool = False
    tie_embeddings: bool = False
    scale_embeddings: bool = False          # gemma-style sqrt(d_model)
    rope_theta: float = 10_000.0
    rope_theta_global: float | None = None  # gemma3 global layers use 1e6
    rms_eps: float = 1e-6
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    frontend: FrontendConfig | None = None
    # numerics
    dtype: str = "bfloat16"                 # activations/weights compute dtype
    # attention implementation knobs
    kv_chunk: int = 1024                    # chunked-softmax KV block
    use_pallas: bool = False                # TPU kernels (tests use interpret)
    decode_kernel: str = "chunked"          # serving decode: "chunked"
                                            # (reference) | "flash"
                                            # (split-KV flash-decode)
    kernel_interpret: bool = False          # Pallas interpret mode (CPU
                                            # parity tests)
    logit_dtype: str = "float32"
    score_dtype: str = "float32"            # attention score/probability dtype
                                            # (bf16 halves the S×chunk buffers)

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (Megatron-style) so the unembed
        can always be vocab-parallel on the model axis; labels never hit the
        padding and serve_step masks it out of sampling."""
        return -(-self.vocab_size // 256) * 256

    def layer_kinds(self) -> tuple[str, ...]:
        pat = self.layer_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def n_remainder(self) -> int:
        return self.n_layers % self.period

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def ssd_heads(self) -> int:
        assert self.ssm is not None
        return (self.d_model * self.ssm.expand) // self.ssm.head_dim

    @property
    def ssd_inner(self) -> int:
        assert self.ssm is not None
        return self.d_model * self.ssm.expand

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter count (for 6·N·D roofline bookkeeping) -------------------

    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count; ``active_only`` counts top-k+shared
        experts only (the N in MoE 6·N_active·D)."""
        d, v = self.d_model, self.padded_vocab
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += d * v
        kinds = self.layer_kinds()
        for kind in kinds:
            n += 2 * d  # two RMSNorm scales per block
            if kind in ("attn", "local"):
                if self.mla is not None:
                    m = self.mla
                    n += d * m.q_lora_rank + m.q_lora_rank  # q down + norm
                    n += m.q_lora_rank * self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                    n += d * (m.kv_lora_rank + m.rope_head_dim) + m.kv_lora_rank
                    n += m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim)
                    n += self.n_heads * m.v_head_dim * d
                else:
                    n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            elif kind == "ssd":
                s = self.ssm
                di = self.ssd_inner
                h = self.ssd_heads
                n += d * (2 * di + 2 * s.d_state + h)      # in_proj(z,x,B,C,dt)
                n += s.d_conv * (di + 2 * s.d_state)       # conv over x,B,C
                n += di + 2 * s.d_state                    # conv bias
                n += 3 * h                                 # A_log, dt_bias, D
                n += di                                    # gate norm scale
                n += di * d                                # out_proj
            elif kind == "rglru":
                r = self.rglru or RGLRUConfig()
                w = r.lru_width or d
                n += d * 2 * w + r.conv_width * w  # x/gate in-projs + conv
                n += 2 * w * w                     # input & recurrence gates
                n += w                             # Lambda
                n += w * d                         # out proj
            # MLP
            if kind in ("attn", "local"):
                if self.moe is not None:
                    e = self.moe
                    n_router = d * e.n_experts
                    per_expert = 3 * d * e.d_expert
                    n += n_router
                    if active_only:
                        n += (e.top_k + e.n_shared) * per_expert
                    else:
                        n += (e.n_experts + e.n_shared) * per_expert
                else:
                    mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                    n += mult * d * self.d_ff
            elif kind in ("ssd", "rglru"):
                # ssd/rglru blocks in these configs are followed by their own
                # MLP block only in hybrid stacks; mamba2 is MLP-free.
                if self.d_ff:
                    mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                    n += mult * d * self.d_ff + 2 * d
        if self.frontend is not None:
            n += self.frontend.input_dim * d + d
        n += d  # final norm
        return n
