"""AdamW with the memory policies needed at 671B scale.

* ``moment_dtype`` — bf16 first/second moments (DeepSeek-V3 trains with BF16
  Adam moments; this is what makes the optimizer state of the 671B config
  fit the assigned pod, see EXPERIMENTS.md §Dry-run),
* ``factored_v`` — Adafactor-style factored second moment (row/col means over
  the trailing two axes) for a further ~4 bytes/param saving,
* ``master_dtype`` — fp32 master copy when model params are bf16; ``"none"``
  keeps a single (fp32) copy in ``params``.

Pure pytree functions — no optax dependency — so optimizer state inherits
parameter shardings (ZeRO: state is sharded exactly like the FSDP'd params).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    factored_v: bool = False
    master_dtype: str = "float32"   # "none" => params are the master copy
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"


def _can_factor(shape: tuple[int, ...]) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adamw_init(params: Any, cfg: OptimizerConfig) -> dict:
    mdt = jnp.dtype(cfg.moment_dtype)
    m = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
    if cfg.factored_v:
        def make_v(p):
            if _can_factor(p.shape):
                return {"row": jnp.zeros(p.shape[:-1], mdt),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], mdt)}
            return {"full": jnp.zeros(p.shape, mdt)}
        v = jax.tree.map(make_v, params)
    else:
        v = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
    state = {"m": m, "v": v, "count": jnp.zeros((), jnp.int32)}
    if cfg.master_dtype != "none":
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.dtype(cfg.master_dtype)), params)
    return state


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _v_update_and_corr(v_leaf, g2, b2, cfg):
    """Update (possibly factored) second moment; return (new_v, denom f32)."""
    mdt = jnp.dtype(cfg.moment_dtype)
    if isinstance(v_leaf, dict) and "row" in v_leaf:
        row = v_leaf["row"].astype(jnp.float32) * b2 + \
            g2.mean(axis=-1) * (1 - b2)
        col = v_leaf["col"].astype(jnp.float32) * b2 + \
            g2.mean(axis=-2) * (1 - b2)
        # rank-1 reconstruction (adafactor): v ≈ row ⊗ col / mean(row)
        denom = row[..., None] * col[..., None, :] / jnp.maximum(
            row.mean(axis=-1, keepdims=True)[..., None], 1e-30)
        return {"row": row.astype(mdt), "col": col.astype(mdt)}, denom
    if isinstance(v_leaf, dict):
        full = v_leaf["full"].astype(jnp.float32) * b2 + g2 * (1 - b2)
        return {"full": full.astype(mdt)}, full
    full = v_leaf.astype(jnp.float32) * b2 + g2 * (1 - b2)
    return full.astype(mdt), full


def adamw_update(params: Any, grads: Any, state: dict, cfg: OptimizerConfig,
                 lr: jnp.ndarray) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, stats)."""
    mdt = jnp.dtype(cfg.moment_dtype)
    count = state["count"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    else:
        scale = jnp.asarray(1.0, jnp.float32)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)
    masters = state.get("master", params)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_master = treedef.flatten_up_to(masters)

    new_p, new_m, new_v, new_master = [], [], [], []
    for p, g, m, v, w in zip(flat_p, flat_g, flat_m, flat_v, flat_master):
        gf = g.astype(jnp.float32) * scale
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vv, denom = _v_update_and_corr(v, jnp.square(gf), b2, cfg)
        upd = (mf / bc1) / (jnp.sqrt(denom / bc2) + cfg.eps)
        wf = w.astype(jnp.float32)
        if cfg.weight_decay > 0 and p.ndim >= 2:
            upd = upd + cfg.weight_decay * wf
        wf = wf - lr * upd
        new_master.append(wf.astype(jnp.dtype(cfg.master_dtype))
                          if cfg.master_dtype != "none" else wf.astype(p.dtype))
        new_p.append(wf.astype(p.dtype))
        new_m.append(mf.astype(mdt))
        new_v.append(vv)

    params2 = jax.tree.unflatten(treedef, new_p)
    state2 = {"m": jax.tree.unflatten(treedef, new_m),
              "v": jax.tree.unflatten(treedef, new_v),
              "count": count}
    if cfg.master_dtype != "none":
        state2["master"] = jax.tree.unflatten(treedef, new_master)
    stats = {"grad_norm": gnorm, "lr": lr}
    return params2, state2, stats
