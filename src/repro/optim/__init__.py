from .adamw import OptimizerConfig, adamw_init, adamw_update, global_norm
from .schedule import lr_at_step

__all__ = ["OptimizerConfig", "adamw_init", "adamw_update", "global_norm",
           "lr_at_step"]
