"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def lr_at_step(step: jnp.ndarray, *, base_lr: float, warmup_steps: int = 0,
               total_steps: int = 0, schedule: str = "cosine",
               min_ratio: float = 0.1) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    lr = jnp.asarray(base_lr, jnp.float32)
    if warmup_steps > 0:
        lr = lr * jnp.minimum(1.0, (step + 1) / warmup_steps)
    if schedule == "cosine" and total_steps > warmup_steps:
        frac = jnp.clip((step - warmup_steps) / (total_steps - warmup_steps),
                        0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        lr = lr * (min_ratio + (1.0 - min_ratio) * cos)
    return lr
