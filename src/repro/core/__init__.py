"""repro.core — the kafka-slurm-agent (KSA) control plane, embedded.

**The public entry point is** :class:`repro.cluster.KsaCluster` — a
context-managed facade that owns broker/topic/agent/monitor lifecycle::

    from repro.cluster import KsaCluster

    with KsaCluster(workers=2, gpu_workers=1) as c:
        tid = c.submit("matrix", params={"n": 96})
        c.wait_all([tid])
        print(c.result(tid))

The components below (paper §3: :class:`Submitter`, :class:`ClusterAgent`,
:class:`WorkerAgent`, :class:`MonitorAgent`, communicating asynchronously over
a durable log — :class:`Broker`) are the facade's building blocks. Wiring
them by hand is considered **internal**: it is still supported (tests and the
facade itself do it), but every component that routes tasks must then be
given the *same* :class:`~repro.core.scheduling.PlacementPolicy`, which the
facade otherwise guarantees.

Resource-aware placement (:mod:`repro.core.scheduling`) extends the paper's
single shared ``PREFIX-new`` topic with per-resource-class topics
(``PREFIX-new.cpu`` / ``PREFIX-new.gpu`` / label classes): agents declare a
:class:`~repro.core.scheduling.ResourceProfile` and subscribe only to the
classes they can serve, so a GPU stage can never execute on a CPU-only pool,
and a pluggable :class:`~repro.core.scheduling.LeasePolicy`
(:class:`~repro.core.scheduling.FairShare` weighted round-robin) arbitrates
how concurrent campaigns drain into that capacity.
"""
from .broker import (Broker, BrokerError, Consumer, FencedError, Producer,
                     Record, TopicPartition)
from .computing import (ClusterComputing, TaskCancelled, register_script,
                        registered_scripts, resolve_script)
from .lease import Lease, LeaseTolerance, RevokeReason
from .scheduling import (FairShare, FifoLease, LeasePolicy, PlacementPolicy,
                         ResourceClassPolicy, ResourceProfile,
                         SingleTopicPolicy, class_topic)
from .agents import AgentBase, ClusterAgent, WorkerAgent
from .messages import (CampaignEvent, ErrorMessage, Resources, ResultMessage,
                       StatusUpdate, TaskMessage, TaskStatus, new_task_id,
                       topic_names)
from .monitor import MonitorAgent, TaskEntry
from .simslurm import SimSlurm
from .submitter import Submitter

__all__ = [
    "AgentBase", "Broker", "BrokerError", "CampaignEvent", "ClusterAgent",
    "ClusterComputing",
    "Consumer", "ErrorMessage", "FairShare", "FencedError", "FifoLease",
    "Lease", "LeasePolicy", "LeaseTolerance", "MonitorAgent",
    "PlacementPolicy", "Producer",
    "Record", "ResourceClassPolicy", "ResourceProfile", "Resources",
    "RevokeReason",
    "ResultMessage", "SimSlurm", "SingleTopicPolicy", "StatusUpdate",
    "Submitter", "TaskCancelled", "TaskEntry", "TaskMessage", "TaskStatus",
    "TopicPartition", "WorkerAgent", "class_topic", "new_task_id",
    "register_script", "registered_scripts", "resolve_script", "topic_names",
]
