"""repro.core — the kafka-slurm-agent (KSA) control plane, embedded.

Components (paper §3): :class:`Submitter`, :class:`ClusterAgent`,
:class:`WorkerAgent`, :class:`MonitorAgent`, communicating asynchronously over
a durable log (:class:`Broker`) with the paper's four-topic layout.
"""
from .broker import (Broker, BrokerError, Consumer, FencedError, Producer,
                     Record, TopicPartition)
from .computing import (ClusterComputing, TaskCancelled, register_script,
                        registered_scripts, resolve_script)
from .agents import AgentBase, ClusterAgent, WorkerAgent
from .messages import (CampaignEvent, ErrorMessage, Resources, ResultMessage,
                       StatusUpdate, TaskMessage, TaskStatus, new_task_id,
                       topic_names)
from .monitor import MonitorAgent, TaskEntry
from .simslurm import SimSlurm
from .submitter import Submitter

__all__ = [
    "AgentBase", "Broker", "BrokerError", "CampaignEvent", "ClusterAgent",
    "ClusterComputing",
    "Consumer", "ErrorMessage", "FencedError", "MonitorAgent", "Producer",
    "Record", "Resources", "ResultMessage", "SimSlurm", "StatusUpdate",
    "Submitter", "TaskCancelled", "TaskEntry", "TaskMessage", "TaskStatus",
    "TopicPartition", "WorkerAgent", "new_task_id", "register_script",
    "registered_scripts", "resolve_script", "topic_names",
]
