"""Embedded Kafka-like durable log — the communication layer of the KSA
control plane.

The paper uses an external Apache Kafka broker as the single piece of shared
infrastructure ("the only requirement is that an Apache Kafka broker be
exposed and accessible from every cluster node or workstation", §1). This
module provides an embedded broker with the same *semantics* so the framework
is dependency-free in this container while keeping the exact API shape of
kafka-python (``producer.send`` / ``consumer.poll`` / ``commit`` / ``seek``)
behind a transport seam — a real Kafka client can be substituted by
implementing the same five methods on :class:`Broker`.

Faithfully implemented Kafka semantics the paper relies on (§3, §6):

* topics split into **partitions**; records carry ``(topic, partition,
  offset)`` coordinates; keyed records hash to a stable partition,
* **consumer groups** with committed offsets per ``(group, topic, partition)``;
  two groups each see every record (broadcast — the paper's "multiple
  MonitorAgents, each receiving a copy"), members of one group load-balance
  partitions (the paper's "each result retrieved and handled by only one of
  the active MonitorAgents"),
* **cooperative rebalance** on membership change (agent joins/leaves/dies) with
  a bumped generation — this is what makes the agent pool *elastic*,
* **at-least-once** (commit after processing; redelivery after a crash) vs
  **exactly-once** (atomic process+produce+commit transaction) selected per
  consumer — the configurability the paper cites as the reason Kafka was
  chosen,
* optional **durability**: per-partition segment files (length-prefixed
  msgpack frames) with replay on restart, plus a committed-offset log; message
  retention is bounded by ``retention_records`` per partition (§6 mentions the
  broker-side retention policy) and can be overridden **per topic**
  (``create_topic(..., retention_records=None)`` pins a journal topic such as
  ``PREFIX-campaigns`` to infinite retention even under a broker-wide cap),
* **replay reads**: :meth:`Broker.read_from` scans a topic from an absolute
  offset outside any consumer group — the API the pipeline recovery path
  uses to fold the campaign journal after an orchestrator crash,
* **explicit prefix deletion**: :meth:`Broker.truncate_before` is the
  ``AdminClient.delete_records`` analogue journal compaction uses to drop
  snapshotted campaigns' events (durable logs persist a truncation marker),
* **incremental backlog counters**: :meth:`Broker.queue_stats` reports
  per-topic depth (produced − committed) for one consumer group from
  counters maintained on the produce/commit paths — the autoscaler's
  per-resource-class demand signal, with no O(records) scans,
* **task leases** (:mod:`repro.core.lease`): every task record fetched
  through :meth:`Broker.lease_records` registers a :class:`~repro.core.lease.Lease`
  (GRANTED → RUNNING → DONE/FAILED/REVOKED). :meth:`Broker.revoke_lease` is
  the single reclamation primitive — it fences the holder's commit, fires
  the task's ``cancel_event``, and (optionally) requeues the record onto
  the topic it was leased from, atomically under the task's lease-shard
  lock; every legacy stop-path (watchdog, drain, scancel/walltime, retry
  fencing, preemption, memory policing) routes through it.

Concurrency model — the sharded data plane
------------------------------------------

The broker used to serialize *every* operation (produce, fetch, grant,
commit, revoke, stats) under one ``threading.RLock``, which caps tasks/sec
far below partition-parallel Kafka. State is now sharded so independent
work never contends:

* **partition locks** (rank 2) — each partition log owns a lock protecting
  its record list, base/next offsets, and segment file. ``append``,
  ``fetch``, and ``truncate_before`` touch only the partition they
  address; ``produce`` never touches group state.
* **group locks** (rank 0) — each consumer group (``_Group.lock``)
  protects its membership, generation, assignment, committed offsets, and
  rebalances. Different groups never contend.
* **lease-shard locks** (rank 1) — the lease registry is a
  :class:`~repro.core.lease.ShardedLeaseTable` hashed by task id;
  grant/claim/complete/revoke on different tasks proceed in parallel while
  every lifecycle op for one task serializes on its shard, preserving the
  per-task atomicity contracts (``complete_lease`` fencing,
  ``revoke_lease``'s fence+cancel+requeue critical section).
* **leaf locks** (unranked) — the registry lock (topic/group maps, member
  id sequence, holder-site tags), the offsets-file lock, and the waiter
  lock. Leaf critical sections are tiny and never acquire a ranked lock.

**Lock-acquisition order**: group (0) → lease shard (1) → partition (2);
a thread may only acquire a ranked lock whose rank is strictly above every
ranked lock it holds (two same-rank locks only in ascending key order —
which the code never needs: partition locks are taken one at a time).
The hot paths: ``lease_records`` holds the group lock while taking
partition locks one at a time for the atomic fetch+commit (0 → 2), then
*releases* the group lock and grants leases in one batched critical
section per lease shard; ``revoke_lease`` requeues the record by producing
inside the task's shard lock (1 → 2). Histogram observes and span appends
happen outside all broker locks (the obs layer has its own short locks).

``debug_locks=True`` wraps every ranked lock in an order-asserting wrapper
that raises :class:`LockOrderError` on a hierarchy violation (e.g. the
group lock acquired while a partition lock is held) — used by the
concurrency stress tests. ``single_lock=True`` is the escape hatch: every
lock aliases one master ``RLock`` and the data plane follows the original
per-record path (fixed-order assignment walk, per-record grants and
observes under the lock) — for debugging lock-sensitive issues and as the
legacy baseline in ``benchmarks/bench_broker.py``.

Blocking fetches use per-topic waiter events instead of one broker-wide
condition variable: a produce wakes only waiters subscribed to that topic
(consumers arm their waiter *before* re-checking, so no wakeup is lost);
rebalances broadcast to all waiters.
"""
from __future__ import annotations

import hashlib
import io
import os
import struct
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Mapping, Sequence

import msgpack

from repro.obs import (FlightRecorder, MetricsRegistry, NullSpanStore,
                       SpanStore, topic_class)

from .lease import ShardedLeaseTable


# --------------------------------------------------------------------------
# Records
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Record:
    topic: str
    partition: int
    offset: int
    key: str | None
    value: Any
    timestamp: float


@dataclass(frozen=True)
class TopicPartition:
    topic: str
    partition: int


class BrokerError(RuntimeError):
    pass


class UnknownTopicError(BrokerError):
    pass


class FencedError(BrokerError):
    """Raised when a consumer from an old generation tries to commit."""


class LockOrderError(RuntimeError):
    """A ``debug_locks=True`` broker detected a lock-hierarchy violation:
    a ranked lock was acquired at or below the rank of one already held
    (e.g. the group lock inside a partition lock, or a second partition
    lock out of key order)."""


def _hash_key(key: str, n: int) -> int:
    h = hashlib.md5(key.encode("utf-8")).digest()
    return int.from_bytes(h[:4], "big") % n


# --------------------------------------------------------------------------
# Lock hierarchy (debug mode) + data waiters
# --------------------------------------------------------------------------

# ranks in the broker lock hierarchy (see module docstring)
_RANK_GROUP = 0
_RANK_SHARD = 1
_RANK_PARTITION = 2

_HELD = threading.local()  # per-thread stack of held _OrderedLocks


class _OrderedLock:
    """RLock wrapper that asserts the broker's lock-acquisition order.

    Acquiring is legal only when this lock's ``(rank, key)`` is strictly
    above every ranked lock the thread already holds (re-entrant
    re-acquisition of a held lock is always legal). Violations raise
    :class:`LockOrderError` *before* blocking, so the stress tests turn a
    potential deadlock into a deterministic failure."""

    __slots__ = ("_lock", "rank", "key")

    def __init__(self, rank: int, key: tuple) -> None:
        self._lock = threading.RLock()
        self.rank = rank
        self.key = key

    def __enter__(self) -> "_OrderedLock":
        stack = getattr(_HELD, "stack", None)
        if stack is None:
            stack = _HELD.stack = []
        if not any(held is self for _, _, held in stack):
            for rank, key, _held in stack:
                if rank > self.rank or (rank == self.rank
                                        and key >= self.key):
                    raise LockOrderError(
                        f"acquiring lock {self.rank}:{self.key} while "
                        f"holding {rank}:{key} violates the order "
                        "group(0) -> shard(1) -> partition(2)")
        self._lock.acquire()
        stack.append((self.rank, self.key, self))
        return self

    def __exit__(self, *exc) -> None:
        stack = _HELD.stack
        for i in range(len(stack) - 1, -1, -1):
            if stack[i][2] is self:
                del stack[i]
                break
        self._lock.release()


class _DataWaiter:
    """One consumer's registered wakeup slot: an event set by produces to
    any of ``topics`` (``None`` = any topic) and by rebalances. The owner
    arms (``clear``) *before* re-checking for data, then waits — a produce
    landing between the check and the wait is never lost."""

    __slots__ = ("_event", "topics")

    def __init__(self, topics: tuple | None) -> None:
        self._event = threading.Event()
        self.topics = topics

    def clear(self) -> None:
        self._event.clear()

    def set(self) -> None:
        self._event.set()

    def wait(self, timeout: float | None = None) -> bool:
        return self._event.wait(timeout)


# --------------------------------------------------------------------------
# Partition log (+ optional segment-file durability)
# --------------------------------------------------------------------------

_FRAME = struct.Struct("<I")

_UNSET = object()  # create_topic sentinel: "use the broker-wide retention"


class _PartitionLog:
    """Append-only in-memory log with an optional on-disk segment file.

    Owns its lock (rank 2 in the broker hierarchy): ``append``, ``fetch``,
    ``truncate_before`` and ``close`` are internally synchronized, so two
    partitions never contend with each other. ``end_offset`` reads a
    single int (GIL-atomic) lock-free — it is a monotonic counter, safe
    for the backlog math that clamps downstream."""

    def __init__(self, topic: str, partition: int, log_dir: str | None,
                 retention_records: int | None, fsync: bool,
                 lock: Any = None):
        self.topic = topic
        self.partition = partition
        self.lock = lock if lock is not None else threading.RLock()
        self.records: list[Record] = []
        self.base_offset = 0  # offset of records[0] after retention trimming
        self.next_offset = 0
        self.retention = retention_records
        self._fsync = fsync
        self._fh: io.BufferedWriter | None = None
        if log_dir is not None:
            os.makedirs(log_dir, exist_ok=True)
            path = os.path.join(log_dir, f"{topic}-{partition}.log")
            self._replay(path)
            self._fh = open(path, "ab")

    def _replay(self, path: str) -> None:
        if not os.path.exists(path):
            return
        with open(path, "rb") as fh:
            data = fh.read()
        pos = 0
        while pos + _FRAME.size <= len(data):
            (length,) = _FRAME.unpack_from(data, pos)
            pos += _FRAME.size
            if pos + length > len(data):
                break  # truncated tail frame (crash mid-write): drop it
            frame = msgpack.unpackb(data[pos:pos + length], raw=False)
            pos += length
            if "trunc" in frame:  # truncation marker (see truncate_before)
                cut = int(frame["trunc"])
                self.records = [r for r in self.records if r.offset >= cut]
                self.base_offset = max(self.base_offset, cut)
                continue
            self.records.append(Record(
                topic=self.topic, partition=self.partition,
                offset=frame["o"], key=frame.get("k"), value=frame["v"],
                timestamp=frame.get("t", 0.0)))
        if self.records:
            self.base_offset = max(self.base_offset, self.records[0].offset)
            self.next_offset = self.records[-1].offset + 1
        else:
            self.next_offset = max(self.next_offset, self.base_offset)

    def append(self, key: str | None, value: Any, ts: float) -> Record:
        with self.lock:
            rec = Record(self.topic, self.partition, self.next_offset, key,
                         value, ts)
            self.records.append(rec)
            self.next_offset += 1
            if self._fh is not None:
                frame = msgpack.packb(
                    {"o": rec.offset, "k": key, "v": value, "t": ts},
                    use_bin_type=True)
                self._fh.write(_FRAME.pack(len(frame)))
                self._fh.write(frame)
                self._fh.flush()
                if self._fsync:
                    os.fsync(self._fh.fileno())
            if self.retention is not None \
                    and len(self.records) > self.retention:
                drop = len(self.records) - self.retention
                self.records = self.records[drop:]
                self.base_offset = self.records[0].offset
            return rec

    def fetch(self, offset: int, max_records: int) -> list[Record]:
        """Records from ``offset`` (clamped to the retained base), at most
        ``max_records``. The slice is taken — and therefore *copied* —
        under the partition lock, so callers hold an immutable snapshot: a
        concurrent ``truncate_before`` or retention trim can never be
        observed mid-iteration."""
        with self.lock:
            offset = max(offset, self.base_offset)
            idx = offset - self.base_offset
            if idx >= len(self.records):
                return []
            return self.records[idx: idx + max_records]

    def end_offset(self) -> int:
        return self.next_offset  # single int read: GIL-atomic, lock-free

    def truncate_before(self, offset: int) -> int:
        """Drop every retained record with offset < ``offset`` (Kafka's
        ``deleteRecords`` semantics). Returns the number of records dropped.
        Durable logs append a truncation marker frame so a restart does not
        resurrect the deleted prefix."""
        with self.lock:
            offset = min(offset, self.next_offset)
            if offset <= self.base_offset:
                return 0
            drop = min(offset - self.base_offset, len(self.records))
            self.records = self.records[drop:]
            self.base_offset = offset
            if self._fh is not None and drop:
                frame = msgpack.packb({"trunc": offset}, use_bin_type=True)
                self._fh.write(_FRAME.pack(len(frame)))
                self._fh.write(frame)
                self._fh.flush()
                if self._fsync:
                    os.fsync(self._fh.fileno())
            return drop

    def close(self) -> None:
        with self.lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


# --------------------------------------------------------------------------
# Consumer groups
# --------------------------------------------------------------------------


@dataclass
class _Member:
    member_id: str
    topics: tuple[str, ...]
    last_heartbeat: float = field(default_factory=time.time)
    # rotating start index into this member's assignment for lease_records:
    # a fixed-order walk starves trailing partitions whenever max_records
    # is exhausted early, so each call starts one partition further along
    lease_cursor: int = 0


@dataclass
class _Group:
    group_id: str
    members: dict[str, _Member] = field(default_factory=dict)
    generation: int = 0
    assignment: dict[str, list[TopicPartition]] = field(default_factory=dict)
    committed: dict[TopicPartition, int] = field(default_factory=dict)
    # rank-0 lock guarding everything above (see broker docstring)
    lock: Any = field(default_factory=threading.RLock)


class Broker:
    """Thread-safe embedded broker with a sharded data plane (see the
    module docstring for the lock hierarchy). All public methods may be
    called from any thread; blocking fetches use per-topic waiter events so
    co-located agents see ~zero poll latency (the paper's polling-interval
    overhead, §6, collapses when the broker is embedded) and a produce
    wakes only consumers of that topic.

    ``single_lock=True`` restores the original one-big-RLock data plane
    (debug escape hatch + benchmark baseline); ``debug_locks=True`` makes
    every ranked lock assert the acquisition order (raises
    :class:`LockOrderError`); ``lease_shards`` sizes the lease registry's
    hash sharding."""

    def __init__(self, log_dir: str | None = None, *,
                 default_partitions: int = 4,
                 retention_records: int | None = None,
                 session_timeout_s: float = 10.0,
                 fsync: bool = False,
                 obs: bool = True,
                 site: str = "",
                 single_lock: bool = False,
                 debug_locks: bool = False,
                 lease_shards: int = 8):
        self.single_lock = bool(single_lock)
        # a single lock cannot violate an order; the wrapper would only
        # slow the baseline down, so debug mode implies the sharded plane
        self._debug_locks = bool(debug_locks) and not self.single_lock
        self._master: threading.RLock | None = (
            threading.RLock() if self.single_lock else None)
        # leaf locks (unranked): tiny critical sections, never held while
        # acquiring a ranked lock. In single-lock mode the registry and
        # offsets locks alias the master so everything serializes as before.
        self._registry_lock: Any = self._master or threading.Lock()
        self._offsets_lock: Any = self._master or threading.Lock()
        self._waiters_lock = threading.Lock()
        self._topic_waiters: dict[str, set[_DataWaiter]] = {}
        self._global_waiters: set[_DataWaiter] = set()
        self._topics: dict[str, list[_PartitionLog]] = {}
        self._groups: dict[str, _Group] = {}
        self._log_dir = log_dir
        self._default_partitions = default_partitions
        self._retention = retention_records
        self._fsync = fsync
        self.session_timeout_s = session_timeout_s
        self._member_seq = 0
        # observability substrate (repro.obs): the broker owns the one
        # registry and span store every co-located component shares.
        # obs=False nulls histograms and spans; counters stay live (the
        # legacy stats views are built on them).
        self.metrics = MetricsRegistry(enabled=obs)
        self.spans = SpanStore() if obs else NullSpanStore()
        self._h_queue_wait = self.metrics.histogram(
            "ksa_task_queue_wait_seconds",
            "Record produce -> lease grant wait, per resource class",
            labels=("cls",))
        self._h_claim = self.metrics.histogram(
            "ksa_lease_claim_latency_seconds",
            "Lease grant -> claim (execution start), per resource class",
            labels=("cls",))
        self._h_run = self.metrics.histogram(
            "ksa_task_run_seconds",
            "Claim -> commit execution time, per resource class",
            labels=("cls",))
        self.metrics.register_callback(
            "ksa_leases_active",
            lambda: self.lease_stats()["active"],
            "Live (GRANTED/RUNNING) leases")
        # crash flight recorder (repro.obs.blackbox): always on — event
        # appends are one deque op — so post-mortems exist even when the
        # telemetry plane is not enabled
        self.blackbox = FlightRecorder()
        self._lease_table = ShardedLeaseTable(
            metrics=self.metrics,
            shards=1 if self.single_lock else max(1, int(lease_shards)),
            lock_factory=lambda i: self._make_lock(_RANK_SHARD,
                                                   ("shard", i)))
        # per-topic cache of (cls, queue-wait child, claim child, run child)
        # so the grant path resolves topic_class + histogram labels once per
        # topic, not once per record (benign last-write-wins under the GIL)
        self._topic_obs_cache: dict[str, tuple] = {}
        # federation: which site this broker belongs to ("" = standalone),
        # and which consumer-group members hold their leases from a remote
        # site — registered by federation bridges so every lease they are
        # granted is stamped with the holder's site and WAN-tolerant
        # heartbeat deadline (consulted by the watchdogs before revoking)
        self.site = site
        self._holder_sites: dict[str, tuple[str, float | None]] = {}
        self._closed = False
        self._offsets_path = (os.path.join(log_dir, "_offsets.log")
                              if log_dir else None)
        if self._offsets_path:
            self._replay_offsets()

    # -- locks / registries --------------------------------------------------

    def _make_lock(self, rank: int, key: tuple) -> Any:
        """One ranked lock of the hierarchy: the master RLock in
        ``single_lock`` mode, an order-asserting wrapper in ``debug_locks``
        mode, a plain RLock otherwise."""
        if self.single_lock:
            return self._master
        if self._debug_locks:
            return _OrderedLock(rank, key)
        return threading.RLock()

    def _topic_logs(self, topic: str) -> list[_PartitionLog]:
        """The topic's partition list, auto-creating like Kafka's
        ``auto.create.topics.enable``. Topics are create-only and a
        partition list is immutable once published, so the fast path is a
        lock-free dict read."""
        logs = self._topics.get(topic)
        if logs is not None:
            return logs
        with self._registry_lock:
            logs = self._topics.get(topic)
            if logs is None:
                logs = self._new_partition_logs(
                    topic, self._default_partitions, self._retention)
                self._topics[topic] = logs
            return logs

    def _new_partition_logs(self, name: str, n: int,
                            retention: int | None) -> list[_PartitionLog]:
        return [
            _PartitionLog(name, p, self._log_dir, retention, self._fsync,
                          lock=self._make_lock(_RANK_PARTITION,
                                               ("partition", name, p)))
            for p in range(n)
        ]

    def _group(self, group_id: str, create: bool = False) -> _Group | None:
        """Lock-free group lookup (groups are create-only); ``create``
        falls back to a registry-locked setdefault."""
        grp = self._groups.get(group_id)
        if grp is not None or not create:
            return grp
        with self._registry_lock:
            grp = self._groups.get(group_id)
            if grp is None:
                grp = _Group(group_id,
                             lock=self._make_lock(_RANK_GROUP,
                                                  ("group", group_id)))
                self._groups[group_id] = grp
            return grp

    # -- topics ------------------------------------------------------------

    def create_topic(self, name: str, partitions: int | None = None,
                     retention_records: int | None | object = _UNSET) -> None:
        """Create a topic (idempotent). ``retention_records`` overrides the
        broker-wide retention for this topic (``None`` = keep every record —
        what a replayable journal topic needs); on an existing topic an
        explicit value updates the retention in place."""
        existed = False
        with self._registry_lock:
            if name in self._topics:
                existed = True
            else:
                n = partitions or self._default_partitions
                retention = (self._retention if retention_records is _UNSET
                             else retention_records)
                self._topics[name] = self._new_partition_logs(
                    name, n, retention)
        if existed and retention_records is not _UNSET:
            self.set_retention(name, retention_records)

    def set_retention(self, topic: str,
                      retention_records: int | None) -> None:
        """Re-bound (or unbound, with ``None``) one topic's per-partition
        retention. Loosening takes effect immediately; tightening trims on
        the next append."""
        for plog in self._topic_logs(topic):
            with plog.lock:
                plog.retention = retention_records

    def topics(self) -> list[str]:
        with self._registry_lock:
            return sorted(self._topics)

    def partitions_for(self, topic: str) -> int:
        return len(self._topic_logs(topic))

    # -- produce / fetch ----------------------------------------------------

    def least_loaded_partition(self, topic: str) -> int:
        """The partition with the fewest records ever produced — the same
        choice unkeyed :meth:`produce` makes. Lets a submitter balance
        *keyed* records (task records must stay keyed for lease granting)
        across partitions instead of hashing, trading per-key placement
        stability for an even per-member share."""
        logs = self._topic_logs(topic)
        return min(range(len(logs)), key=lambda p: logs[p].end_offset())

    def produce(self, topic: str, value: Any, key: str | None = None,
                partition: int | None = None) -> Record:
        """Append one record. Touches only the target partition's lock —
        never group state — then wakes waiters of this topic."""
        logs = self._topic_logs(topic)
        if partition is None:
            if key is not None:
                partition = _hash_key(key, len(logs))
            else:
                partition = min(range(len(logs)),
                                key=lambda p: logs[p].end_offset())
        rec = logs[partition].append(key, value, time.time())
        self._notify(topic)
        return rec

    def fetch(self, tp: TopicPartition, offset: int,
              max_records: int = 500) -> list[Record]:
        return self._topic_logs(tp.topic)[tp.partition].fetch(
            offset, max_records)

    def end_offset(self, tp: TopicPartition) -> int:
        return self._topic_logs(tp.topic)[tp.partition].end_offset()

    def read_from(self, topic: str, offset: int = 0, *,
                  partition: int | None = None) -> list[Record]:
        """Group-less replay read: every retained record of ``topic`` with
        offset ≥ ``offset`` (all partitions unless one is named), ordered by
        ``(partition, offset)``. No consumer group, no committed offsets —
        the caller owns its position. This is the recovery-path API: a
        restarted orchestrator folds the ``PREFIX-campaigns`` journal from
        here (per-campaign order is per-partition order because journal
        records are keyed by campaign id)."""
        logs = self._topic_logs(topic)
        parts = logs if partition is None else [logs[partition]]
        out: list[Record] = []
        for plog in parts:  # one partition lock at a time (inside fetch)
            out.extend(plog.fetch(offset, 1 << 62))
        return out

    def truncate_before(self, topic: str, offset: int, *,
                        partition: int | None = None) -> int:
        """Delete every retained record of ``topic`` with offset < ``offset``
        (one partition, or all of them) — the embedded analogue of Kafka's
        ``AdminClient.delete_records``, used by journal compaction to bound
        the ``PREFIX-campaigns`` topic after terminal campaigns have been
        snapshotted. Returns the number of records dropped. Committed
        offsets are untouched; fetches below the new base offset clamp
        forward to it."""
        logs = self._topic_logs(topic)
        parts = logs if partition is None else [logs[partition]]
        return sum(p.truncate_before(offset) for p in parts)

    # -- data waiters --------------------------------------------------------

    def data_waiter(self, topics: Sequence[str] | None = None) -> _DataWaiter:
        """Register a wakeup slot for produces to ``topics`` (``None`` =
        any topic) and rebalance broadcasts. Consumers arm it (``clear``)
        *before* re-checking for data, wait on it, and must
        :meth:`release_waiter` it when done."""
        w = _DataWaiter(tuple(topics) if topics else None)
        with self._waiters_lock:
            if w.topics is None:
                self._global_waiters.add(w)
            else:
                for t in w.topics:
                    self._topic_waiters.setdefault(t, set()).add(w)
        return w

    def release_waiter(self, w: _DataWaiter) -> None:
        with self._waiters_lock:
            if w.topics is None:
                self._global_waiters.discard(w)
                return
            for t in w.topics:
                ws = self._topic_waiters.get(t)
                if ws is not None:
                    ws.discard(w)
                    if not ws:
                        del self._topic_waiters[t]

    def _notify(self, topic: str) -> None:
        """Wake waiters of one topic (plus topic-agnostic waiters). The
        empty-registry fast path is lock-free so an unwatched produce pays
        nothing."""
        if not self._topic_waiters and not self._global_waiters:
            return
        with self._waiters_lock:
            targets = list(self._topic_waiters.get(topic, ()))
            targets.extend(self._global_waiters)
        for w in targets:
            w.set()

    def _notify_all(self) -> None:
        """Broadcast (rebalance / membership change): assignments moved, so
        every blocked consumer must re-check what it owns."""
        with self._waiters_lock:
            targets = [w for ws in self._topic_waiters.values() for w in ws]
            targets.extend(self._global_waiters)
        for w in targets:
            w.set()

    def wait_for_data(self, timeout: float,
                      topics: Sequence[str] | None = None) -> None:
        """Block until a record is produced to one of ``topics`` (any topic
        if ``None``), a rebalance broadcasts, or the timeout elapses.
        One-shot convenience over :meth:`data_waiter` — for loop use,
        register a waiter once and arm it per iteration (see
        :meth:`Consumer.poll`)."""
        w = self.data_waiter(topics)
        try:
            w.wait(timeout)
        finally:
            self.release_waiter(w)

    # -- backlog accounting (autoscaling signal) -----------------------------

    def queue_stats(self, group_id: str,
                    topics: Sequence[str] | None = None
                    ) -> dict[str, dict[str, int]]:
        """Per-topic backlog of one consumer group, from counters the broker
        already maintains incrementally (partition end offsets and committed
        offsets) — O(topics × partitions) with **no record scans**, safe to
        poll at control-loop frequency.

        For each topic: ``produced`` is the cumulative record count appended
        since topic creation (monotonic — retention trimming does not rewind
        it), ``consumed`` is the cumulative count the group has committed,
        and ``depth`` = produced − consumed is the queue backlog. The
        autoscaler's per-resource-class demand signal is the ``depth`` of
        each ``PREFIX-new.<class>`` topic under the shared agents group;
        drain *rate* falls out of successive ``consumed`` samples."""
        grp = self._groups.get(group_id)
        names = list(topics) if topics is not None else self.topics()
        out: dict[str, dict[str, int]] = {}
        for t in names:
            produced, consumed = self._topic_counters(grp, t)
            out[t] = {"produced": produced,
                      "consumed": min(consumed, produced),
                      "depth": max(0, produced - consumed)}
        return out

    def _topic_counters(self, grp: _Group | None,
                        topic: str) -> tuple[int, int]:
        """(cumulative produced, cumulative committed) for one topic/group —
        the single definition of the backlog counters behind queue_stats()
        and the per-group ``lag`` in stats(). Lock-free: end offsets and
        committed offsets are monotonic ints read GIL-atomically, and the
        callers clamp (``consumed ≤ produced``, ``depth ≥ 0``) so a read
        torn across partitions stays sane."""
        logs = self._topic_logs(topic)
        produced = sum(p.end_offset() for p in logs)
        consumed = 0
        if grp is not None:
            committed = grp.committed
            consumed = sum(committed.get(TopicPartition(topic, p), 0)
                           for p in range(len(logs)))
        return produced, consumed

    # -- consumer groups ----------------------------------------------------

    def join_group(self, group_id: str, topics: Sequence[str],
                   member_id: str | None = None) -> tuple[str, int]:
        """Register a member; returns (member_id, generation). Triggers a
        rebalance (range assignor over the union of subscribed topics)."""
        for t in topics:
            self._topic_logs(t)  # ensure before assignment math
        grp = self._group(group_id, create=True)
        if member_id is None:
            with self._registry_lock:
                self._member_seq += 1
                member_id = f"{group_id}-member-{self._member_seq}"
        with grp.lock:
            grp.members[member_id] = _Member(member_id, tuple(topics))
            self._rebalance(grp)
            return member_id, grp.generation

    def leave_group(self, group_id: str, member_id: str) -> None:
        grp = self._groups.get(group_id)
        if grp is None:
            return
        with grp.lock:
            if member_id in grp.members:
                del grp.members[member_id]
                self._rebalance(grp)

    def heartbeat(self, group_id: str, member_id: str) -> int:
        """Refresh liveness; returns current generation (consumer compares to
        detect rebalances). Also lazily evicts dead members."""
        grp = self._groups.get(group_id)
        if grp is None:
            raise FencedError(f"unknown member {member_id} in {group_id}")
        with grp.lock:
            if member_id not in grp.members:
                raise FencedError(f"unknown member {member_id} in {group_id}")
            grp.members[member_id].last_heartbeat = time.time()
            self._evict_dead(grp)
            return grp.generation

    def _evict_dead(self, grp: _Group) -> None:
        # caller holds grp.lock
        now = time.time()
        dead = [m for m, st in grp.members.items()
                if now - st.last_heartbeat > self.session_timeout_s]
        for m in dead:
            del grp.members[m]
        if dead:
            self._rebalance(grp)

    def evict_expired_members(self) -> None:
        """Watchdog entry point: evict all session-expired members (elastic
        downscale path — the broker notices a dead agent and reassigns its
        partitions to the survivors)."""
        with self._registry_lock:
            groups = list(self._groups.values())
        for grp in groups:  # one group lock at a time
            with grp.lock:
                self._evict_dead(grp)

    def _rebalance(self, grp: _Group) -> None:
        # sticky (cooperative) assignor: a membership change moves only the
        # partitions that *must* move — to a joining member, or away from a
        # departed one. A live member keeps the partitions it is mid-lease
        # on (up to its fair share), which is what makes elastic pool growth
        # duplication-free: the paper's eager-style full reshuffle would
        # hand a just-fetched partition to the new member, whose refetch
        # from the committed offset re-runs the in-flight task.
        prev = {m: set(tps) for m, tps in grp.assignment.items()}
        grp.generation += 1
        grp.assignment = {m: [] for m in grp.members}
        if not grp.members:
            return
        members = sorted(grp.members)
        topics = sorted({t for m in grp.members.values() for t in m.topics})
        for topic in topics:
            subs = [m for m in members if topic in grp.members[m].topics]
            if not subs:
                continue
            nparts = len(self._topics[topic])
            # exact fair shares: every member gets floor or floor+1, with
            # the +1 quotas going to the members holding the most already
            # (maximum stickiness at perfect balance)
            floor, rem = divmod(nparts, len(subs))
            held = {m: sum(1 for tp in prev.get(m, ())
                           if tp.topic == topic) for m in subs}
            by_held = sorted(subs, key=lambda m: (-held[m], m))
            quota = {m: floor + (1 if i < rem else 0)
                     for i, m in enumerate(by_held)}
            counts = {m: 0 for m in subs}
            owner_of: dict[int, str] = {}
            for p in range(nparts):  # sticky pass: keep current owners
                tp = TopicPartition(topic, p)
                for m in subs:
                    if tp in prev.get(m, ()) and counts[m] < quota[m]:
                        owner_of[p] = m
                        counts[m] += 1
                        break
            for p in range(nparts):  # place the rest, least-loaded first
                if p in owner_of:
                    continue
                m = min(subs, key=lambda s: (counts[s] - quota[s], s))
                owner_of[p] = m
                counts[m] += 1
            for p in sorted(owner_of):
                grp.assignment[owner_of[p]].append(TopicPartition(topic, p))
        self._notify_all()

    def assignment(self, group_id: str, member_id: str) -> list[TopicPartition]:
        grp = self._groups.get(group_id)
        if grp is None:
            return []
        with grp.lock:
            if member_id not in grp.members:
                return []
            return list(grp.assignment.get(member_id, []))

    def generation(self, group_id: str) -> int:
        grp = self._groups.get(group_id)
        return grp.generation if grp else 0

    # -- offsets -------------------------------------------------------------

    def _check_fence(self, grp: _Group,
                     offsets: Mapping[TopicPartition, int],
                     member_id: str | None, generation: int | None) -> None:
        """Cooperative-rebalance fencing: a commit from a stale generation
        is still valid for partitions the member *retained* across the
        bump (membership churn elsewhere in the group — e.g. an autoscaler
        growing the pool mid-poll — must not void a live member's lease).
        Only a commit for a partition the member no longer owns is fenced."""
        if generation is None or generation == grp.generation:
            return
        owned = set(grp.assignment.get(member_id or "", []))
        lost = [tp for tp in offsets if tp not in owned]
        if lost:
            raise FencedError(
                f"commit from stale generation {generation} "
                f"(current {grp.generation}) for reassigned partitions "
                f"{[(tp.topic, tp.partition) for tp in lost]}")

    def commit(self, group_id: str, offsets: Mapping[TopicPartition, int],
               member_id: str | None = None,
               generation: int | None = None) -> None:
        grp = self._group(group_id, create=True)
        with grp.lock:
            self._check_fence(grp, offsets, member_id, generation)
            for tp, off in offsets.items():
                grp.committed[tp] = off
            self._persist_offsets(group_id, offsets)

    def committed(self, group_id: str, tp: TopicPartition) -> int:
        grp = self._groups.get(group_id)
        if grp is None:
            return 0
        return grp.committed.get(tp, 0)

    def lease_records(self, group_id: str, member_id: str,
                      max_records: int = 500) -> list[Record]:
        """Atomic fetch+commit ("lease") for one group member: records come
        from the committed offset of each partition the member owns *right
        now*, and the offsets advance in the same critical section. A
        concurrent rebalance therefore can never hand an already-leased
        record to another member — the poll-then-commit window that makes
        eager-rebalance consumers re-run in-flight work during membership
        churn (exactly what an autoscaler growing the pool would trigger).
        This is the task-leasing path agents use; observers (monitor,
        pipeline) keep at-least-once poll()/commit().

        Sharded hot path: the group lock covers only the fetch+commit
        (partition locks taken one at a time inside it, start index
        rotated per call so trailing partitions can't starve); lease
        grants then run in one batched critical section per lease shard,
        and histogram/span observes happen outside all broker locks."""
        if self.single_lock:
            return self._lease_records_legacy(group_id, member_id,
                                              max_records)
        grp = self._groups.get(group_id)
        if grp is None:
            raise FencedError(f"unknown member {member_id} in {group_id}")
        out: list[Record] = []
        with grp.lock:
            member = grp.members.get(member_id)
            if member is None:
                raise FencedError(f"unknown member {member_id} in {group_id}")
            member.last_heartbeat = time.time()
            assigned = grp.assignment.get(member_id, [])
            n = len(assigned)
            updates: dict[TopicPartition, int] = {}
            if n:
                start = member.lease_cursor % n
                member.lease_cursor = start + 1
                budget = max_records
                for k in range(n):
                    if budget <= 0:
                        break
                    tp = assigned[(start + k) % n]
                    off = grp.committed.get(tp, 0)
                    recs = self._topics[tp.topic][tp.partition].fetch(
                        off, budget)
                    if recs:
                        out.extend(recs)
                        updates[tp] = recs[-1].offset + 1
                        grp.committed[tp] = updates[tp]
                        budget -= len(recs)
            if updates:
                self._persist_offsets(group_id, updates)
        if out:
            self._grant_and_observe(out, member_id)
        return out

    def _grant_and_observe(self, records: list[Record],
                           member_id: str) -> None:
        """Batched lease grants for just-leased records + vectorized
        observability. Runs *after* the group lock is released: the records
        are already this member's responsibility (offsets committed), and
        any claim/revoke race on a not-yet-granted lease falls into the
        lease table's existing stale-sibling / tombstone fencing."""
        task_recs = [r for r in records
                     # task records (keyed, self-describing) get a GRANTED
                     # lease — the handle every stop-path revokes through
                     if r.key and isinstance(r.value, dict)
                     and r.value.get("task_id") == r.key]
        if not task_recs:
            return
        h_site, h_deadline = self._holder_sites.get(
            member_id, (self.site, None))
        now = time.time()
        pairs = self._lease_table.grant_batch(
            task_recs, member_id, site=h_site, deadline_s=h_deadline,
            now=now)
        # vectorized observes, one histogram lock hold per class and one
        # span-store lock hold per batch — never inside a broker lock
        waits: dict[str, tuple] = {}
        spans: list[dict] = []
        last_topic, obs = None, None
        for rec, lease in pairs:
            if lease is None:
                continue
            # the grant span's duration IS the queue wait:
            # record append -> this lease
            if rec.topic != last_topic:
                last_topic = rec.topic
                obs = self._topic_obs(rec.topic)
            cls = obs[0]
            wait = now - rec.timestamp
            w = waits.get(cls)
            if w is None:
                w = waits[cls] = (obs[1], [])
            w[1].append(wait)
            trace = rec.value.get("trace") or {}
            spans.append((rec.key, {
                "name": "grant", "task_id": rec.key,
                "start": rec.timestamp, "end": now,
                "dur_s": wait if wait > 0.0 else 0.0,
                "attempt": lease.attempt, "holder": member_id,
                "topic": rec.topic, "cls": cls,
                "trace_id": trace.get("trace_id", rec.key)}))
        for h_wait, vals in waits.values():
            h_wait.observe_many(vals)
        if spans:
            self.spans.add_batch(spans)
            # blackbox: grants are recorded count-level per batch — one
            # ring slot per poll, not per task, so grant volume cannot
            # wash the interesting (revocation/drain) events out
            self.blackbox.record("grants", holder=member_id,
                                 count=len(spans))

    def _topic_obs(self, topic: str) -> tuple:
        """Cached ``(cls, queue-wait, claim, run)`` histogram children for
        one topic — topic_class parsing and label interning happen once per
        topic, not once per record."""
        t = self._topic_obs_cache.get(topic)
        if t is None:
            cls = topic_class(topic)
            t = (cls,
                 self._h_queue_wait.labels(cls=cls),
                 self._h_claim.labels(cls=cls),
                 self._h_run.labels(cls=cls))
            self._topic_obs_cache[topic] = t
        return t

    def _lease_records_legacy(self, group_id: str, member_id: str,
                              max_records: int) -> list[Record]:
        """The seed's single-lock data plane, preserved verbatim as the
        ``single_lock=True`` escape hatch and the benchmark baseline:
        fixed-order assignment walk (no rotation), per-record grants with a
        value copy, and per-record topic_class / label / observe / span
        work, all inside the master lock."""
        with self._master:
            grp = self._groups.get(group_id)
            if grp is None or member_id not in grp.members:
                raise FencedError(f"unknown member {member_id} in {group_id}")
            grp.members[member_id].last_heartbeat = time.time()
            out: list[Record] = []
            budget = max_records
            updates: dict[TopicPartition, int] = {}
            for tp in grp.assignment.get(member_id, []):
                if budget <= 0:
                    break
                off = grp.committed.get(tp, 0)
                recs = self._topics[tp.topic][tp.partition].fetch(off, budget)
                if recs:
                    out.extend(recs)
                    updates[tp] = recs[-1].offset + 1
                    grp.committed[tp] = updates[tp]
                    budget -= len(recs)
            if updates:
                self._persist_offsets(group_id, updates)
            now = time.time()
            for rec in out:
                if rec.key and isinstance(rec.value, dict) \
                        and rec.value.get("task_id") == rec.key:
                    h_site, h_deadline = self._holder_sites.get(
                        member_id, (self.site, None))
                    lease = self._lease_table.grant(
                        rec.key, member_id, rec.topic,
                        int(rec.value.get("attempt", 0)), dict(rec.value),
                        site=h_site, deadline_s=h_deadline)
                    if lease is not None:
                        # uncached class parse, per-record label lookup and
                        # observe — the per-record cost profile the sharded
                        # plane is benchmarked against
                        cls = topic_class.__wrapped__(rec.topic)
                        self._h_queue_wait.labels(cls=cls).observe(
                            now - rec.timestamp)
                        trace = rec.value.get("trace") or {}
                        self.spans.add(
                            rec.key, "grant", rec.timestamp, now,
                            attempt=lease.attempt, holder=member_id,
                            topic=rec.topic, cls=cls,
                            trace_id=trace.get("trace_id", rec.key))
            return out

    # -- task leases (repro.core.lease) -------------------------------------

    def claim_start(self, task_id: str, holder: str, attempt: int,
                    cancel: Any, on_revoke: Callable[[], None] | None = None
                    ) -> bool:
        """GRANTED → RUNNING for the holder's lease, binding the task's
        ``cancel_event`` (and an optional ``on_revoke`` hook, e.g. the
        ClusterAgent's ``scancel``). False means the lease was revoked or
        superseded while queued — the holder must drop the task, its record
        has already been requeued (or belongs to someone else)."""
        ok, lease = self._lease_table.claim_start(task_id, holder, attempt,
                                                  cancel, on_revoke)
        if ok and lease is not None and lease.started_at is not None:
            if self.single_lock:
                with self._master:
                    cls = topic_class.__wrapped__(lease.topic)
                    self._h_claim.labels(cls=cls).observe(
                        lease.started_at - lease.granted_at)
                    self.spans.add(task_id, "claim", lease.granted_at,
                                   lease.started_at, attempt=attempt,
                                   holder=holder, cls=cls)
            else:
                # observes outside the shard lock (obs has its own locks)
                cls, _w, h_claim, _r = self._topic_obs(lease.topic)
                h_claim.observe(lease.started_at - lease.granted_at)
                self.spans.add(task_id, "claim", lease.granted_at,
                               lease.started_at, attempt=attempt,
                               holder=holder, cls=cls)
        return ok

    def complete_lease(self, task_id: str, holder: str | None = None,
                       attempt: int | None = None, *, ok: bool = True) -> bool:
        """The commit gate: atomically RUNNING → DONE/FAILED. Returns False
        when the lease was revoked (or superseded) — the holder's result or
        error is stale and must be suppressed, because the revocation
        already requeued the task."""
        committed, lease = self._lease_table.complete(task_id, holder,
                                                      attempt, ok)
        if committed and lease is not None and lease.started_at is not None:
            now = time.time()
            if self.single_lock:
                with self._master:
                    cls = topic_class.__wrapped__(lease.topic)
                    self._h_run.labels(cls=cls).observe(
                        now - lease.started_at)
                    self.spans.add(task_id, "run", lease.started_at, now,
                                   attempt=lease.attempt,
                                   holder=lease.holder, ok=ok, cls=cls)
            else:
                cls, _w, _c, h_run = self._topic_obs(lease.topic)
                h_run.observe(now - lease.started_at)
                self.spans.add(task_id, "run", lease.started_at, now,
                               attempt=lease.attempt, holder=lease.holder,
                               ok=ok, cls=cls)
        return committed

    def claim_start_batch(self, items: Sequence[tuple], holder: str,
                          cancel: Any,
                          on_revoke: Callable[[], None] | None = None
                          ) -> dict[str, bool]:
        """Batched :meth:`claim_start` for one holder starting a wave of
        tasks: ``items`` is ``[(task_id, attempt), ...]``; every claim binds
        the same ``cancel`` event / ``on_revoke`` hook. One lease-shard
        critical section per shard touched, one histogram flush per topic
        class and one span-store flush for the whole wave. Returns
        ``{task_id: ok}`` with exactly the per-task semantics of the scalar
        call."""
        if self.single_lock:
            # legacy plane: per-record claims under the master lock
            return {tid: self.claim_start(tid, holder, attempt, cancel,
                                          on_revoke)
                    for tid, attempt in items}
        results = self._lease_table.claim_start_batch(items, holder, cancel,
                                                      on_revoke)
        waits: dict[str, tuple] = {}
        spans: list[dict] = []
        out: dict[str, bool] = {}
        last_topic, obs = None, None
        for task_id, ok, lease in results:
            out[task_id] = ok
            if not ok or lease is None or lease.started_at is None:
                continue
            if lease.topic != last_topic:
                last_topic = lease.topic
                obs = self._topic_obs(lease.topic)
            cls = obs[0]
            dur = lease.started_at - lease.granted_at
            w = waits.get(cls)
            if w is None:
                w = waits[cls] = (obs[2], [])
            w[1].append(dur)
            spans.append((task_id, {
                "name": "claim", "task_id": task_id,
                "start": lease.granted_at, "end": lease.started_at,
                "dur_s": dur if dur > 0.0 else 0.0,
                "attempt": lease.attempt, "holder": holder, "cls": cls}))
        for h_claim, vals in waits.values():
            h_claim.observe_many(vals)
        if spans:
            self.spans.add_batch(spans)
        return out

    def complete_lease_batch(self, items: Sequence[tuple],
                             holder: str | None = None, *,
                             ok: bool = True) -> dict[str, bool]:
        """Batched :meth:`complete_lease`: ``items`` is ``[(task_id,
        attempt|None), ...]`` sharing one wave outcome ``ok`` — a holder
        commits its successes and failures as separate waves. One
        lease-shard critical section per shard touched and one vectorized
        obs flush for the whole wave; every entry passes through the same
        commit gate (holder/attempt fencing, completion tombstones) as the
        scalar call. Returns ``{task_id: committed}``."""
        if self.single_lock:
            return {tid: self.complete_lease(tid, holder, attempt, ok=ok)
                    for tid, attempt in items}
        results = self._lease_table.complete_batch(items, holder, ok)
        now = time.time()
        runs: dict[str, tuple] = {}
        spans: list[dict] = []
        out: dict[str, bool] = {}
        last_topic, obs = None, None
        for task_id, committed, lease in results:
            out[task_id] = committed
            if not committed or lease is None or lease.started_at is None:
                continue
            if lease.topic != last_topic:
                last_topic = lease.topic
                obs = self._topic_obs(lease.topic)
            cls = obs[0]
            dur = now - lease.started_at
            r = runs.get(cls)
            if r is None:
                r = runs[cls] = (obs[3], [])
            r[1].append(dur)
            spans.append((task_id, {
                "name": "run", "task_id": task_id,
                "start": lease.started_at, "end": now,
                "dur_s": dur if dur > 0.0 else 0.0,
                "attempt": lease.attempt, "holder": lease.holder,
                "ok": ok, "cls": cls}))
        for h_run, vals in runs.values():
            h_run.observe_many(vals)
        if spans:
            self.spans.add_batch(spans)
        return out

    def revoke_lease(self, task_id: str, reason: str, *,
                     requeue: bool = True) -> bool:
        """**The** reclamation primitive: atomically (one critical section)
        fence the holder's commit, fire the task's ``cancel_event`` /
        ``on_revoke`` hook, and — with ``requeue`` — put the task record
        back on the topic it was leased from (same attempt if it never
        started; bumped attempt if it was running, so the stale holder's
        status updates are fenced downstream too). Returns False when there
        is no live lease — already terminal, never leased, or lost the race
        to a concurrent :meth:`complete_lease` — in which case nothing is
        cancelled and nothing is requeued (a completed task is never
        double-run).

        The fence+cancel+requeue happens inside the task's lease-shard
        critical section (the requeue produce takes a partition lock
        *inside* the shard lock — the legal 1 → 2 order), so a revoked
        task is never both requeued and completed."""
        def _requeue(lease) -> None:
            value = dict(lease.value)
            if lease.started_at is not None:
                value["attempt"] = lease.attempt + 1
            self.produce(lease.topic, value, key=task_id)

        cb = _requeue if requeue else None
        if self.single_lock:
            with self._master:
                lease = self._lease_table.revoke(task_id, reason, cb)
        else:
            lease = self._lease_table.revoke(task_id, reason, cb)
        if lease is None:
            return False
        self.spans.add(task_id, "revoke",
                       lease.revoked_at, lease.revoked_at,
                       attempt=lease.attempt, holder=lease.holder,
                       reason=reason, requeued=requeue)
        # blackbox: every revocation, with its reason — the flight
        # recorder's storm detector auto-dumps on a burst of these
        self.blackbox.record("revocation", task_id=task_id, reason=reason,
                             holder=lease.holder, attempt=lease.attempt,
                             requeued=requeue)
        return True

    def register_holder_site(self, member_id: str, site: str,
                             deadline_s: float | None = None) -> None:
        """Tag a consumer-group member as executing on a (remote) federation
        site: every lease granted to ``member_id`` from now on is stamped
        with ``site`` and the WAN-tolerant heartbeat ``deadline_s`` (see
        :class:`~repro.core.lease.LeaseTolerance`), which the MonitorAgent
        and PipelineAgent watchdogs honour instead of their uniform
        deadline. Idempotent; re-registering updates the deadline."""
        with self._registry_lock:
            self._holder_sites[member_id] = (site, deadline_s)

    def unregister_holder_site(self, member_id: str) -> None:
        """Drop a member's site tag (bridge drained/stopped). Leases already
        granted keep their stamp — their holder really is remote until they
        reach a terminal state."""
        with self._registry_lock:
            self._holder_sites.pop(member_id, None)

    def forget_lease(self, task_id: str, holder: str) -> None:
        """Drop the holder's lease without a verdict (misroute bounce: the
        rerouted record grants a fresh lease to whoever leases it)."""
        self._lease_table.forget(task_id, holder)

    def lease_view(self, task_id: str) -> dict | None:
        """Observability snapshot of one task's lease (None if untracked)."""
        return self._lease_table.get_view(task_id)

    def live_leases(self, task_ids: Sequence[str] | None = None,
                    holder: str | None = None) -> list[dict]:
        """Views of live (GRANTED/RUNNING) leases, optionally filtered —
        the preemption victim-selection query."""
        return self._lease_table.live_views(task_ids, holder)

    def lease_stats(self) -> dict:
        """Cumulative lease counters: granted/completed/failed/requeued and
        revocations by reason — the unified stop-path telemetry."""
        return self._lease_table.stats()

    # -- transactions (exactly-once) -----------------------------------------

    def transact(self, group_id: str, offsets: Mapping[TopicPartition, int],
                 produces: Iterable[tuple[str, Any, str | None]],
                 member_id: str | None = None,
                 generation: int | None = None) -> list[Record]:
        """Atomically: verify generation fencing, append all ``produces``
        ``(topic, value, key)``, and commit ``offsets``. Atomicity is with
        respect to the *group*: fence check, appends, and offset commits
        all happen under the group lock (produces take partition locks
        inside it — the legal 0 → 2 order), so no consumer of this group
        can observe the offsets without the produces, and a stale
        generation can never get either in."""
        grp = self._group(group_id, create=True)
        with grp.lock:
            # exactly-once keeps the *strict* generation fence: the relaxed
            # ownership check would let a member that lost and regained a
            # partition across two rebalances replay its produces (the
            # at-least-once commit path tolerates that; a transaction must
            # not)
            if generation is not None and generation != grp.generation:
                raise FencedError(
                    f"transaction from stale generation {generation} "
                    f"(current {grp.generation})")
            out = [self.produce(t, v, key=k) for (t, v, k) in produces]
            for tp, off in offsets.items():
                grp.committed[tp] = off
            self._persist_offsets(group_id, offsets)
            return out

    # -- offset durability -----------------------------------------------------

    def _persist_offsets(self, group_id: str,
                         offsets: Mapping[TopicPartition, int]) -> None:
        if not self._offsets_path:
            return
        with self._offsets_lock:  # leaf: serializes the shared offsets file
            with open(self._offsets_path, "ab") as fh:
                for tp, off in offsets.items():
                    frame = msgpack.packb(
                        {"g": group_id, "t": tp.topic, "p": tp.partition,
                         "o": off},
                        use_bin_type=True)
                    fh.write(_FRAME.pack(len(frame)))
                    fh.write(frame)

    def _replay_offsets(self) -> None:
        path = self._offsets_path
        if not path or not os.path.exists(path):
            return
        with open(path, "rb") as fh:
            data = fh.read()
        pos = 0
        while pos + _FRAME.size <= len(data):
            (length,) = _FRAME.unpack_from(data, pos)
            pos += _FRAME.size
            if pos + length > len(data):
                break
            d = msgpack.unpackb(data[pos:pos + length], raw=False)
            pos += length
            grp = self._group(d["g"], create=True)
            grp.committed[TopicPartition(d["t"], d["p"])] = d["o"]

    # -- lifecycle -------------------------------------------------------------

    def close(self) -> None:
        with self._registry_lock:
            if self._closed:
                return
            self._closed = True
            all_logs = [log for logs in self._topics.values()
                        for log in logs]
        for log in all_logs:  # partition locks taken inside close()
            log.close()

    # stats for the MonitorAgent REST API / benchmarks
    def stats(self) -> dict:
        with self._registry_lock:
            topic_snapshot = dict(self._topics)
            group_snapshot = dict(self._groups)

        def _lag(grp: _Group) -> dict[str, int]:
            # per-topic depth over the topics the group has touched —
            # the queue_stats counters, surfaced for /broker
            touched = sorted({tp.topic for tp in grp.committed} |
                             {t for m in grp.members.values()
                              for t in m.topics})
            out = {}
            for t in touched:
                if t not in topic_snapshot:
                    continue
                produced, consumed = self._topic_counters(grp, t)
                out[t] = max(0, produced - consumed)
            return out

        groups = {}
        for g, grp in group_snapshot.items():
            with grp.lock:  # one group lock at a time
                groups[g] = {
                    "members": sorted(grp.members),
                    "generation": grp.generation,
                    "committed": {
                        f"{tp.topic}:{tp.partition}": off
                        for tp, off in sorted(
                            grp.committed.items(),
                            key=lambda kv: (kv[0].topic, kv[0].partition))
                    },
                    "lag": _lag(grp),
                }
        return {
            "site": self.site,
            "topics": {
                t: {str(p): logs[p].end_offset() for p in range(len(logs))}
                for t, logs in topic_snapshot.items()
            },
            "groups": groups,
            "leases": self._lease_table.stats(),
        }


# --------------------------------------------------------------------------
# kafka-python-shaped clients
# --------------------------------------------------------------------------


class Producer:
    """API shape of ``kafka.KafkaProducer`` (paper §5 uses kafka-python-ng)."""

    def __init__(self, broker: Broker):
        self._broker = broker
        self._dead = False

    def send(self, topic: str, value: Any, key: str | None = None,
             partition: int | None = None) -> Record | None:
        if self._dead:  # simulated process death (see AgentBase.crash)
            return None
        return self._broker.produce(topic, value, key=key, partition=partition)

    def kill(self) -> None:
        """Test hook: silently drop all future sends, as a dead process would."""
        self._dead = True

    def flush(self) -> None:  # embedded log is synchronous; kept for API parity
        pass


class Consumer:
    """Group consumer with the kafka-python API shape.

    ``semantics`` selects the paper's delivery knob:

    * ``"at_least_once"`` — caller processes records then calls ``commit()``;
      a crash before commit redelivers (to whichever member owns the partition
      after the next rebalance).
    * ``"exactly_once"`` — caller uses :meth:`process_transactionally`, which
      runs the handler and atomically appends its output records + commits the
      input offsets under generation fencing.
    """

    def __init__(self, broker: Broker, topics: Sequence[str], group_id: str,
                 *, semantics: str = "at_least_once",
                 max_poll_records: int = 500,
                 member_id: str | None = None):
        if semantics not in ("at_least_once", "exactly_once"):
            raise ValueError(f"unknown semantics: {semantics}")
        self._broker = broker
        self._group = group_id
        self.semantics = semantics
        self._max_poll = max_poll_records
        self._topics = tuple(topics)
        self.member_id, self._generation = broker.join_group(
            group_id, topics, member_id=member_id)
        self._positions: dict[TopicPartition, int] = {}
        self._pending: dict[TopicPartition, int] = {}
        self._closed = False

    # -- assignment bookkeeping --------------------------------------------

    def _sync_assignment(self) -> list[TopicPartition]:
        gen = self._broker.heartbeat(self._group, self.member_id)
        if gen != self._generation:
            # rebalance happened: drop positions for partitions we lost,
            # re-seek newly acquired partitions to their committed offset.
            self._generation = gen
            self._positions = {}
            self._pending = {}
        assignment = self._broker.assignment(self._group, self.member_id)
        for tp in assignment:
            if tp not in self._positions:
                self._positions[tp] = self._broker.committed(self._group, tp)
        return assignment

    def assignment(self) -> list[TopicPartition]:
        return self._sync_assignment()

    # -- polling -------------------------------------------------------------

    def poll(self, timeout: float = 0.0,
             max_records: int | None = None) -> dict[TopicPartition, list[Record]]:
        if self._closed:
            raise BrokerError("consumer is closed")
        deadline = time.time() + timeout
        max_records = max_records or self._max_poll
        waiter = None
        try:
            while True:
                if waiter is not None:
                    waiter.clear()  # arm BEFORE checking: no lost wakeup
                out: dict[TopicPartition, list[Record]] = {}
                budget = max_records
                for tp in self._sync_assignment():
                    if budget <= 0:
                        break
                    recs = self._broker.fetch(tp, self._positions[tp], budget)
                    if recs:
                        out[tp] = recs
                        self._positions[tp] = recs[-1].offset + 1
                        self._pending[tp] = recs[-1].offset + 1
                        budget -= len(recs)
                if out or time.time() >= deadline:
                    return out
                if waiter is None:
                    # register, then loop once more: a produce that landed
                    # before registration is caught by the re-check
                    waiter = self._broker.data_waiter(self._topics)
                    continue
                waiter.wait(max(0.0, deadline - time.time()))
        finally:
            if waiter is not None:
                self._broker.release_waiter(waiter)

    # -- leasing (atomic fetch+commit) ------------------------------------------

    def lease(self, timeout: float = 0.0,
              max_records: int | None = None) -> list[Record]:
        """Fetch records with their offsets committed atomically (see
        :meth:`Broker.lease_records`) — the consumption mode for task
        *leasing*: once returned, a record is this member's responsibility
        and will never be redelivered by a rebalance. Liveness recovery for
        a member that dies after leasing is the watchdog's job, exactly the
        agents' two-level fault-tolerance contract."""
        if self._closed:
            raise BrokerError("consumer is closed")
        deadline = time.time() + timeout
        max_records = max_records or self._max_poll
        waiter = None
        try:
            while True:
                if waiter is not None:
                    waiter.clear()  # arm BEFORE checking: no lost wakeup
                if self._broker.single_lock:
                    # legacy data plane: heartbeat + assignment round trip
                    # per call, exactly as the seed consumer did
                    self._sync_assignment()
                # sharded plane: lease_records heartbeats internally and
                # reads the live assignment under the group lock — the
                # extra sync here would just be two more group-lock trips
                recs = self._broker.lease_records(self._group,
                                                  self.member_id,
                                                  max_records)
                if recs or time.time() >= deadline:
                    return recs
                if waiter is None:
                    waiter = self._broker.data_waiter(self._topics)
                    continue
                waiter.wait(max(0.0, deadline - time.time()))
        finally:
            if waiter is not None:
                self._broker.release_waiter(waiter)

    # -- offsets ---------------------------------------------------------------

    def commit(self) -> None:
        """At-least-once commit of everything returned by previous polls."""
        if self._pending:
            self._broker.commit(self._group, dict(self._pending),
                                member_id=self.member_id,
                                generation=self._generation)
            self._pending = {}

    def seek(self, tp: TopicPartition, offset: int) -> None:
        self._positions[tp] = offset

    def position(self, tp: TopicPartition) -> int:
        return self._positions.get(tp, self._broker.committed(self._group, tp))

    # -- exactly-once -----------------------------------------------------------

    def process_transactionally(
        self, handler: Callable[[list[Record]], Iterable[tuple[str, Any, str | None]]],
        timeout: float = 0.0,
    ) -> int:
        """Poll once; run ``handler(records) -> [(topic, value, key), ...]``;
        atomically append outputs and commit inputs. Returns #records
        processed. If the handler raises, nothing commits (pure redelivery)."""
        batches = self.poll(timeout)
        records = [r for recs in batches.values() for r in recs]
        if not records:
            return 0
        produces = list(handler(records))
        self._broker.transact(self._group, dict(self._pending), produces,
                              member_id=self.member_id,
                              generation=self._generation)
        self._pending = {}
        return len(records)

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._broker.leave_group(self._group, self.member_id)
