"""Message schemas for the KSA control plane.

The paper (kafka-slurm-agent, §3/§5) routes four kinds of messages over four
Kafka topics:

  ``PREFIX-new``   — task descriptions to be computed,
  ``PREFIX-jobs``  — task status updates (SUBMITTED, WAITING, RUNNING, DONE, ...),
  ``PREFIX-done``  — results of finished tasks,
  ``PREFIX-error`` — error reports.

We keep the same four-topic layout and the same lifecycle, and add the fields
needed for at-least-once redelivery with attempt fencing (``attempt``) which the
paper lists as a future extension ("running multiple copies of each task ...
the current implementation of the status update mechanism is not designed to
handle this scenario").
"""
from __future__ import annotations

import dataclasses
import enum
import time
import uuid
from typing import Any, Mapping


class TaskStatus(str, enum.Enum):
    """Lifecycle from the paper's ``PREFIX-jobs`` topic (§5), plus the
    timeout/cancel states implied by the ClusterAgent watchdog (§3)."""

    SUBMITTED = "SUBMITTED"
    WAITING = "WAITING"
    RUNNING = "RUNNING"
    DONE = "DONE"
    ERROR = "ERROR"
    TIMEOUT = "TIMEOUT"
    CANCELLED = "CANCELLED"
    # the lease layer (repro.core.lease) took the task back: the attempt is
    # fenced and the record was (or will be) requeued by the revoker — the
    # monitor must NOT resubmit on this status, unlike TIMEOUT/CANCELLED.
    REVOKED = "REVOKED"
    # custom statuses may be emitted by computing scripts at any point (§5);
    # anything not in this enum is passed through verbatim as a string.


TERMINAL_STATUSES = frozenset(
    {TaskStatus.DONE, TaskStatus.ERROR, TaskStatus.CANCELLED}
)


def topic_names(prefix: str) -> Mapping[str, str]:
    """The paper's default topic layout (§5), plus the ``-campaigns`` topic
    carrying both :class:`CampaignEvent` progress snapshots and the pipeline
    agents' write-ahead journal of typed campaign events
    (:mod:`repro.pipeline.state`) — the durable log that makes campaigns
    recoverable after an orchestrator crash.

    ``new`` is the *base* task-topic name. Resource-aware placement
    (:mod:`repro.core.scheduling`) routes tasks to per-resource-class
    children of it (``PREFIX-new.cpu``, ``PREFIX-new.gpu``, ...); the flat
    :class:`~repro.core.scheduling.SingleTopicPolicy` uses the base topic
    directly, which is the paper's original layout.

    ``telemetry`` is the telemetry plane's durable stream
    (:mod:`repro.obs.telemetry`): periodic metric/span/event snapshot
    records, replayable like the journal so a restarted collector
    rebuilds its time-series store from the topic."""
    return {
        "new": f"{prefix}-new",
        "jobs": f"{prefix}-jobs",
        "done": f"{prefix}-done",
        "error": f"{prefix}-error",
        "campaigns": f"{prefix}-campaigns",
        "telemetry": f"{prefix}-telemetry",
    }


@dataclasses.dataclass
class Resources:
    """Resource request serialized with every task (paper §5: GPU, memory,
    number of CPUs). ``labels`` name extra resource classes (e.g. a
    ``bigmem`` pool) the placement policy can route on; ``tolerations`` let a
    task *accept* a tainted pool it does not otherwise request (a batch task
    tolerating the ``serve`` taint may be routed onto the serve pool) — see
    :mod:`repro.core.scheduling`. ``mem_mb`` is enforced at lease time:
    workers admit tasks only while the sum of running requests fits their
    profile, and SimSlurm packs it per node alongside cpus/gpus.

    ``site`` pins the task to a named federation site (see
    :mod:`repro.federation`): a :class:`~repro.federation.SiteRouter` routes
    it to that site's bridge class instead of the generic cpu/gpu classes.
    ``input_mb`` is the task's input payload weight — the data-locality
    term a federated router charges against a WAN link's bandwidth when
    scoring a remote placement. Both default to the non-federated no-ops."""

    cpus: int = 1
    gpus: int = 0
    mem_mb: int = 1024
    labels: tuple = ()
    tolerations: tuple = ()
    site: str = ""
    input_mb: float = 0.0

    def __post_init__(self) -> None:
        self.labels = tuple(self.labels)
        self.tolerations = tuple(self.tolerations)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["labels"] = list(self.labels)
        d["tolerations"] = list(self.tolerations)
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any] | None) -> "Resources":
        if d is None:
            return cls()
        return cls(**{k: d[k]
                      for k in ("cpus", "gpus", "mem_mb", "labels",
                                "tolerations", "site", "input_mb")
                      if k in d})


@dataclasses.dataclass
class TaskMessage:
    """A unit of work on ``PREFIX-new``.

    ``script`` names the computation (paper: the Python script to run; here:
    a registered ``ClusterComputing`` subclass or callable kind such as
    ``"train_chunk"``, ``"knot_batch"``, ``"serve_microbatch"``).
    ``params`` is the arbitrary JSON-serializable payload the paper passes to
    the computing script as its configuration.
    """

    task_id: str
    script: str
    params: dict = dataclasses.field(default_factory=dict)
    resources: Resources = dataclasses.field(default_factory=Resources)
    attempt: int = 0
    timeout_s: float | None = None
    submitted_at: float = dataclasses.field(default_factory=time.time)
    # campaign metadata (repro.pipeline): which campaign/stage this task
    # belongs to and which upstream task_ids it consumed. Flat tasks leave
    # these unset — the control plane treats them identically either way.
    campaign_id: str | None = None
    stage: str | None = None
    dep_ids: list = dataclasses.field(default_factory=list)
    # trace context (repro.obs): carried end-to-end so every control-plane
    # hop can attach spans to the same logical task. The submitter stamps
    # ``trace_id`` (defaults to the task_id) if unset; pipeline tasks also
    # carry ``parent`` = campaign_id. Redeliveries share the dict, which is
    # what links attempt spans into one chain.
    trace: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["resources"] = self.resources.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "TaskMessage":
        return cls(
            task_id=d["task_id"],
            script=d["script"],
            params=dict(d.get("params", {})),
            resources=Resources.from_dict(d.get("resources")),
            attempt=int(d.get("attempt", 0)),
            timeout_s=d.get("timeout_s"),
            submitted_at=float(d.get("submitted_at", time.time())),
            campaign_id=d.get("campaign_id"),
            stage=d.get("stage"),
            dep_ids=list(d.get("dep_ids", [])),
            trace=dict(d.get("trace") or {}),
        )

    def retry(self) -> "TaskMessage":
        """A redelivery copy with a bumped attempt counter (fencing token)."""
        nxt = dataclasses.replace(self, attempt=self.attempt + 1)
        return nxt


@dataclasses.dataclass
class StatusUpdate:
    """A record on ``PREFIX-jobs``."""

    task_id: str
    status: str
    agent_id: str = ""
    attempt: int = 0
    info: dict = dataclasses.field(default_factory=dict)
    ts: float = dataclasses.field(default_factory=time.time)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "StatusUpdate":
        return cls(
            task_id=d["task_id"],
            status=str(d["status"]),
            agent_id=d.get("agent_id", ""),
            attempt=int(d.get("attempt", 0)),
            info=dict(d.get("info", {})),
            ts=float(d.get("ts", time.time())),
        )


@dataclasses.dataclass
class ResultMessage:
    """A record on ``PREFIX-done``. Bulk outputs stay off-broker (the paper
    ships structure batches via shared storage); ``result`` carries metrics and
    *references* (e.g. checkpoint paths)."""

    task_id: str
    agent_id: str
    result: dict = dataclasses.field(default_factory=dict)
    attempt: int = 0
    elapsed_s: float = 0.0
    ts: float = dataclasses.field(default_factory=time.time)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ResultMessage":
        return cls(
            task_id=d["task_id"],
            agent_id=d.get("agent_id", ""),
            result=dict(d.get("result", {})),
            attempt=int(d.get("attempt", 0)),
            elapsed_s=float(d.get("elapsed_s", 0.0)),
            ts=float(d.get("ts", time.time())),
        )


@dataclasses.dataclass
class ErrorMessage:
    """A record on ``PREFIX-error``."""

    task_id: str
    agent_id: str
    error: str
    traceback: str = ""
    attempt: int = 0
    ts: float = dataclasses.field(default_factory=time.time)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ErrorMessage":
        return cls(
            task_id=d["task_id"],
            agent_id=d.get("agent_id", ""),
            error=d.get("error", ""),
            traceback=d.get("traceback", ""),
            attempt=int(d.get("attempt", 0)),
            ts=float(d.get("ts", time.time())),
        )


@dataclasses.dataclass
class CampaignEvent:
    """A progress-snapshot record on ``PREFIX-campaigns``, published by a
    pipeline agent on every state transition. The MonitorAgent mirrors the
    latest snapshot per campaign into its ``/campaigns`` REST endpoint, so
    observability works across processes exactly like the paper's
    task-status flow (§3).

    The topic is shared with the write-ahead *journal* of typed campaign
    events (:mod:`repro.pipeline.state`); ``kind`` discriminates the two
    record families (journal records carry ``kind="journal"``).
    ``recovered`` marks snapshots published by an agent that rebuilt this
    campaign from the journal after a crash."""

    campaign_id: str
    pipeline: str
    state: str  # RUNNING | COMPLETED | FAILED
    agent_id: str = ""
    stages: dict = dataclasses.field(default_factory=dict)
    recovered: bool = False
    preemptions: int = 0  # fair-share lease revocations taken so far
    kind: str = "snapshot"
    ts: float = dataclasses.field(default_factory=time.time)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "CampaignEvent":
        return cls(
            campaign_id=d["campaign_id"],
            pipeline=d.get("pipeline", ""),
            state=str(d.get("state", "RUNNING")),
            agent_id=d.get("agent_id", ""),
            stages=dict(d.get("stages", {})),
            recovered=bool(d.get("recovered", False)),
            preemptions=int(d.get("preemptions", 0)),
            kind=str(d.get("kind", "snapshot")),
            ts=float(d.get("ts", time.time())),
        )


def new_task_id(prefix: str = "task") -> str:
    return f"{prefix}-{uuid.uuid4().hex[:12]}"
