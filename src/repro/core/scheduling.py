"""Resource-aware placement and lease scheduling for the KSA control plane.

The paper routes every task to every agent through one shared ``PREFIX-new``
consumer group (§3), which makes ``Resources.gpus`` decorative: any agent may
lease a GPU stage. ParaFold (arXiv:2111.06340) shows that the CPU/GPU stage
split is the key to AlphaFold-scale throughput, and the Summit deployment
(arXiv:2201.10024) shows ensemble workflows need placement-aware scheduling
rather than a flat task bag. This module makes placement a first-class,
pluggable concept:

* :class:`ResourceProfile` — what an *agent pool* can run (cpus, gpus, mem,
  labels, taints). Agents subscribe only to the per-resource-class topics
  (``PREFIX-new.<class>``) their profile can serve, so a GPU stage can never
  be leased by a CPU-only agent — it queues on the GPU class topic instead.
  ``mem_mb`` is an admission budget enforced at lease time, and ``taints``
  make a pool exclusive (k8s-style: a ``serve``-tainted pool refuses plain
  batch work unless the task tolerates the taint via
  ``Resources.tolerations``).
* :class:`PlacementPolicy` — maps tasks to class topics and profiles to
  subscriptions. :class:`ResourceClassPolicy` (the default) splits ``cpu`` /
  ``gpu`` plus arbitrary label classes; :class:`SingleTopicPolicy` reproduces
  the paper's flat shared topic (every agent sees every task) and is kept as
  the baseline for ``benchmarks/bench_routing.py``.
* :class:`LeasePolicy` — how multiple campaigns' ready tasks drain into
  ``-new`` capacity. :class:`FairShare` (smooth weighted round-robin keyed by
  ``campaign_id``) replaces the first-come FIFO contention;
  :class:`FifoLease` preserves the old strict arrival order.

The :class:`~repro.core.submitter.Submitter`, the agents, the
:class:`~repro.core.monitor.MonitorAgent`, and the
:class:`~repro.pipeline.agent.PipelineAgent` all take the same policy object
(usually wired once through :class:`repro.cluster.KsaCluster`).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Mapping

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .messages import Resources, TaskMessage


# --------------------------------------------------------------------------
# Agent-side capability declaration
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResourceProfile:
    """What one agent pool is equipped to run.

    ``cpus`` is a capacity hint (packing is enforced by slots / SimSlurm);
    ``mem_mb`` is the pool's admission budget — workers lease a task only
    while the sum of running requests fits it (mem-aware admission, the same
    packing SimSlurm applies per node for cpus/gpus); ``gpus`` and ``labels``
    are *routability* dimensions — they decide which resource-class topics
    the agent subscribes to, and :meth:`can_run` checks only those, so a task
    asking for more CPUs than one agent advertises still runs (slower), while
    a task asking for a GPU on a CPU-only pool never does.

    ``taints`` make a pool *exclusive*: a tainted pool subscribes only to the
    class topics its taints/labels name and refuses any task that neither
    carries the taint as a label nor tolerates it
    (``Resources.tolerations``) — e.g. a ``serve``-tainted pool never drains
    plain cpu batch work (the ROADMAP label-taint follow-on).
    """

    cpus: int = 1
    gpus: int = 0
    mem_mb: int = 1024
    labels: tuple[str, ...] = ()
    taints: tuple[str, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "labels", tuple(self.labels))
        object.__setattr__(self, "taints", tuple(self.taints))

    def can_run(self, res: "Resources") -> bool:
        """Routability check: GPU *capability*, labels, and taints. GPU
        count, like cpus/mem, is a capacity hint (SimSlurm packs it per
        node); what a CPU-only pool can never do is run a GPU task at all —
        and what a tainted pool must never do is run work that neither asks
        for nor tolerates the taint."""
        if res.gpus > 0 and self.gpus <= 0:
            return False
        if not set(res.labels) <= set(self.labels):
            return False
        accepted = set(res.labels) | set(res.tolerations)
        return set(self.taints) <= accepted

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["labels"] = list(self.labels)
        d["taints"] = list(self.taints)
        return d


# --------------------------------------------------------------------------
# Placement: task -> class topic, profile -> subscriptions
# --------------------------------------------------------------------------


def class_topic(prefix: str, cls: str) -> str:
    """The per-resource-class task topic, ``PREFIX-new.<class>``."""
    return f"{prefix}-new.{cls}"


class PlacementPolicy:
    """Pluggable task-routing strategy.

    Implementations answer three questions for one broker ``prefix``:
    which task topics exist (:meth:`topics`), which topic one task goes to
    (:meth:`route`), and which topics one agent profile consumes
    (:meth:`subscriptions`).
    """

    def topics(self, prefix: str) -> tuple[str, ...]:
        raise NotImplementedError

    def route(self, prefix: str, task: "TaskMessage") -> str:
        raise NotImplementedError

    def subscriptions(self, prefix: str,
                      profile: ResourceProfile | None) -> tuple[str, ...]:
        raise NotImplementedError


class ResourceClassPolicy(PlacementPolicy):
    """Default policy: per-resource-class topics ``cpu`` / ``gpu`` plus any
    ``extra_classes`` (label-routed pools, e.g. ``bigmem``).

    Routing: a task labelled with a known class goes to that class; else
    ``gpus > 0`` routes to ``gpu``, everything else to ``cpu`` (the ParaFold
    featurize/predict split). Subscriptions: ``profile=None`` means a legacy
    universal agent (subscribes to every class — the paper's behaviour);
    GPU-capable profiles serve ``gpu`` and, when ``gpu_takes_cpu`` (default),
    also drain ``cpu`` work when idle (work conservation); CPU-only profiles
    serve ``cpu`` alone, which is what makes GPU tasks queue rather than
    misroute when the GPU pool is saturated.
    """

    def __init__(self, extra_classes: tuple[str, ...] = (), *,
                 gpu_takes_cpu: bool = True):
        self.extra_classes = tuple(extra_classes)
        self.gpu_takes_cpu = gpu_takes_cpu
        self._classes = ("cpu", "gpu") + self.extra_classes

    def classes(self) -> tuple[str, ...]:
        return self._classes

    def classify(self, task: "TaskMessage") -> str:
        res = task.resources
        if res.labels:
            for lb in res.labels:
                if lb in self._classes:
                    return lb
            # a label names a pool; silently routing a bigmem task to the
            # plain cpu class would execute it on hardware it asked to avoid
            raise ValueError(
                f"task {task.task_id}: labels {list(res.labels)} name no "
                f"resource class (known: {list(self._classes)}); declare "
                f"them via ResourceClassPolicy(extra_classes=...)")
        # a gpu demand always wins — a toleration is permission, not a
        # demand, and must never land a GPU task on whatever hardware backs
        # the tolerated pool
        if res.gpus > 0:
            return "gpu"
        # route tolerating cpu work to the tolerated (usually tainted) class
        # so that pool *can* serve it; unknown tolerations simply fall
        # through to the default class.
        for tl in res.tolerations:
            if tl in self._classes:
                return tl
        return "cpu"

    def topics(self, prefix: str) -> tuple[str, ...]:
        return tuple(class_topic(prefix, c) for c in self._classes)

    def route(self, prefix: str, task: "TaskMessage") -> str:
        return class_topic(prefix, self.classify(task))

    def subscriptions(self, prefix: str,
                      profile: ResourceProfile | None) -> tuple[str, ...]:
        if profile is None:
            return self.topics(prefix)
        if profile.taints:
            # exclusive pool: only the class topics its taints/labels name —
            # a serve-tainted agent never even subscribes to the plain cpu
            # class, so it cannot drain untolerated batch work.
            keep = set(profile.labels) | set(profile.taints)
            topics = tuple(class_topic(prefix, c) for c in self._classes
                           if c in keep)
            if not topics:
                # same fail-fast contract as classify() for unknown labels:
                # a silently idle worker is a misconfiguration, not a pool
                raise ValueError(
                    f"profile taints {list(profile.taints)} name no "
                    f"resource class (known: {list(self._classes)}); "
                    f"declare them via ResourceClassPolicy(extra_classes=...)")
            return topics
        classes: list[str] = []
        if profile.gpus > 0:
            classes.append("gpu")
            if self.gpu_takes_cpu:
                classes.append("cpu")
        else:
            classes.append("cpu")
        classes += [lb for lb in profile.labels
                    if lb in self._classes and lb not in classes]
        return tuple(class_topic(prefix, c) for c in classes)


class SingleTopicPolicy(PlacementPolicy):
    """The paper's flat design: one shared ``PREFIX-new`` topic, every agent
    load-balances every task. Kept for comparison benchmarks and drop-in
    compatibility with external producers that write to the bare topic."""

    def topics(self, prefix: str) -> tuple[str, ...]:
        return (f"{prefix}-new",)

    def route(self, prefix: str, task: "TaskMessage") -> str:
        return f"{prefix}-new"

    def subscriptions(self, prefix: str,
                      profile: ResourceProfile | None) -> tuple[str, ...]:
        return self.topics(prefix)


# --------------------------------------------------------------------------
# Lease scheduling: which campaign's ready tasks drain next
# --------------------------------------------------------------------------


class LeasePolicy:
    """Picks which campaign submits its next ready task when several compete
    for ``-new`` capacity. ``candidates`` maps campaign_id -> weight for
    every campaign that has a submittable ready task right now."""

    def select(self, candidates: Mapping[str, float]) -> str:
        raise NotImplementedError

    def preempt(self, shares: Mapping[str, tuple[float, int, bool, bool]]
                ) -> str | None:
        """The preemption hook: name the campaign whose longest-running
        lease should be revoked (and requeued) to make room, or ``None``
        to leave everything running. ``shares`` maps campaign_id ->
        ``(weight, in_flight, has_ready_waiting, preemptible)`` over the
        live campaigns — fairness is judged over all of them, but only a
        ``preemptible`` campaign (one with ``RetryPolicy.max_preemptions``
        budget left) may be named. Submission-time arbitration alone
        cannot reclaim a slot a long-running task already holds — this
        hook can. Default: never preempt (``FifoLease`` keeps the paper's
        run-to-completion behaviour)."""
        return None

    def forget(self, campaign_id: str) -> None:
        """Drop any per-campaign state (campaign finished/evicted)."""


class FifoLease(LeasePolicy):
    """Strict arrival order: the earliest-registered campaign with ready work
    drains first — the paper's first-come contention, kept as the baseline."""

    def select(self, candidates: Mapping[str, float]) -> str:
        return next(iter(candidates))


class FairShare(LeasePolicy):
    """Smooth weighted round-robin over campaigns (nginx's swrr): each pick,
    every candidate's credit grows by its weight; the max-credit candidate is
    picked and pays the total weight back. Weights 3:1 yield the interleaving
    A A B A, A A B A, ... — task completions track the weight ratio instead
    of first-come-first-served campaign ordering.

    **Preemptive** fair share: when some campaign is *severely* over its
    share — holding more than ``preempt_factor`` times its weighted slice of
    the total in-flight leases — while another campaign with ready work sits
    below its own slice, :meth:`preempt` names the over-share campaign; the
    PipelineAgent then revokes its longest-running lease
    (``Broker.revoke_lease(reason="preempt")``, journaled as
    ``LeaseRevoked``) and the freed capacity drains through the normal
    weighted round-robin. Bounded per campaign by
    ``RetryPolicy.max_preemptions``."""

    def __init__(self, preempt_factor: float = 2.0) -> None:
        if not (preempt_factor > 1.0):
            raise ValueError(
                f"preempt_factor must exceed 1.0 (got {preempt_factor!r}); "
                f"at 1.0 every campaign at exactly its fair share would be "
                f"preempted")
        self.preempt_factor = preempt_factor
        self._credit: dict[str, float] = {}

    def select(self, candidates: Mapping[str, float]) -> str:
        total = sum(candidates.values())
        best: str | None = None
        for cid, weight in candidates.items():
            credit = self._credit.get(cid, 0.0) + weight
            self._credit[cid] = credit
            if best is None or credit > self._credit[best]:
                best = cid
        assert best is not None, "select() called with no candidates"
        self._credit[best] -= total
        return best

    def preempt(self, shares: Mapping[str, tuple[float, int, bool, bool]]
                ) -> str | None:
        total_w = sum(w for w, _, _, _ in shares.values())
        total_in = sum(f for _, f, _, _ in shares.values())
        if total_w <= 0 or total_in <= 0:
            return None
        fair = {cid: w / total_w * total_in
                for cid, (w, _, _, _) in shares.items()}
        # someone must actually be starved: ready work waiting while the
        # campaign sits below its slice — otherwise a lone campaign using
        # the whole pool is work conservation, not unfairness
        if not any(ready and f < fair[cid]
                   for cid, (_, f, ready, _) in shares.items()):
            return None
        # fairness is computed over every campaign, but only a preemptible
        # one may pay — an opted-out hog must not shield a lesser (but
        # still severely over-share) opted-in peer from preemption
        worst, worst_ratio = None, self.preempt_factor
        for cid, (_, f, _, preemptible) in shares.items():
            if f <= 0 or not preemptible:
                continue
            ratio = f / max(fair[cid], 1e-9)
            if ratio > worst_ratio:
                worst, worst_ratio = cid, ratio
        return worst

    def forget(self, campaign_id: str) -> None:
        self._credit.pop(campaign_id, None)
