"""MonitorAgent — results collection, status tracking, watchdog, REST API.

Paper §3: "The MonitorAgent is, in fact, an optional component. Its main role
is to collect the results sent by each ClusterAgent and WorkerAgent upon task
completion. It also monitors the status of each submitted task, including
managing error messages through a separate flow with a designated topic. To
simplify user interaction, the MonitorAgent provides a web-based REST API."

Beyond the paper's baseline we implement the extension it names (§3): safe
handling of multiple concurrent attempts of the same task. Results are
**deduplicated and attempt-fenced** — the first DONE for a task wins, stale
attempts are recorded but ignored — which is what makes the watchdog's
resubmission (straggler mitigation) safe, i.e. exactly-once *effect* on top of
at-least-once delivery.
"""
from __future__ import annotations

import json
import logging
import threading
import time
from dataclasses import dataclass, field
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any

from .broker import Broker, Consumer, Producer
from .lease import RevokeReason
from .messages import (CampaignEvent, ErrorMessage, ResultMessage,
                       StatusUpdate, TaskMessage, TaskStatus, topic_names)
from .scheduling import PlacementPolicy, ResourceClassPolicy
from .submitter import Submitter

log = logging.getLogger(__name__)

# Every endpoint the REST API serves, as advertised on ``GET /`` and in 404
# payloads. tests/test_obs.py lint-checks that the do_GET dispatch below
# never grows a route that is missing from this index.
ROUTES = (
    "/",
    "/tasks",
    "/tasks/<id>",
    "/campaigns",
    "/campaigns/<id>",
    "/summary",
    "/broker",
    "/autoscale",
    "/sites",
    "/metrics",
    "/trace/<task_id>",
    "/query",
    "/alerts",
    "/blackbox",
)

# /query accepts these aggregations (validated before hitting the store so
# a bad request is a structured 400, not a 500)
_QUERY_AGGS = ("latest", "rate", "quantile", "sum_by", "sum", "points")


@dataclass
class TaskEntry:
    task: TaskMessage | None = None
    status: str = TaskStatus.SUBMITTED.value
    attempt: int = 0
    agent_id: str = ""
    last_update: float = field(default_factory=time.time)
    result: dict | None = None
    result_attempt: int | None = None
    errors: list[dict] = field(default_factory=list)
    attempts_seen: int = 0
    duplicate_results: int = 0
    history: list[tuple[float, str, int]] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.result is not None

    def to_dict(self) -> dict:
        return {
            "status": self.status,
            "attempt": self.attempt,
            "agent_id": self.agent_id,
            "last_update": self.last_update,
            "done": self.done,
            "result": self.result,
            "result_attempt": self.result_attempt,
            "errors": self.errors[-3:],
            "duplicate_results": self.duplicate_results,
        }


class MonitorAgent:
    """Consumes ``jobs``/``done``/``error`` (and ``new``, to learn task
    definitions for resubmission) and maintains the task table.

    ``group_id`` semantics follow the paper: give each monitor its own group
    to broadcast every record to every monitor; share a group to load-balance
    result handling across monitors.
    """

    def __init__(self, broker: Broker, prefix: str = "ksa", *,
                 monitor_id: str = "monitor-0",
                 group_id: str | None = None,
                 task_timeout_s: float | None = None,
                 max_attempts: int = 3,
                 retry_on_error: bool = True,
                 retry_on_timeout: bool = True,
                 resubmit_campaign_tasks: bool = False,
                 placement: PlacementPolicy | None = None,
                 poll_interval_s: float = 0.05):
        self.broker = broker
        self.prefix = prefix
        self.topics = topic_names(prefix)
        self.monitor_id = monitor_id
        self.task_timeout_s = task_timeout_s
        self.max_attempts = max_attempts
        self.retry_on_error = retry_on_error
        self.retry_on_timeout = retry_on_timeout
        # pipeline-tagged tasks are retried by their PipelineAgent (which
        # enforces the stage RetryPolicy); a monitor resubmitting them too
        # would double every attempt. Opt in only for monitor-only setups.
        self.resubmit_campaign_tasks = resubmit_campaign_tasks
        self.placement = placement or ResourceClassPolicy()
        self.poll_interval_s = poll_interval_s
        self._submitter = Submitter(broker, prefix, placement=self.placement)
        gid = group_id or f"{prefix}-monitor-{monitor_id}"
        # task definitions (needed for watchdog resubmission) now live on the
        # per-resource-class topics; subscribe to all of them plus the bare
        # `-new` topic so flat/SingleTopicPolicy producers are seen too.
        task_topics = list(self.placement.topics(prefix))
        if self.topics["new"] not in task_topics:
            task_topics.append(self.topics["new"])
        self._consumer = Consumer(
            broker,
            [*task_topics, self.topics["jobs"], self.topics["done"],
             self.topics["error"], self.topics["campaigns"]],
            group_id=gid, member_id=f"{gid}-{monitor_id}")
        self._producer = Producer(broker)
        self._table: dict[str, TaskEntry] = {}
        # latest CampaignEvent snapshot per campaign (repro.pipeline agents
        # publish these on PREFIX-campaigns; mirrored into /campaigns).
        self._campaigns: dict[str, dict] = {}
        # per-campaign journal tallies (the same topic carries the pipeline
        # agents' write-ahead event journal; the monitor does not fold it —
        # it surfaces durability/recovery status alongside the snapshots).
        self._journal: dict[str, dict] = {}
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._http: ThreadingHTTPServer | None = None
        # optional autoscale status source (wired by KsaCluster when an
        # AutoscaleController runs): a zero-arg callable returning the
        # /autoscale payload — per-pool membership, backlog history, and
        # the scaling decision log.
        self._autoscale_source: Any = None
        # federation attachments: /sites payload + federated /metrics text
        self._federation_source: Any = None
        self._federation_metrics: Any = None
        # telemetry plane attachments (attach_telemetry): the collector is
        # polled (and the alert engine evaluated) from the monitor loop;
        # /query, /alerts and /blackbox serve from them.
        self._telemetry_collector: Any = None
        self._alert_engine: Any = None
        self._telemetry_interval_s = 0.25
        self._next_telemetry = 0.0
        # scheduled journal compaction (attach_compaction): a periodic /
        # event-count trigger that invokes the pipeline's compact() from
        # this loop so operators never have to remember the maintenance.
        self._compact_cb: Any = None
        self._compact_interval_s: float | None = None
        self._compact_every_events: int | None = None
        self._last_compact = time.time()
        self._events_at_compact = 0
        # counters live in the broker's obs registry (one labeled family);
        # the bare attribute names below are read-only property views
        events = broker.metrics.counter(
            "ksa_monitor_events_total",
            "Per-monitor ingestion/watchdog events",
            labels=("monitor", "event"))
        self._c = {e: events.labels(monitor=monitor_id, event=e)
                   for e in ("results_handled", "resubmissions",
                             "revocations", "compactions", "legacy_forwards")}
        self._h_commit = broker.metrics.histogram(
            "ksa_result_commit_seconds",
            "Result publish -> monitor ingestion (commit) latency, "
            "per resource class", labels=("cls",))
        # per-class histogram children, interned once instead of a
        # labels() dict round trip per ingested result
        self._h_commit_cls: dict = {}
        # eviction sweeps take every group's lock; once per second is
        # plenty against the default multi-second session timeout —
        # sweeping at the 5ms poll tick just adds group-lock traffic. The
        # sweep quantum must stay a small fraction of the session timeout,
        # though: records stranded in a dead member's partitions are only
        # releasable after eviction, and every extra watchdog period they
        # stay stranded burns a resubmit out of the attempt budget.
        self._evict_interval_s = min(1.0, broker.session_timeout_s / 8.0)
        self._next_evict = 0.0

    # -- counter views (registry-backed; names predate repro.obs) ----------

    @property
    def results_handled(self) -> int:
        return self._c["results_handled"].value

    @property
    def resubmissions(self) -> int:
        return self._c["resubmissions"].value

    @property
    def revocations(self) -> int:
        return self._c["revocations"].value

    @property
    def compactions(self) -> int:
        return self._c["compactions"].value

    @property
    def legacy_forwards(self) -> int:
        return self._c["legacy_forwards"].value

    def _task_class(self, task: TaskMessage | None) -> str:
        if task is None:
            return "flat"
        classify = getattr(self.placement, "classify", None)
        if classify is None:
            return "flat"
        try:
            return classify(task)
        except ValueError:
            return "flat"

    # -- ingestion --------------------------------------------------------------

    def _entry(self, task_id: str) -> TaskEntry:
        e = self._table.get(task_id)
        if e is None:
            e = TaskEntry()
            self._table[task_id] = e
        return e

    def _ingest(self, topic: str, value: dict) -> None:
        with self._lock:
            if topic == self.topics["new"] or \
                    topic.startswith(self.topics["new"] + "."):
                task = TaskMessage.from_dict(value)
                e = self._entry(task.task_id)
                e.task = task
                e.attempts_seen = max(e.attempts_seen, task.attempt + 1)
                # a resubmission supersedes older attempts
                if task.attempt >= e.attempt and not e.done:
                    e.attempt = task.attempt
                    e.status = TaskStatus.SUBMITTED.value
                    e.last_update = time.time()
                if topic == self.topics["new"] and not e.done:
                    # legacy/flat producer wrote to the bare `-new` topic,
                    # which no agent consumes under a class-routing policy —
                    # forward onto the class topic so the task actually runs
                    # (not a resubmission: same attempt, just re-addressed).
                    try:
                        target = self.placement.route(self.prefix, task)
                    except ValueError:
                        log.warning("task %s on %s is unroutable; leaving "
                                    "for the watchdog", task.task_id, topic)
                    else:
                        if target != topic:
                            now = time.time()
                            self.broker.spans.add(
                                task.task_id, "route", now, now,
                                attempt=task.attempt,
                                monitor=self.monitor_id, target=target)
                            self._producer.send(target, task.to_dict(),
                                                key=task.task_id)
                            self._c["legacy_forwards"].inc()
            elif topic == self.topics["jobs"]:
                upd = StatusUpdate.from_dict(value)
                e = self._entry(upd.task_id)
                e.history.append((upd.ts, upd.status, upd.attempt))
                if e.done:
                    return  # terminal result already accepted
                if upd.attempt < e.attempt:
                    return  # fenced: stale attempt
                e.attempt = upd.attempt
                e.status = upd.status
                e.agent_id = upd.agent_id or e.agent_id
                e.last_update = time.time()
            elif topic == self.topics["done"]:
                res = ResultMessage.from_dict(value)
                e = self._entry(res.task_id)
                if e.done:
                    e.duplicate_results += 1  # fenced duplicate (late attempt)
                    return
                e.result = res.result
                e.result_attempt = res.attempt
                e.status = TaskStatus.DONE.value
                e.agent_id = res.agent_id
                now = time.time()
                e.last_update = now
                self._c["results_handled"].inc()
                # commit span: result published -> accepted here (terminal)
                cls = self._task_class(e.task)
                h = self._h_commit_cls.get(cls)
                if h is None:
                    h = self._h_commit_cls[cls] = self._h_commit.labels(
                        cls=cls)
                h.observe(max(0.0, now - res.ts))
                self.broker.spans.add(res.task_id, "commit", res.ts, now,
                                      attempt=res.attempt,
                                      agent=res.agent_id,
                                      monitor=self.monitor_id)
            elif topic == self.topics["campaigns"]:
                if value.get("kind") == "journal":
                    # a write-ahead journal event (repro.pipeline.state):
                    # tally it for the /campaigns recovery status instead of
                    # parsing it as a progress snapshot
                    cid = value.get("campaign_id", "")
                    j = self._journal.setdefault(
                        cid, {"events": 0, "last_seq": -1, "last_type": ""})
                    j["events"] += 1
                    seq = int(value.get("seq", -1))
                    if seq >= j["last_seq"]:
                        j["last_seq"] = seq
                        j["last_type"] = str(value.get("type", ""))
                    return
                ev = CampaignEvent.from_dict(value)
                prev = self._campaigns.get(ev.campaign_id)
                if prev is None or ev.ts >= prev.get("ts", 0.0):
                    self._campaigns[ev.campaign_id] = ev.to_dict()
            elif topic == self.topics["error"]:
                err = ErrorMessage.from_dict(value)
                e = self._entry(err.task_id)
                e.errors.append({"error": err.error, "attempt": err.attempt,
                                 "agent_id": err.agent_id})
                e.last_update = time.time()
                if not e.done and err.attempt >= e.attempt:
                    e.status = TaskStatus.ERROR.value
                    self._maybe_resubmit(e, reason="error")

    # -- watchdog / straggler mitigation --------------------------------------------

    def _maybe_resubmit(self, e: TaskEntry, reason: str) -> None:
        if e.task is None or e.done:
            return
        if e.task.campaign_id and not self.resubmit_campaign_tasks:
            return  # the owning PipelineAgent handles campaign-task retries
        if reason == "error" and not self.retry_on_error:
            return
        if reason in ("timeout", "stale") and not self.retry_on_timeout:
            return
        if e.attempts_seen >= self.max_attempts:
            log.warning("task %s exhausted %d attempts (%s)",
                        e.task.task_id, e.attempts_seen, reason)
            return
        # unified stop-path: if a live lease exists (a stale holder is — or
        # was — still on the hook for the task, e.g. a crashed agent that
        # never heartbeats again), Broker.revoke_lease cancels it, fences
        # its late verdict, and requeues the record in one atomic step.
        # Only when there is nothing to revoke (never leased, or the
        # agent-side watchdog already revoked and deliberately left the
        # requeue decision here) does the monitor produce a fresh attempt.
        lease = self.broker.lease_view(e.task.task_id)
        if lease is not None and lease["attempt"] > e.attempt:
            # a newer attempt than this table knows is already leased —
            # the requeue beat our ingestion; revoking (or resubmitting)
            # now would kill or duplicate healthy work. Let it run.
            e.last_update = time.time()
            return
        if reason != "error" and \
                self.broker.revoke_lease(e.task.task_id,
                                         RevokeReason.WATCHDOG):
            self._c["revocations"].inc()
            e.attempts_seen += 1
            # e.attempt is refreshed when the requeued record is ingested
            # (same attempt for a never-started lease, +1 for a running one)
            e.status = TaskStatus.SUBMITTED.value
            e.last_update = time.time()
            log.info("revoked lease of %s (reason=%s)", e.task.task_id,
                     reason)
            return
        nxt = TaskMessage.from_dict(e.task.to_dict())
        nxt.attempt = e.attempt
        self._submitter.resubmit(nxt)
        e.attempts_seen += 1
        e.attempt = nxt.attempt + 1
        e.status = TaskStatus.SUBMITTED.value
        e.last_update = time.time()
        self._c["resubmissions"].inc()
        log.info("resubmitted %s (attempt %d, reason=%s)",
                 e.task.task_id, e.attempt, reason)

    def _watchdog(self) -> None:
        if self.task_timeout_s is None:
            return
        now = time.time()
        with self._lock:
            for tid, e in self._table.items():
                if e.done or e.task is None:
                    continue
                if e.status in (TaskStatus.SUBMITTED.value,
                                TaskStatus.WAITING.value,
                                TaskStatus.RUNNING.value,
                                TaskStatus.TIMEOUT.value,
                                TaskStatus.CANCELLED.value,
                                TaskStatus.REVOKED.value):
                    # CANCELLED-without-result means the work did not finish
                    # (graceful agent shutdown mid-task) — recover it too.
                    # REVOKED normally supersedes itself (the revoker's
                    # requeued record arrives and resets the entry to
                    # SUBMITTED); one going *stale* means that redelivery
                    # never happened — _maybe_resubmit's newer-lease guard
                    # keeps this from duplicating a healthy requeue.
                    stale_for = now - e.last_update
                    deadline = self.task_timeout_s
                    if e.status == TaskStatus.SUBMITTED.value:
                        # no agent has accepted the record yet: it may be
                        # stranded in a dead member's partitions, which the
                        # broker only reassigns at session expiry. Waiting
                        # out that delivery horizon before resubmitting
                        # keeps the attempt budget for *executed* attempts
                        # instead of burning it on duplicates of a record
                        # that was never deliverable in the first place.
                        deadline += self.broker.session_timeout_s
                    if e.status == TaskStatus.TIMEOUT.value:
                        self._maybe_resubmit(e, reason="timeout")
                    elif stale_for > deadline and \
                            stale_for > self._deadline_for(e.task.task_id):
                        self._maybe_resubmit(e, reason="timeout")

    def _deadline_for(self, task_id: str) -> float:
        """The staleness deadline for one task: the uniform
        ``task_timeout_s`` unless the task's lease is stamped with a
        per-site WAN-tolerant deadline (a federation bridge holds it across
        a slow link — see :class:`~repro.core.lease.LeaseTolerance`), in
        which case the stamped deadline wins. Never *tighter* than the
        uniform one: a remote site with a fast link still gets the
        configured grace. Only consulted for tasks the uniform check has
        already flagged stale, so the lease lookup is off the common
        path."""
        base = self.task_timeout_s
        lease = self.broker.lease_view(task_id)
        if lease is None:
            return base
        deadline = lease.get("deadline_s")
        if deadline is None:
            return base
        return max(base, deadline)

    # -- main loop -----------------------------------------------------------------

    def start(self) -> "MonitorAgent":
        self._thread = threading.Thread(target=self._loop,
                                        name=f"{self.monitor_id}-loop",
                                        daemon=True)
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                batches = self._consumer.poll(timeout=self.poll_interval_s)
                for tp, recs in batches.items():
                    for rec in recs:
                        self._ingest(tp.topic, rec.value)
                if batches:
                    self._consumer.commit()
                self._watchdog()
                self._maybe_compact()
                self._telemetry_tick()
                now = time.time()
                if now >= self._next_evict:
                    self._next_evict = now + self._evict_interval_s
                    self.broker.evict_expired_members()
            except Exception:  # pragma: no cover - defensive
                log.exception("monitor %s loop error", self.monitor_id)
                time.sleep(self.poll_interval_s)
        self._consumer.close()

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
        self.stop_http()

    # -- queries ----------------------------------------------------------------------

    def task(self, task_id: str) -> TaskEntry | None:
        with self._lock:
            return self._table.get(task_id)

    def tasks(self) -> dict[str, TaskEntry]:
        with self._lock:
            return dict(self._table)

    def pending(self) -> list[str]:
        with self._lock:
            return [t for t, e in self._table.items() if not e.done]

    def all_done(self, task_ids: list[str] | None = None) -> bool:
        with self._lock:
            ids = task_ids if task_ids is not None else list(self._table)
            return all(self._table.get(t) is not None and self._table[t].done
                       for t in ids)

    def wait_all(self, task_ids: list[str], timeout: float = 60.0,
                 poll: float = 0.02) -> bool:
        deadline = time.time() + timeout
        while time.time() < deadline:
            if self.all_done(task_ids):
                return True
            time.sleep(poll)
        return False

    def attach_autoscale(self, source: Any) -> None:
        """Register the autoscaler's status callable; served on
        ``GET /autoscale`` (and detachable with ``None``)."""
        with self._lock:
            self._autoscale_source = source

    def attach_federation(self, sites: Any, metrics: Any = None) -> None:
        """Register a :class:`~repro.federation.FederatedCluster`'s status
        callables: ``sites()`` → the ``GET /sites`` payload (per-site
        queues, leases, links, spillover state), ``metrics()`` → the
        federated Prometheus exposition (every per-site registry merged
        with a ``site`` label) which then replaces the local registry on
        ``GET /metrics``. Detach with ``attach_federation(None)``."""
        with self._lock:
            self._federation_source = sites
            self._federation_metrics = metrics

    def sites(self) -> dict | None:
        with self._lock:
            source = self._federation_source
        return None if source is None else source()

    def metrics_text(self) -> str:
        """The ``/metrics`` exposition: federated (site-labelled, merged
        across sites) when a federation is attached, the local registry's
        render otherwise."""
        with self._lock:
            fed = self._federation_metrics
        if fed is not None:
            return fed()
        return self.broker.metrics.render()

    # -- scheduled journal compaction (ROADMAP open item) -----------------------

    def attach_compaction(self, cb: Any, *, interval_s: float | None = None,
                          every_events: int | None = None) -> None:
        """Run ``cb()`` (normally ``KsaCluster``'s pipeline ``compact()``)
        from the monitor loop whenever ``interval_s`` has elapsed or
        ``every_events`` new journal records have been ingested since the
        last compaction — scheduled maintenance instead of an operator
        chore. ``cb`` returning a truthy value counts as a compaction
        (surfaced as ``compactions`` in ``/summary``); returning ``None``
        (e.g. no pipeline agent started yet) does not."""
        with self._lock:
            self._compact_cb = cb
            self._compact_interval_s = interval_s
            self._compact_every_events = every_events
            self._last_compact = time.time()
            self._events_at_compact = self._journal_events()

    def _journal_events(self) -> int:
        return sum(j["events"] for j in self._journal.values())

    def _maybe_compact(self) -> None:
        with self._lock:
            cb = self._compact_cb
            if cb is None:
                return
            now = time.time()
            events = self._journal_events()
            due = False
            if self._compact_interval_s is not None and \
                    now - self._last_compact >= self._compact_interval_s:
                due = True
            if self._compact_every_events is not None and \
                    events - self._events_at_compact >= \
                    self._compact_every_events:
                due = True
            if not due:
                return
            self._last_compact = now
            self._events_at_compact = events
        try:
            result = cb()
        except Exception:  # pragma: no cover - defensive
            log.exception("monitor %s: scheduled compaction failed",
                          self.monitor_id)
            return
        if result:
            with self._lock:
                self._c["compactions"].inc()
            log.info("monitor %s: scheduled compaction truncated %s records",
                     self.monitor_id, result.get("truncated", "?")
                     if isinstance(result, dict) else "?")

    def autoscale(self) -> dict | None:
        with self._lock:
            source = self._autoscale_source
        return None if source is None else source()

    # -- telemetry plane (ISSUE 9) ----------------------------------------------

    def attach_telemetry(self, collector: Any, engine: Any = None, *,
                         interval_s: float = 0.25) -> None:
        """Register the cluster's :class:`~repro.obs.TelemetryCollector`
        (and optionally its :class:`~repro.obs.AlertEngine`): the monitor
        loop polls the collector's feeds and evaluates the alert rules
        every ``interval_s``, and ``GET /query`` / ``GET /alerts`` serve
        from them. Detach with ``attach_telemetry(None)``."""
        with self._lock:
            self._telemetry_collector = collector
            self._alert_engine = engine
            self._telemetry_interval_s = interval_s
            self._next_telemetry = 0.0

    def _telemetry_tick(self) -> None:
        with self._lock:
            collector = self._telemetry_collector
            engine = self._alert_engine
            now = time.time()
            if collector is None or now < self._next_telemetry:
                return
            self._next_telemetry = now + self._telemetry_interval_s
        try:
            collector.poll()
            if engine is not None:
                engine.evaluate(now)
        except Exception:  # pragma: no cover - defensive
            log.exception("monitor %s telemetry tick failed",
                          self.monitor_id)

    def query(self, name: str, *, agg: str = "latest",
              labels: dict | None = None, window_s: float = 60.0,
              q: float | None = None, by: str | None = None) -> dict | None:
        """Run one :meth:`~repro.obs.TimeSeriesStore.query` against the
        attached collector's store (None when no telemetry is attached;
        ``ValueError`` propagates for malformed requests)."""
        with self._lock:
            collector = self._telemetry_collector
        if collector is None:
            return None
        return collector.store.query(name, agg=agg, labels=labels,
                                     window_s=window_s, q=q, by=by)

    def alerts(self) -> dict | None:
        """The ``GET /alerts`` payload (None without an alert engine)."""
        with self._lock:
            engine = self._alert_engine
        return None if engine is None else engine.status()

    def blackbox(self) -> dict:
        """The ``GET /blackbox`` payload: the broker flight recorder's
        recent events and retained post-mortem dumps."""
        return self.broker.blackbox.snapshot()

    def campaigns(self) -> dict[str, dict]:
        """Latest per-campaign progress snapshots (per-stage done/in-flight/
        failed counters published by pipeline agents), each annotated with
        its journal tally (``journal.events`` / ``last_seq`` / ``last_type``)
        and ``recovered`` flag — the recovery status served on
        ``/campaigns``. A campaign seen only through journal events (its
        orchestrator died before publishing a snapshot) still appears, with
        ``state="JOURNALED"``: durable, awaiting ``KsaCluster.recover()``."""
        with self._lock:
            out: dict[str, dict] = {}
            for cid in set(self._campaigns) | set(self._journal):
                snap = self._campaigns.get(cid)
                d = dict(snap) if snap is not None else {
                    "campaign_id": cid, "state": "JOURNALED"}
                if cid in self._journal:
                    d["journal"] = dict(self._journal[cid])
                out[cid] = d
            return out

    def campaign(self, campaign_id: str) -> dict | None:
        with self._lock:
            return self.campaigns().get(campaign_id)

    def summary(self) -> dict:
        with self._lock:
            by_status: dict[str, int] = {}
            for e in self._table.values():
                by_status[e.status] = by_status.get(e.status, 0) + 1
            return {
                "tasks": len(self._table),
                "done": sum(e.done for e in self._table.values()),
                "by_status": by_status,
                "results_handled": self.results_handled,
                "resubmissions": self.resubmissions,
                "revocations": self.revocations,
                "compactions": self.compactions,
                "legacy_forwards": self.legacy_forwards,
                "duplicates_fenced": sum(e.duplicate_results
                                         for e in self._table.values()),
                "campaigns": len(self._campaigns),
                "journal_events": sum(j["events"]
                                      for j in self._journal.values()),
            }

    # -- REST API (paper §3: "a web-based REST API") ------------------------------------

    def start_http(self, port: int = 0) -> int:
        mon = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *a: Any) -> None:  # quiet
                pass

            def _send(self, code: int, payload: Any) -> None:
                body = json.dumps(payload).encode()
                self.send_response(code)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def _send_text(self, code: int, body: str, content_type: str =
                           "text/plain; version=0.0.4; charset=utf-8") -> None:
                raw = body.encode()
                self.send_response(code)
                self.send_header("Content-Type", content_type)
                self.send_header("Content-Length", str(len(raw)))
                self.end_headers()
                self.wfile.write(raw)

            def do_GET(self) -> None:  # noqa: N802 - http.server API
                # any handler bug must surface as structured JSON, never
                # as a stack trace over a half-written response
                try:
                    self._route()
                except Exception as exc:  # pragma: no cover - defensive
                    log.exception("monitor %s: %s failed",
                                  mon.monitor_id, self.path)
                    try:
                        self._send(500, {"error": "internal error",
                                         "detail": str(exc)})
                    except Exception:
                        pass

            def _query_params(self) -> dict:
                """Parse /query parameters; raises ValueError with a
                user-facing message on anything malformed."""
                from urllib.parse import parse_qsl
                _, _, qs = self.path.partition("?")
                params = dict(parse_qsl(qs, keep_blank_values=True))
                name = params.pop("name", "")
                if not name:
                    raise ValueError("missing required parameter: name")
                agg = params.pop("agg", "latest")
                if agg not in _QUERY_AGGS:
                    raise ValueError(
                        f"unknown agg {agg!r} (one of {_QUERY_AGGS})")
                out: dict = {"name": name, "agg": agg}
                for key, cast in (("window_s", float), ("q", float)):
                    if key in params:
                        try:
                            out[key] = cast(params.pop(key))
                        except ValueError:
                            raise ValueError(
                                f"parameter {key} must be a number")
                if "by" in params:
                    out["by"] = params.pop("by")
                labels = {k[2:]: v for k, v in params.items()
                          if k.startswith("l.") and len(k) > 2}
                for k in list(params):
                    if k.startswith("l."):
                        params.pop(k)
                if params:
                    raise ValueError(
                        f"unknown parameters: {sorted(params)} (labels "
                        f"filter with l.<label>=<value>)")
                if labels:
                    out["labels"] = labels
                if agg == "quantile" and "q" not in out:
                    raise ValueError("agg=quantile requires q")
                if agg == "sum_by" and "by" not in out:
                    raise ValueError("agg=sum_by requires by=<label>")
                return out

            def _route(self) -> None:
                path, _, _ = self.path.partition("?")
                parts = [p for p in path.split("/") if p]
                if not parts:
                    self._send(200, {"service": "ksa-monitor",
                                     "monitor_id": mon.monitor_id,
                                     "endpoints": list(ROUTES)})
                elif parts == ["metrics"]:
                    self._send_text(200, mon.metrics_text())
                elif len(parts) == 2 and parts[0] == "trace":
                    spans = mon.broker.spans.trace(parts[1])
                    if not spans:
                        self._send(404, {"error": "no spans for task "
                                                  "(unknown, evicted, or "
                                                  "tracing disabled)"})
                    else:
                        self._send(200, {"task_id": parts[1],
                                         "spans": spans})
                elif parts == ["tasks"]:
                    with mon._lock:
                        self._send(200, {t: e.to_dict()
                                         for t, e in mon._table.items()})
                elif len(parts) == 2 and parts[0] == "tasks":
                    e = mon.task(parts[1])
                    if e is None:
                        self._send(404, {"error": "unknown task"})
                    else:
                        self._send(200, e.to_dict())
                elif parts == ["campaigns"]:
                    self._send(200, mon.campaigns())
                elif len(parts) == 2 and parts[0] == "campaigns":
                    c = mon.campaign(parts[1])
                    if c is None:
                        self._send(404, {"error": "unknown campaign"})
                    else:
                        self._send(200, c)
                elif parts == ["summary"]:
                    self._send(200, mon.summary())
                elif parts == ["broker"]:
                    self._send(200, mon.broker.stats())
                elif parts == ["autoscale"]:
                    payload = mon.autoscale()
                    if payload is None:
                        self._send(404, {"error": "no autoscaler attached"})
                    else:
                        self._send(200, payload)
                elif parts == ["sites"]:
                    payload = mon.sites()
                    if payload is None:
                        self._send(404, {"error": "no federation attached"})
                    else:
                        self._send(200, payload)
                elif parts == ["query"]:
                    try:
                        kw = self._query_params()
                    except ValueError as exc:
                        self._send(400, {"error": "bad query",
                                         "detail": str(exc)})
                        return
                    name = kw.pop("name")
                    try:
                        payload = mon.query(name, **kw)
                    except ValueError as exc:
                        self._send(400, {"error": "bad query",
                                         "detail": str(exc)})
                        return
                    if payload is None:
                        self._send(404, {"error": "no telemetry attached"})
                    else:
                        self._send(200, payload)
                elif parts == ["alerts"]:
                    payload = mon.alerts()
                    if payload is None:
                        self._send(404, {"error": "no alert engine "
                                                  "attached"})
                    else:
                        self._send(200, payload)
                elif parts == ["blackbox"]:
                    self._send(200, mon.blackbox())
                else:
                    self._send(404, {"error": "unknown endpoint",
                                     "endpoints": list(ROUTES)})

        self._http = ThreadingHTTPServer(("127.0.0.1", port), Handler)
        t = threading.Thread(target=self._http.serve_forever,
                             name=f"{self.monitor_id}-http", daemon=True)
        t.start()
        return self._http.server_address[1]

    def stop_http(self) -> None:
        if self._http is not None:
            self._http.shutdown()
            self._http.server_close()
            self._http = None
