"""ClusterAgent and WorkerAgent — the compute-side components of KSA (§3).

Both subscribe to the per-resource-class task topics their
:class:`~repro.core.scheduling.ResourceProfile` can serve (``PREFIX-new.cpu``,
``PREFIX-new.gpu``, ...) in one shared consumer group — the broker
load-balances each class across the agents equipped for it, so a GPU stage
can never land on a CPU-only pool (resource-aware routing; an agent with no
declared profile subscribes to every class, the paper's original
any-agent-any-task behaviour). They differ only in *where* they run the work:

* :class:`WorkerAgent` — "executes the retrieved tasks directly on the
  workstation where it is running, using separate threads for each task."
* :class:`ClusterAgent` — submits tasks as Slurm jobs and manages their
  execution, including the paper's queue-filling strategy: "always submit more
  tasks to Slurm than can be immediately started … This approach ensures that
  the Slurm queue always has tasks waiting, allowing Slurm to start subsequent
  tasks as soon as resources become available", and the watchdog: "If a task
  hangs or exceeds the predefined timeout, the ClusterAgent intervenes by
  canceling the associated Slurm job."

Fault-tolerance contract (two levels, matching the paper):

1. *lease-commit*: an agent commits its consumer offset when it has accepted
   (leased) a task. If the agent dies **before** accepting, the group
   rebalance hands the partition — and the unread task — to a surviving agent.
2. *watchdog redelivery*: if the agent dies (or the task hangs) **after**
   accepting, the MonitorAgent notices the missing heartbeat/timeout and
   resubmits the task with a bumped attempt (at-least-once end-to-end;
   the monitor fences duplicate results by attempt).

Planned removal is a third, loss-free path: :meth:`AgentBase.request_drain`
(the autoscaler's scale-down mechanism) leaves the consumer group so unread
partitions rebalance to survivors, requeues deferred leases back onto their
class topics, lets in-flight tasks finish (heartbeating throughout, so the
monitor never mistakes a draining agent for a dead one), and only then
stops — no task is lost and none is double-run.

Every stop-path above routes through the unified lease layer
(:mod:`repro.core.lease`): an accepted task holds a broker-tracked
:class:`~repro.core.lease.Lease` whose execution is started through
:meth:`~repro.core.broker.Broker.claim_start` (binding the cancel event),
committed through the :meth:`~repro.core.broker.Broker.complete_lease`
fence, and taken back through :meth:`~repro.core.broker.Broker.revoke_lease`
— the agent watchdog (``reason="watchdog"``), drain requeues
(``reason="drain"``), SimSlurm walltime/scancel policing
(``reason="scancel"``), and memory-overage policing
(``reason="mem_overage"``) are all callers of that one primitive, so a
revoked task is cancelled, its stale verdict fenced, and its record
requeued in one atomic broker operation.
"""
from __future__ import annotations

import logging
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any

from .broker import Broker, Consumer, Producer
from .computing import ClusterComputing, resolve_script
from .lease import RevokeReason
from .messages import (ErrorMessage, StatusUpdate, TaskMessage, TaskStatus,
                       topic_names)
from .scheduling import PlacementPolicy, ResourceClassPolicy, ResourceProfile
from .simslurm import SimSlurm

log = logging.getLogger(__name__)


class _AnyEvent:
    """Event-like view that is set when ANY of the underlying events is.

    Replaces the 10 ms ``_pump`` polling thread the ClusterAgent used to spin
    per Slurm job to merge its own cancel with scancel/walltime:
    ``is_set()`` composes the sources exactly and allocates no thread.
    ``set()`` fires the primary (agent-side) event.
    """

    def __init__(self, *events: threading.Event):
        self._events = tuple(events)

    def is_set(self) -> bool:
        return any(e.is_set() for e in self._events)

    def set(self) -> None:
        self._events[0].set()

    def wait(self, timeout: float | None = None) -> bool:
        deadline = None if timeout is None else time.time() + timeout
        while True:
            if self.is_set():
                return True
            chunk = 0.05
            if deadline is not None:
                remaining = deadline - time.time()
                if remaining <= 0:
                    return False
                chunk = min(chunk, remaining)
            self._events[0].wait(chunk)


@dataclass
class _Running:
    task: TaskMessage
    cancel: threading.Event
    thread: threading.Thread | None = None
    slurm_job_id: int | None = None
    started_at: float = field(default_factory=time.time)
    last_heartbeat: float = field(default_factory=time.time)
    computing: Any = None            # live ClusterComputing (mem sampling)
    mem_tolerated: bool = False      # over-budget but past the revoke limit


class AgentBase:
    """Shared polling/lease/watchdog loop."""

    kind = "agent"

    def __init__(self, broker: Broker, prefix: str = "ksa", *,
                 agent_id: str | None = None,
                 slots: int = 4,
                 oversubscribe: int = 0,
                 profile: ResourceProfile | None = None,
                 placement: PlacementPolicy | None = None,
                 poll_interval_s: float = 0.05,
                 heartbeat_interval_s: float = 0.5,
                 default_timeout_s: float | None = None,
                 max_revoke_requeues: int = 3):
        self.broker = broker
        self.prefix = prefix
        self.topics = topic_names(prefix)
        self.agent_id = agent_id or f"{self.kind}-{id(self) & 0xffff:04x}"
        self.slots = slots
        # paper's ClusterAgent strategy: keep `oversubscribe` extra tasks
        # queued beyond what can start immediately.
        self.oversubscribe = oversubscribe
        # placement: profile=None -> subscribe every class (universal agent);
        # an explicit profile narrows the subscription to the classes the
        # pool can actually serve (resource-aware routing).
        self.profile = profile
        self.placement = placement or ResourceClassPolicy()
        self.poll_interval_s = poll_interval_s
        self.heartbeat_interval_s = heartbeat_interval_s
        # saturated-poll group-heartbeat cadence: the configured interval,
        # but bounded well under the broker's session timeout — a busy
        # agent that only heartbeats at the nominal interval can slip past
        # expiry under scheduler load and get falsely evicted (its live
        # lease revoked + requeued out from under it)
        self._group_hb_interval_s = min(
            heartbeat_interval_s, broker.session_timeout_s / 4.0)
        self._last_group_heartbeat = 0.0
        self.default_timeout_s = default_timeout_s
        self._producer = Producer(broker)
        self._subscriptions = tuple(
            self.placement.subscriptions(prefix, self.profile))
        self._consumer = Consumer(broker, list(self._subscriptions),
                                  group_id=f"{prefix}-agents",
                                  member_id=f"{prefix}-agents-{self.agent_id}")
        self._running: dict[str, _Running] = {}
        # leased tasks waiting for admission (mem-aware lease gate): the
        # offset is committed — the task is ours — but execution starts only
        # once it fits the profile's mem budget (the WorkerAgent analogue of
        # a SimSlurm PD job waiting for a node with free memory).
        self._deferred: deque[TaskMessage] = deque()
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._crashed = threading.Event()  # test hook: simulate sudden death
        # graceful-drain lifecycle (autoscale scale-down path): stop leasing,
        # requeue deferred leases, let in-flight work finish, deregister.
        self._draining = threading.Event()
        self._drain_deadline: float | None = None
        self._drain_entered = False
        # revocation-requeue bound: past this many attempts, mem-overage
        # policing tolerates the task instead of revoke-looping it forever
        # (the same spirit as the oversized-task admission escape hatch).
        self.max_revoke_requeues = max_revoke_requeues
        # lifecycle counters live in the broker's obs registry as one
        # labeled family; the legacy ``tasks_*`` attributes below are
        # read-only views over the same children (see properties)
        events = broker.metrics.counter(
            "ksa_agent_events_total",
            "Per-agent task lifecycle events", labels=("agent", "event"))
        self._c = {e: events.labels(agent=self.agent_id, event=e)
                   for e in ("completed", "failed", "rerouted", "deferred",
                             "requeued", "revoked", "dropped_revoked",
                             "mem_revoked", "heartbeat_failures")}

    # -- counter views (registry-backed; names predate repro.obs) ----------

    @property
    def tasks_completed(self) -> int:
        return self._c["completed"].value

    @property
    def tasks_failed(self) -> int:
        return self._c["failed"].value

    @property
    def tasks_rerouted(self) -> int:
        return self._c["rerouted"].value

    @property
    def tasks_deferred(self) -> int:
        return self._c["deferred"].value

    @property
    def tasks_requeued(self) -> int:
        return self._c["requeued"].value

    @property
    def tasks_revoked(self) -> int:
        return self._c["revoked"].value

    @property
    def tasks_dropped_revoked(self) -> int:
        return self._c["dropped_revoked"].value

    @property
    def mem_revoked(self) -> int:
        return self._c["mem_revoked"].value

    @property
    def heartbeat_failures(self) -> int:
        return self._c["heartbeat_failures"].value

    # -- capacity -------------------------------------------------------------

    def _in_flight(self) -> int:
        with self._lock:
            return len(self._running)

    def _capacity(self) -> int:
        """How many more tasks to lease right now (deferred leases count —
        they already occupy a slot's worth of committed work)."""
        return (self.slots + self.oversubscribe) \
            - self._in_flight() - len(self._deferred)

    def _admit(self, task: TaskMessage) -> bool:
        """Lease-time admission gate; subclasses veto starting a task *now*
        (it stays leased in the deferral queue). Base: always admit."""
        return True

    def _admit_deferred(self) -> None:
        while self._deferred and self._admit(self._deferred[0]):
            self._accept(self._deferred.popleft())

    # -- main loop ----------------------------------------------------------------

    def start(self) -> "AgentBase":
        self._thread = threading.Thread(target=self._loop,
                                        name=f"{self.agent_id}-loop",
                                        daemon=True)
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set() and not self._crashed.is_set():
            try:
                if self._draining.is_set():
                    if self._drain_tick():
                        break
                else:
                    self._tick()
            except Exception:  # pragma: no cover - defensive
                log.exception("agent %s tick failed", self.agent_id)
            self._stop.wait(self.poll_interval_s)
        # crashed agents do NOT leave the group: the broker's session timeout
        # must evict them (that is the failure mode being simulated).
        if self._crashed.is_set():
            return
        # cancel whatever is still running so it gets redelivered — a no-op
        # after a completed graceful drain, and the stop() contract when
        # stop() overrides a drain still in progress
        self._drain()
        # either path: leased-but-unstarted tasks must survive the agent —
        # an offset this agent committed is a task nobody else will be given
        self._flush_deferred()
        self._consumer.close()

    def _tick(self) -> None:
        self._admit_deferred()
        cap = self._capacity()
        if cap > 0:
            # lease-commit (see module docstring) — fetch and commit are
            # one atomic broker operation, so a rebalance caused by a pool
            # scaling up mid-tick can never redeliver (and double-run) a
            # task this agent already leased
            for rec in self._consumer.lease(timeout=0.0, max_records=cap):
                task = TaskMessage.from_dict(rec.value)
                if not self._routable(task):
                    continue
                # FIFO behind an existing deferral: admitting fresh
                # leases past the queue head would starve a big task
                # under a stream of small ones
                if not self._deferred and self._admit(task):
                    self._accept(task)
                else:
                    self._deferred.append(task)
                    self._c["deferred"].inc()
        else:
            # still heartbeat group membership while saturated — but at the
            # (session-timeout-bounded) heartbeat interval, not per poll
            # tick: a 5ms tick hammering the group lock adds contention
            # for no extra liveness
            now = time.time()
            if now - self._last_group_heartbeat >= self._group_hb_interval_s:
                self._last_group_heartbeat = now
                try:
                    self.broker.heartbeat(f"{self.prefix}-agents",
                                          self._consumer.member_id)
                except Exception as exc:
                    self._c["heartbeat_failures"].inc()
                    log.debug("agent %s: broker heartbeat failed: %r",
                              self.agent_id, exc)
        self._watchdog()
        self._heartbeat_running()

    def _routable(self, task: TaskMessage) -> bool:
        """Defence against misrouted tasks (e.g. a producer using a different
        placement policy): a task this profile cannot run is bounced to its
        correct class topic instead of executing where it must not."""
        if self.profile is None or self.profile.can_run(task.resources):
            return True
        target = self.placement.route(self.prefix, task)
        if target in self._subscriptions:
            # rerouting would hand it straight back to us — run it rather
            # than loop (can only happen with an inconsistent policy).
            log.warning("agent %s: task %s is unroutable for profile %s — "
                        "executing anyway", self.agent_id, task.task_id,
                        self.profile)
            return True
        self._c["rerouted"].inc()
        log.warning("agent %s: rerouting misplaced task %s to %s",
                    self.agent_id, task.task_id, target)
        # give the lease up without a verdict: the rerouted record grants a
        # fresh one to whichever equipped agent leases it
        self.broker.forget_lease(task.task_id, self._consumer.member_id)
        now = time.time()
        self.broker.spans.add(task.task_id, "route", now, now,
                              attempt=task.attempt, agent=self.agent_id,
                              target=target)
        self._producer.send(target, task.to_dict(), key=task.task_id)
        return False

    # -- acceptance (subclass hook) --------------------------------------------

    def _accept(self, task: TaskMessage) -> None:
        raise NotImplementedError

    def _send_status(self, task: TaskMessage, status: TaskStatus | str,
                     **info: Any) -> None:
        upd = StatusUpdate(task_id=task.task_id,
                           status=str(getattr(status, "value", status)),
                           agent_id=self.agent_id, attempt=task.attempt,
                           info=info)
        self._producer.send(self.topics["jobs"], upd.to_dict(),
                            key=task.task_id)

    # -- watchdog (paper §3: cancel hung / timed-out tasks) -----------------------

    def _revoke_run(self, run: _Running, reason: str, *,
                    requeue: bool) -> bool:
        """Route one in-flight task through the unified reclamation
        primitive (:meth:`Broker.revoke_lease`): cancel + commit fence
        (+ requeue). False when no live lease exists — caller falls back to
        the plain cancel_event (legacy direct-wired agents)."""
        if not self.broker.revoke_lease(run.task.task_id, reason,
                                        requeue=requeue):
            return False
        self._c["revoked"].inc()
        return True

    def _watchdog(self) -> None:
        now = time.time()
        with self._lock:
            items = list(self._running.items())
        for tid, run in items:
            timeout = run.task.timeout_s or self.default_timeout_s
            if timeout is None:
                continue
            if now - run.started_at > timeout and not run.cancel.is_set():
                log.warning("agent %s: task %s exceeded %.1fs — revoking",
                            self.agent_id, tid, timeout)
                # revoke without requeue: the TIMEOUT status keeps the
                # redelivery *decision* where the attempt budget lives (the
                # MonitorAgent for flat tasks, the PipelineAgent's
                # RetryPolicy for campaign tasks); the revocation itself
                # fences this attempt's late verdict either way.
                if not self._revoke_run(run, RevokeReason.WATCHDOG,
                                        requeue=False):
                    self._cancel_task(run)
                self._send_status(run.task, TaskStatus.TIMEOUT,
                                  timeout_s=timeout)
        self._police_mem(items)

    def _police_mem(self, items: list[tuple[str, _Running]]) -> None:
        """Mem-overage policing: sample each running task's resident memory
        — kernel-accounted RSS growth by default, the task's
        ``report_mem()`` value when it self-reports (see
        :attr:`ClusterComputing.mem_used_mb`) — against its
        ``Resources.mem_mb`` request and revoke over-budget
        leases (admission packs requests; this polices *usage*). Flat tasks
        are requeued with a bumped attempt up to ``max_revoke_requeues``,
        then tolerated (mirroring the oversized-task admission escape
        hatch); campaign tasks get an ErrorMessage instead of a broker
        requeue so the owning PipelineAgent retries them on its journaled
        ``RetryPolicy`` budget."""
        for tid, run in items:
            comp = run.computing
            if comp is None or run.cancel.is_set() or run.mem_tolerated:
                continue
            used = float(getattr(comp, "mem_used_mb", 0.0) or 0.0)
            budget = run.task.resources.mem_mb
            if budget <= 0 or used <= budget:
                continue
            task = run.task
            if task.campaign_id is None \
                    and task.attempt >= self.max_revoke_requeues:
                run.mem_tolerated = True
                log.warning("agent %s: task %s over budget (%.0f > %d MB) "
                            "past %d requeues — tolerating", self.agent_id,
                            tid, used, budget, self.max_revoke_requeues)
                continue
            requeue = task.campaign_id is None
            if not self._revoke_run(run, RevokeReason.MEM_OVERAGE,
                                    requeue=requeue):
                continue
            self._c["mem_revoked"].inc()
            log.warning("agent %s: task %s exceeded mem budget "
                        "(%.0f > %d MB) — lease revoked%s", self.agent_id,
                        tid, used, budget, " and requeued" if requeue else "")
            self._send_status(task, TaskStatus.REVOKED,
                              reason=RevokeReason.MEM_OVERAGE,
                              mem_used_mb=used, mem_budget_mb=budget)
            if task.campaign_id is not None:
                err = ErrorMessage(
                    task_id=tid, agent_id=self.agent_id,
                    error=(f"mem overage: {used:.0f} MB used > "
                           f"{budget} MB requested"),
                    attempt=task.attempt)
                self._producer.send(self.topics["error"], err.to_dict(),
                                    key=tid)

    def _cancel_task(self, run: _Running) -> None:
        run.cancel.set()

    def _heartbeat_running(self) -> None:
        now = time.time()
        with self._lock:
            items = list(self._running.values())
        for run in items:
            if now - run.last_heartbeat >= self.heartbeat_interval_s:
                run.last_heartbeat = now
                self._send_status(run.task, TaskStatus.RUNNING,
                                  heartbeat=True, elapsed_s=now - run.started_at)

    # -- completion ------------------------------------------------------------------

    def _finish(self, task: TaskMessage, ok: bool) -> None:
        with self._lock:
            self._running.pop(task.task_id, None)
        if ok:
            self._c["completed"].inc()
        else:
            self._c["failed"].inc()

    # -- lifecycle ------------------------------------------------------------------

    def _drain(self) -> None:
        """On graceful stop, revoke in-flight work so it gets redelivered:
        flat tasks are requeued by the broker in the same critical section;
        campaign tasks are only cancelled+fenced (their PipelineAgent owns
        resubmission, exactly like the watchdog split)."""
        with self._lock:
            runs = list(self._running.values())
        for run in runs:
            if not self._revoke_run(run, RevokeReason.DRAIN,
                                    requeue=run.task.campaign_id is None):
                self._cancel_task(run)
        deadline = time.time() + 2.0
        while time.time() < deadline and self._in_flight() > 0:
            time.sleep(0.01)

    # -- graceful drain (autoscale scale-down) --------------------------------

    def request_drain(self, timeout_s: float | None = None) -> None:
        """Begin a graceful drain: the agent leaves its consumer group (the
        rebalance hands unread partitions to the survivors), requeues every
        deferred lease back onto its class topic, lets in-flight tasks run
        to completion — no cancellation, so nothing is re-executed — and
        then stops. Non-blocking; observe progress via :attr:`state` /
        :attr:`alive`. With ``timeout_s``, tasks still running at the
        deadline are cancelled (and redelivered by the watchdog) so the
        drain always terminates."""
        with self._lock:
            if timeout_s is not None:
                self._drain_deadline = time.time() + timeout_s
        self.broker.blackbox.record(
            "drain", agent=self.agent_id, in_flight=self._in_flight(),
            deferred=len(self._deferred), timeout_s=timeout_s)
        self._draining.set()

    def _drain_tick(self) -> bool:
        """One loop iteration while draining; True once fully drained."""
        if not self._drain_entered:
            self._drain_entered = True
            log.info("agent %s draining: %d in flight, %d deferred",
                     self.agent_id, self._in_flight(), len(self._deferred))
            # leave the group first: no new leases, and partitions this
            # agent held rebalance to the surviving members immediately
            self._consumer.close()
            self._flush_deferred()
        # in-flight tasks still need the watchdog and liveness heartbeats —
        # a silent draining agent would look dead to the monitor, which
        # would resubmit (and therefore double-run) its tasks
        self._watchdog()
        self._heartbeat_running()
        if self._drain_deadline is not None \
                and time.time() > self._drain_deadline:
            with self._lock:
                runs = list(self._running.values())
            for run in runs:
                if not run.cancel.is_set():
                    log.warning("agent %s drain deadline: revoking %s for "
                                "redelivery", self.agent_id, run.task.task_id)
                    if not self._revoke_run(
                            run, RevokeReason.DRAIN,
                            requeue=run.task.campaign_id is None):
                        self._cancel_task(run)
        return self._in_flight() == 0

    def _flush_deferred(self) -> None:
        """Requeue leased-but-unstarted tasks with the *same* attempt (a
        requeue, not a retry — the task never started, so another agent
        running it is not a duplicate execution). A deferred lease is still
        GRANTED, so :meth:`Broker.revoke_lease` with ``reason="drain"``
        requeues it onto the topic it was leased from in one atomic step;
        the manual reroute below only covers leases the broker no longer
        tracks. Without this, an agent removed mid-run would strand every
        task whose offset it had committed until a watchdog timeout."""
        while True:
            with self._lock:
                if not self._deferred:
                    return
                task = self._deferred.popleft()
            if not self.broker.revoke_lease(task.task_id, RevokeReason.DRAIN):
                try:
                    target = self.placement.route(self.prefix, task)
                except ValueError:
                    # unroutable under our policy: the bare topic, where the
                    # monitor's legacy-forwarding or watchdog picks it up
                    target = self.topics["new"]
                self._producer.send(target, task.to_dict(), key=task.task_id)
            self._send_status(task, TaskStatus.SUBMITTED,
                              requeued_by=self.agent_id)
            self._c["requeued"].inc()

    @property
    def draining(self) -> bool:
        return self._draining.is_set() and self.alive

    @property
    def state(self) -> str:
        """``running`` | ``draining`` | ``stopped`` | ``crashed``."""
        if self._crashed.is_set():
            return "crashed"
        if self._thread is None or not self._thread.is_alive():
            return "stopped"
        if self._draining.is_set():
            return "draining"
        return "running"

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)

    def crash(self) -> None:
        """Test hook: die abruptly — no drain, no group leave, and no further
        messages of any kind (the producer is killed, as a dead process would
        be). The broker's session timeout + the MonitorAgent watchdog must
        recover the work."""
        self._crashed.set()
        self._producer.kill()
        with self._lock:
            for run in self._running.values():
                run.cancel.set()  # stop burning CPU; nothing is sent

    @property
    def alive(self) -> bool:
        return (self._thread is not None and self._thread.is_alive()
                and not self._crashed.is_set())

    def _mem_in_flight(self) -> int:
        with self._lock:
            return sum(r.task.resources.mem_mb
                       for r in self._running.values())

    def stats(self) -> dict:
        with self._lock:
            return {
                "agent_id": self.agent_id,
                "kind": self.kind,
                "state": self.state,
                "in_flight": len(self._running),
                "completed": self.tasks_completed,
                "failed": self.tasks_failed,
                "slots": self.slots,
                "oversubscribe": self.oversubscribe,
                "profile": (self.profile.to_dict()
                            if self.profile is not None else None),
                "subscriptions": list(self._subscriptions),
                "rerouted": self.tasks_rerouted,
                "deferred": self.tasks_deferred,
                "deferred_pending": len(self._deferred),
                "requeued": self.tasks_requeued,
                "revoked": self.tasks_revoked,
                "dropped_revoked": self.tasks_dropped_revoked,
                "mem_revoked": self.mem_revoked,
                "mem_in_flight_mb": sum(r.task.resources.mem_mb
                                        for r in self._running.values()),
                "heartbeat_failures": self.heartbeat_failures,
            }


class WorkerAgent(AgentBase):
    """Runs tasks directly in threads on the local machine (paper §3).

    With a declared profile, ``ResourceProfile.mem_mb`` is enforced at lease
    time: a task starts only while the sum of running requests fits the
    budget; otherwise it waits in the deferral queue — the workstation
    analogue of SimSlurm's per-node memory packing, instead of the old
    treat-it-as-a-hint behaviour."""

    kind = "worker"

    def _admit(self, task: TaskMessage) -> bool:
        if self.profile is None:
            return True
        need = task.resources.mem_mb
        cap = self.profile.mem_mb
        used = self._mem_in_flight()
        if used + need <= cap:
            return True
        if need > cap and not self._running:
            # the request can never fit this pool; running it best-effort on
            # an idle worker beats deadlocking the deferral queue (and
            # mirrors cpus-as-capacity-hint semantics, §5)
            log.warning("agent %s: task %s requests %d MB > profile budget "
                        "%d MB — admitting on idle worker", self.agent_id,
                        task.task_id, need, cap)
            return True
        return False

    def _accept(self, task: TaskMessage) -> None:
        cancel = threading.Event()
        member = self._consumer.member_id
        # GRANTED → RUNNING through the lease layer: a lease revoked while
        # the task waited in the deferral queue (drain flush, preemption,
        # operator scancel) was already requeued — starting it here would
        # double-run it.
        if not self.broker.claim_start(task.task_id, member, task.attempt,
                                       cancel):
            self._c["dropped_revoked"].inc()
            return
        run = _Running(task=task, cancel=cancel)
        with self._lock:
            self._running[task.task_id] = run
        self._send_status(task, TaskStatus.WAITING)

        def _target() -> None:
            if self._crashed.is_set():
                return
            cls = resolve_script(task.script)
            comp = cls(task, self._producer, self.prefix, self.agent_id,
                       cancel_event=cancel,
                       commit=lambda ok: self.broker.complete_lease(
                           task.task_id, member, task.attempt, ok=ok))
            run.computing = comp
            ok = False
            try:
                ok = comp.execute()
            finally:
                if not self._crashed.is_set():
                    self._finish(task, ok)
                else:
                    with self._lock:
                        self._running.pop(task.task_id, None)

        t = threading.Thread(target=_target,
                             name=f"{self.agent_id}-{task.task_id}",
                             daemon=True)
        run.thread = t
        t.start()


class ClusterAgent(AgentBase):
    """Submits tasks as (simulated) Slurm jobs and manages their lifecycle.

    ``slots`` is derived from the cluster size; ``oversubscribe`` > 0 enables
    the paper's keep-the-queue-full strategy. The agent holds **no** compute
    resources itself — between tasks, nodes are free for other users (the
    exact property that distinguishes KSA from Celery-style long-running
    workers, paper §2).
    """

    kind = "cluster"

    def __init__(self, broker: Broker, slurm: SimSlurm, prefix: str = "ksa",
                 *, oversubscribe: int | None = None, user: str = "ksa",
                 **kw: Any):
        slots = kw.pop("slots", slurm.total_cpus)
        if oversubscribe is None:
            oversubscribe = max(2, slots // 2)  # paper: always keep extras queued
        if "profile" not in kw:
            # derive routability/capacity from the simulated cluster's
            # hardware: a GPU-less Slurm partition must never lease GPU
            # stages, and the advertised mem budget is the cluster total
            # (per-node packing is SimSlurm's job).
            kw["profile"] = ResourceProfile(
                cpus=slurm.total_cpus,
                gpus=sum(n.gpus for n in slurm.nodes),
                mem_mb=sum(n.mem_mb for n in slurm.nodes))
        super().__init__(broker, prefix, slots=slots,
                         oversubscribe=oversubscribe, **kw)
        self.slurm = slurm
        self.user = user

    def _accept(self, task: TaskMessage) -> None:
        cancel = threading.Event()
        member = self._consumer.member_id
        run = _Running(task=task, cancel=cancel)

        def _on_revoke() -> None:
            # a revocation must also free the simulated node: scancel the
            # Slurm job (late-bound — the job id exists once sbatch returns)
            if run.slurm_job_id is not None:
                self.slurm.scancel(run.slurm_job_id)

        if not self.broker.claim_start(task.task_id, member, task.attempt,
                                       cancel, on_revoke=_on_revoke):
            self._c["dropped_revoked"].inc()
            return

        def _job(cancel_event: threading.Event | None = None) -> None:
            # runs inside a SimSlurm slot; honour both the agent's cancel and
            # Slurm's scancel/walltime event (merged view, no polling thread).
            if self._crashed.is_set():
                return
            merged = (cancel if cancel_event is None
                      else _AnyEvent(cancel, cancel_event))
            cls = resolve_script(task.script)
            comp = cls(task, self._producer, self.prefix, self.agent_id,
                       cancel_event=merged,
                       commit=lambda ok: self.broker.complete_lease(
                           task.task_id, member, task.attempt, ok=ok))
            run.computing = comp
            ok = False
            try:
                ok = comp.execute()
            finally:
                if not self._crashed.is_set():
                    self._finish(task, ok)
                else:
                    with self._lock:
                        self._running.pop(task.task_id, None)

        job_id = self.slurm.sbatch(
            _job, name=task.task_id, cpus=task.resources.cpus,
            gpus=task.resources.gpus, mem_mb=task.resources.mem_mb,
            walltime_s=task.timeout_s, user=self.user)
        run.slurm_job_id = job_id
        with self._lock:
            self._running[task.task_id] = run
        self._send_status(task, TaskStatus.WAITING, slurm_job_id=job_id)

    def _capacity(self) -> int:
        # lease only while the Slurm queue has room below the oversubscription
        # target: running-or-pending jobs < slots + oversubscribe.
        q = len(self.slurm.squeue(user=self.user))
        return (self.slots + self.oversubscribe) - max(q, self._in_flight())

    def _watchdog(self) -> None:
        super()._watchdog()
        self._police_slurm()

    def _police_slurm(self) -> None:
        """Slurm-side stops become lease revocations: a job the scheduler
        cancelled (walltime ``TO``) or an operator ``scancel``'d (``CA``)
        still holds a live lease — revoke it with ``reason="scancel"`` so
        the stale attempt is fenced at the broker instead of limping to a
        CANCELLED status the monitor has to notice going stale. Flat tasks
        are requeued in the same step; campaign resubmission stays with the
        PipelineAgent (watchdog split)."""
        with self._lock:
            items = list(self._running.items())
        for tid, run in items:
            if run.slurm_job_id is None:
                continue
            job = self.slurm.job(run.slurm_job_id)
            if job is None or job.state not in ("TO", "CA"):
                continue
            if self._revoke_run(run, RevokeReason.SCANCEL,
                                requeue=run.task.campaign_id is None):
                self._send_status(run.task, TaskStatus.REVOKED,
                                  reason=RevokeReason.SCANCEL,
                                  slurm_state=job.state,
                                  slurm_job_id=run.slurm_job_id)

    def _cancel_task(self, run: _Running) -> None:
        run.cancel.set()
        if run.slurm_job_id is not None:
            self.slurm.scancel(run.slurm_job_id)
