"""Submitter — sends task descriptions to the task topics (paper §3).

"The submission of any task involves setting the necessary parameters and then
using the built-in Submitter class to send the appropriate messages" (§5).
Batching helpers mirror the AlphaKnot campaign pattern (§4): "the entire set
of AlphaFold structures was divided into batches of 4,000, with each batch
submitted as a single task".

Unlike the paper's single shared ``PREFIX-new`` topic, each task is routed to
the per-resource-class topic its :class:`~repro.core.messages.Resources`
require (``PREFIX-new.cpu`` / ``PREFIX-new.gpu`` / label classes) through a
pluggable :class:`~repro.core.scheduling.PlacementPolicy`, so a GPU stage can
only ever be leased by a GPU-capable pool. Pass
:class:`~repro.core.scheduling.SingleTopicPolicy` to recover the paper's flat
layout.
"""
from __future__ import annotations

import time
from typing import Any, Sequence

from .broker import Broker, Producer
from .messages import (Resources, StatusUpdate, TaskMessage, TaskStatus,
                       new_task_id, topic_names)
from .scheduling import PlacementPolicy, ResourceClassPolicy


class Submitter:
    """``partitioner`` picks how task records map to partitions of their
    class topic: ``"hash"`` (default, kafka-like — stable per task id) or
    ``"balanced"`` (least-loaded partition — evens out the per-member share
    under the sticky group assignor, which sets a campaign's makespan).
    Status updates always hash so each task's timeline stays ordered."""

    def __init__(self, broker: Broker, prefix: str = "ksa", *,
                 placement: PlacementPolicy | None = None,
                 partitioner: str = "hash"):
        if partitioner not in ("hash", "balanced"):
            raise ValueError(f"unknown partitioner {partitioner!r} "
                             f"(expected 'hash' or 'balanced')")
        self.broker = broker
        self.prefix = prefix
        self.topics = topic_names(prefix)
        self.placement = placement or ResourceClassPolicy()
        self.partitioner = partitioner
        self._producer = Producer(broker)
        for t in self.topics.values():
            broker.create_topic(t)
        for t in self.placement.topics(prefix):
            broker.create_topic(t)

    def submit(self, script: str, task_id: str | None = None, *,
               params: dict | None = None, cpus: int = 1, gpus: int = 0,
               mem_mb: int = 1024, labels: Sequence[str] = (),
               timeout_s: float | None = None,
               attempt: int = 0, resources: Resources | None = None,
               campaign_id: str | None = None, stage: str | None = None,
               dep_ids: list | None = None) -> str:
        """Submit one task (paper §5: script name, task ID, resources, and any
        number of extra parameters). ``campaign_id``/``stage``/``dep_ids``
        tag tasks emitted by the repro.pipeline DAG orchestrator."""
        task = TaskMessage(
            task_id=task_id or new_task_id(script),
            script=script,
            params=dict(params or {}),
            resources=resources or Resources(cpus=cpus, gpus=gpus,
                                             mem_mb=mem_mb,
                                             labels=tuple(labels)),
            timeout_s=timeout_s,
            attempt=attempt,
            campaign_id=campaign_id,
            stage=stage,
            dep_ids=list(dep_ids or []),
        )
        return self.submit_task(task)

    def submit_task(self, task: TaskMessage) -> str:
        """Submit a fully-built :class:`TaskMessage` (used by the pipeline
        agent, which constructs stage tasks itself). The placement policy
        picks the class topic; the SUBMITTED status update carries the routed
        topic for observability."""
        task.trace.setdefault("trace_id", task.task_id)
        topic = self.placement.route(self.prefix, task)
        now = time.time()
        self.broker.spans.add(task.task_id, "submit", now, now,
                              attempt=task.attempt, topic=topic,
                              trace_id=task.trace["trace_id"],
                              campaign=task.campaign_id)
        self._producer.send(topic, task.to_dict(), key=task.task_id,
                            partition=self._task_partition(topic))
        self._producer.send(
            self.topics["jobs"],
            StatusUpdate(task_id=task.task_id,
                         status=TaskStatus.SUBMITTED.value,
                         attempt=task.attempt,
                         info={"topic": topic}).to_dict(),
            key=task.task_id)
        return task.task_id

    def _task_partition(self, topic: str) -> int | None:
        if self.partitioner != "balanced":
            return None  # keyed hash, the broker's default
        return self.broker.least_loaded_partition(topic)

    def resubmit(self, task: TaskMessage) -> str:
        """Redeliver a task with a bumped attempt (straggler mitigation /
        at-least-once path used by the MonitorAgent watchdog). Routed through
        the same placement policy as the original submission."""
        nxt = task.retry()
        nxt.trace.setdefault("trace_id", nxt.task_id)
        topic = self.placement.route(self.prefix, nxt)
        now = time.time()
        self.broker.spans.add(nxt.task_id, "submit", now, now,
                              attempt=nxt.attempt, topic=topic,
                              trace_id=nxt.trace["trace_id"],
                              campaign=nxt.campaign_id, resubmitted=True)
        self._producer.send(topic, nxt.to_dict(), key=nxt.task_id,
                            partition=self._task_partition(topic))
        self._producer.send(
            self.topics["jobs"],
            StatusUpdate(task_id=nxt.task_id,
                         status=TaskStatus.SUBMITTED.value,
                         attempt=nxt.attempt,
                         info={"resubmitted": True}).to_dict(),
            key=nxt.task_id)
        return nxt.task_id

    def submit_batches(self, script: str, items: Sequence[Any], *,
                       batch_size: int, params: dict | None = None,
                       id_prefix: str | None = None,
                       **resource_kw: Any) -> list[str]:
        """Campaign-style submission: split ``items`` into batches of
        ``batch_size`` and submit one task per batch (paper §4, batches of
        4000 AlphaFold structures)."""
        ids = []
        base = id_prefix or script
        for i in range(0, len(items), batch_size):
            batch = list(items[i:i + batch_size])
            p = dict(params or {})
            p["batch"] = batch
            p["batch_index"] = i // batch_size
            ids.append(self.submit(script, task_id=f"{base}-b{i // batch_size:06d}",
                                   params=p, **resource_kw))
        return ids
