"""SimSlurm — a faithful, in-process simulator of the Slurm subset KSA uses.

The paper's ClusterAgent talks to Slurm exclusively through the unprivileged
command-line interface (``sbatch`` / ``squeue`` / ``scancel`` — §5 stresses
that no Slurm REST API, Kafka plugin, or C library is required). SimSlurm
models exactly that surface:

* a cluster of ``nodes × cpus_per_node`` (+ optional GPUs and per-node
  memory),
* a FIFO queue with per-job resource requests; jobs start when a node has
  free cpu/gpu slots *and* free memory (first-fit packing, like a
  single-partition Slurm with ``SelectType=cons_tres`` — memory is a packed
  resource, not a hint),
* job states ``PD`` (pending) → ``R`` (running) → ``CD`` (completed) /
  ``F`` (failed) / ``CA`` (cancelled) / ``TO`` (walltime timeout),
* ``scancel``, per-job walltime limits, and a global scheduler tick.

It runs submitted Python callables on a thread pool sized to the simulated
slot count, so "a Slurm job" really executes work — which is what lets the
oversubscription benchmark and the Celery-comparison benchmark (paper §2/§7)
measure real utilization numbers.
"""
from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass
class NodeState:
    name: str
    cpus: int
    gpus: int
    free_cpus: int
    free_gpus: int
    mem_mb: int = 0
    free_mem_mb: int = 0
    up_at: float = 0.0  # node boots at this wall-clock time (spin-up latency)

    @property
    def up(self) -> bool:
        return time.time() >= self.up_at


@dataclass
class Job:
    job_id: int
    name: str
    fn: Callable[[], Any]
    cpus: int
    gpus: int
    walltime_s: float | None
    user: str
    mem_mb: int = 0
    state: str = "PD"  # PD | R | CD | F | CA | TO
    node: str | None = None
    submitted_at: float = field(default_factory=time.time)
    started_at: float | None = None
    ended_at: float | None = None
    future: Future | None = None
    cancel_event: threading.Event = field(default_factory=threading.Event)

    @property
    def pending(self) -> bool:
        return self.state == "PD"

    @property
    def active(self) -> bool:
        return self.state in ("PD", "R")


class SimSlurm:
    """A single-partition simulated cluster.

    ``speedup`` scales simulated walltimes for fast tests/benchmarks: a task
    that declares ``duration`` sleeps ``duration / speedup`` wall seconds but
    is accounted at full duration in utilization stats.
    """

    def __init__(self, nodes: int = 4, cpus_per_node: int = 8,
                 gpus_per_node: int = 0, mem_mb_per_node: int | None = None,
                 scheduler_interval_s: float = 0.01,
                 spinup_s: float = 0.0):
        # default memory sizes the node to its cpu count at the control
        # plane's default request (1024 MB/task), so cpu-bound workloads
        # pack exactly as before memory became a packed resource.
        if mem_mb_per_node is None:
            mem_mb_per_node = 1024 * cpus_per_node
        # ``spinup_s`` models node provisioning latency (powering on a
        # drained partition / cloud-bursting a node): jobs queue PD until
        # the node is up, which is exactly the cold-start cost an elastic
        # autoscaler must weigh before scaling a Slurm pool to zero.
        up_at = time.time() + spinup_s
        self.spinup_s = spinup_s
        self.nodes = [
            NodeState(f"node{i:03d}", cpus_per_node, gpus_per_node,
                      cpus_per_node, gpus_per_node,
                      mem_mb_per_node, mem_mb_per_node, up_at=up_at)
            for i in range(nodes)
        ]
        self.total_cpus = nodes * cpus_per_node
        self._jobs: dict[int, Job] = {}
        self._ids = itertools.count(1)
        self._lock = threading.RLock()
        self._pool = ThreadPoolExecutor(max_workers=self.total_cpus,
                                        thread_name_prefix="simslurm")
        self._interval = scheduler_interval_s
        self._stop = threading.Event()
        self._sched = threading.Thread(target=self._scheduler_loop,
                                       name="simslurm-sched", daemon=True)
        self._busy_cpu_seconds = 0.0
        self._t0 = time.time()
        self._sched.start()

    # -- the unprivileged CLI surface ---------------------------------------

    def sbatch(self, fn: Callable[..., Any], *, name: str = "job",
               cpus: int = 1, gpus: int = 0, mem_mb: int = 0,
               walltime_s: float | None = None,
               user: str = "user") -> int:
        """Submit a job; returns the Slurm job id. ``fn`` may accept a
        ``cancel_event`` kwarg to observe scancel/timeout. ``mem_mb`` is
        packed per node like cpus/gpus (0 = no memory demand)."""
        with self._lock:
            job = Job(next(self._ids), name, fn, cpus, gpus, walltime_s,
                      user, mem_mb=mem_mb)
            self._jobs[job.job_id] = job
            return job.job_id

    def squeue(self, user: str | None = None,
               states: tuple[str, ...] | None = None) -> list[Job]:
        with self._lock:
            out = [j for j in self._jobs.values() if j.active]
            if user is not None:
                out = [j for j in out if j.user == user]
            if states is not None:
                out = [j for j in out if j.state in states]
            return sorted(out, key=lambda j: j.job_id)

    def scancel(self, job_id: int) -> bool:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None or not job.active:
                return False
            if job.state == "PD":
                job.state = "CA"
                job.ended_at = time.time()
            else:
                job.cancel_event.set()  # running: cooperative cancel
                job.state = "CA"
            return True

    def job(self, job_id: int) -> Job | None:
        with self._lock:
            return self._jobs.get(job_id)

    def sinfo(self) -> dict:
        with self._lock:
            return {
                "nodes": len(self.nodes),
                "nodes_up": sum(n.up for n in self.nodes),
                "total_cpus": self.total_cpus,
                "free_cpus": sum(n.free_cpus for n in self.nodes),
                "free_mem_mb": sum(n.free_mem_mb for n in self.nodes),
                "pending": sum(j.state == "PD" for j in self._jobs.values()),
                "running": sum(j.state == "R" for j in self._jobs.values()),
            }

    # -- scheduler ------------------------------------------------------------

    def _try_place(self, job: Job) -> NodeState | None:
        for node in self.nodes:  # first-fit over cpus, gpus, and memory
            if not node.up:
                continue  # still spinning up: jobs stay PD (cold start)
            if node.free_cpus >= job.cpus and node.free_gpus >= job.gpus \
                    and node.free_mem_mb >= job.mem_mb:
                return node
        return None

    def _scheduler_loop(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                pending = [j for j in self._jobs.values() if j.state == "PD"]
                pending.sort(key=lambda j: j.job_id)  # FIFO
                for job in pending:
                    node = self._try_place(job)
                    if node is None:
                        continue
                    node.free_cpus -= job.cpus
                    node.free_gpus -= job.gpus
                    node.free_mem_mb -= job.mem_mb
                    job.state = "R"
                    job.node = node.name
                    job.started_at = time.time()
                    job.future = self._pool.submit(self._run_job, job)
                # walltime enforcement
                now = time.time()
                for job in self._jobs.values():
                    if (job.state == "R" and job.walltime_s is not None
                            and job.started_at is not None
                            and now - job.started_at > job.walltime_s):
                        job.cancel_event.set()
                        job.state = "TO"
            self._stop.wait(self._interval)

    def _run_job(self, job: Job) -> None:
        try:
            try:
                job.fn(cancel_event=job.cancel_event)  # type: ignore[call-arg]
            except TypeError as te:
                if "cancel_event" not in str(te):
                    raise
                job.fn()
            ok = True
        except Exception:
            ok = False
        with self._lock:
            if job.state == "R":  # not already CA/TO
                job.state = "CD" if ok else "F"
            job.ended_at = time.time()
            if job.started_at is not None:
                self._busy_cpu_seconds += (job.ended_at - job.started_at) * job.cpus
            node = next(n for n in self.nodes if n.name == job.node)
            node.free_cpus += job.cpus
            node.free_gpus += job.gpus
            node.free_mem_mb += job.mem_mb

    # -- accounting -------------------------------------------------------------

    def utilization(self) -> float:
        """busy cpu-seconds / available cpu-seconds since construction."""
        with self._lock:
            elapsed = max(time.time() - self._t0, 1e-9)
            running = sum(
                (time.time() - j.started_at) * j.cpus
                for j in self._jobs.values()
                if j.state == "R" and j.started_at is not None)
            return (self._busy_cpu_seconds + running) / (elapsed * self.total_cpus)

    def wait_all(self, timeout: float = 60.0) -> bool:
        deadline = time.time() + timeout
        while time.time() < deadline:
            with self._lock:
                if not any(j.active for j in self._jobs.values()):
                    return True
            time.sleep(self._interval)
        return False

    def shutdown(self) -> None:
        self._stop.set()
        self._sched.join(timeout=2.0)
        self._pool.shutdown(wait=False, cancel_futures=True)
