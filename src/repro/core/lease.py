"""Unified task-lease lifecycle — the one way work is taken back.

The control plane used to have four disjoint stop-work mechanisms: the
MonitorAgent watchdog resubmitted stale tasks, the autoscaler's graceful
drain requeued deferred leases, SimSlurm's ``scancel``/walltime fired a
``cancel_event``, and the PipelineAgent fenced late results of retried
tasks — each with its own bookkeeping and its own races. The paper's own
ClusterAgent already treats reclamation as a first-class operation ("if a
task hangs or exceeds the predefined timeout, the ClusterAgent intervenes
by canceling the associated Slurm job", §3), and both ParaFold
(arXiv:2111.06340) and the Summit proteome-scale deployment
(arXiv:2201.10024) show heterogeneous campaigns stay fast only when the
scheduler can actively take resources *back*, not just hand them out.

This module is that primitive. A :class:`Lease` is the broker-tracked
handle for one attempt of one task on one holder, with a single state
machine::

    GRANTED ──→ RUNNING ──→ DONE
       │           │    └──→ FAILED
       └───────────┴───────→ REVOKED(reason)

* **GRANTED** — the holder committed the record's offset via
  :meth:`~repro.core.broker.Broker.lease_records` (the task is its
  responsibility; it may still be waiting in a deferral queue),
* **RUNNING** — execution started (:meth:`~repro.core.broker.Broker.claim_start`
  bound the task's ``cancel_event`` so a revocation can actually stop it),
* **DONE** / **FAILED** — the holder committed its verdict through the
  :meth:`~repro.core.broker.Broker.complete_lease` gate,
* **REVOKED** — :meth:`~repro.core.broker.Broker.revoke_lease` took the
  lease back: the ``cancel_event`` fires (``check_cancel`` raises inside
  the computation), any late ``complete_lease`` from the old holder
  returns False (the commit is *fenced* — no stale result or error ever
  leaves the agent), and, when requested, the task record is requeued
  onto the topic it was leased from — all in one critical section under
  the broker lock, so a revoked task is never both requeued and completed.

Every stopper is now a caller: the agent/monitor watchdogs revoke with
``reason="watchdog"``, graceful drain flushes deferred leases with
``reason="drain"``, SimSlurm walltime/scancel policing uses
``reason="scancel"``, memory policing uses ``reason="mem_overage"``, and
the PipelineAgent's preemptive fair share revokes with
``reason="preempt"`` (journaled as a ``LeaseRevoked`` event so recovery
replays revocations exactly like completions).
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Sequence

# -- lease states ------------------------------------------------------------

GRANTED = "GRANTED"
RUNNING = "RUNNING"
DONE = "DONE"
FAILED = "FAILED"
REVOKED = "REVOKED"

LIVE_STATES = (GRANTED, RUNNING)


class RevokeReason:
    """Why a lease was taken back (the ``REVOKED(reason=...)`` tag)."""

    WATCHDOG = "watchdog"        # hung / timed-out / stale-heartbeat task
    PREEMPT = "preempt"          # fair-share preemption of an over-share campaign
    MEM_OVERAGE = "mem_overage"  # task exceeded its Resources.mem_mb request
    DRAIN = "drain"              # agent leaving (autoscale shrink / stop)
    SCANCEL = "scancel"          # slurm-side stop (walltime / external scancel)

    ALL = (WATCHDOG, PREEMPT, MEM_OVERAGE, DRAIN, SCANCEL)


# how long an unacknowledged REVOKED entry is kept for commit fencing before
# the periodic sweep drops it (holders that crashed never ack)
_REVOKED_TTL_S = 120.0

# completion tombstones retained for duplicate-execution fencing (a stale
# requeued/resubmitted record of an already-accepted task must never run)
_DONE_CAP = 4096


@dataclass(frozen=True)
class LeaseTolerance:
    """WAN-tolerance policy for leases held across a slow link.

    A federated site's bridge holds home-broker leases for tasks executing
    remotely; its heartbeats cross a WAN link whose round-trip can dwarf the
    uniform watchdog deadline tuned for local workers. Instead of loosening
    every deadline to the slowest link (masking genuinely hung local tasks),
    holders registered with a tolerance get a *per-site* heartbeat deadline
    stamped onto each lease they are granted::

        deadline_s = base_timeout_s * rtt_factor + slack_s

    where ``base_timeout_s`` is the watchdog's configured deadline. The
    MonitorAgent and PipelineAgent watchdogs consult the stamped deadline
    before revoking, so a healthy remote lease behind a slow-but-alive link
    survives, while cross-site revocation still fences exactly-once
    execution when the lease really is taken back."""

    slack_s: float = 0.0     # absolute extra headroom (e.g. 2 * link RTT)
    rtt_factor: float = 1.0  # multiplier on the watchdog's base deadline

    def deadline(self, base_timeout_s: float | None) -> float | None:
        """Per-site heartbeat deadline for a given base watchdog timeout.
        None base (watchdog disabled) stays None unless slack alone is
        meaningful — a pure-slack tolerance still bounds the lease."""
        if base_timeout_s is None:
            return self.slack_s if self.slack_s > 0 else None
        return base_timeout_s * self.rtt_factor + self.slack_s


@dataclass(slots=True)
class Lease:
    """One attempt of one task held by one agent (broker-internal record).

    ``value`` keeps the leased record's payload so a revocation can requeue
    the task without a topic scan; ``seq`` is the broker-wide monotonic
    grant sequence (journaled observability, not a fencing token — fencing
    is by ``(holder, attempt)``)."""

    task_id: str
    holder: str
    topic: str
    attempt: int
    value: dict
    seq: int
    granted_at: float = field(default_factory=time.time)
    state: str = GRANTED
    started_at: float | None = None
    revoked_at: float | None = None
    reason: str | None = None
    cancel: threading.Event | None = None
    on_revoke: Callable[[], None] | None = None
    site: str = ""                   # holder's site ("" = broker-local)
    deadline_s: float | None = None  # per-site heartbeat deadline, if any

    @property
    def live(self) -> bool:
        return self.state in LIVE_STATES

    def view(self) -> dict:
        """JSON-safe snapshot for observability / victim selection."""
        return {
            "task_id": self.task_id,
            "holder": self.holder,
            "topic": self.topic,
            "attempt": self.attempt,
            "seq": self.seq,
            "state": self.state,
            "granted_at": self.granted_at,
            "started_at": self.started_at,
            "revoked_at": self.revoked_at,
            "reason": self.reason,
            "campaign_id": self.value.get("campaign_id"),
            "site": self.site,
            "deadline_s": self.deadline_s,
        }


class LeaseTable:
    """One shard of the broker's lease registry. **Not** thread-safe on its
    own — every method is called with the owning lock held (the broker's
    single lock in ``single_lock`` mode, the shard lock of a
    :class:`ShardedLeaseTable` otherwise), which is what makes
    revoke-vs-complete atomic per task.

    ``seq_source`` injects a shared grant-sequence counter so N shards keep
    one broker-wide monotonic ``Lease.seq``; ``done_cap`` bounds this
    shard's completion-tombstone dict (a sharded table divides the global
    cap across shards)."""

    def __init__(self, metrics=None, *,
                 seq_source: Iterator[int] | None = None,
                 done_cap: int = _DONE_CAP) -> None:
        # counters live in the obs registry (repro.obs) so /metrics and the
        # legacy stats() dict are the same numbers; a standalone table (unit
        # tests, direct wiring) gets a private registry. Registration is
        # idempotent by name, so every shard of a ShardedLeaseTable shares
        # the same counter families.
        from repro.obs import MetricsRegistry
        reg = metrics if metrics is not None else MetricsRegistry()
        self._c_granted = reg.counter(
            "ksa_leases_granted_total", "Leases granted (GRANTED entered)")
        self._c_completed = reg.counter(
            "ksa_leases_completed_total", "Leases committed DONE")
        self._c_failed = reg.counter(
            "ksa_leases_failed_total", "Leases committed FAILED")
        self._c_requeued = reg.counter(
            "ksa_leases_requeued_total",
            "Revoked lease records requeued by the broker")
        self._c_stale = reg.counter(
            "ksa_lease_stale_drops_total",
            "Stale sibling records refused (grant or claim)")
        self._c_revoked = reg.counter(
            "ksa_leases_revoked_total", "Leases revoked, by reason",
            labels=("reason",))
        for r in RevokeReason.ALL:  # pre-create so stats() always lists ALL
            self._c_revoked.labels(reason=r)
        self._leases: dict[str, Lease] = {}
        # task_id -> accepted attempt: completion tombstones. Stop-path
        # requeues and watchdog resubmissions race the attempt they
        # replace; when the older attempt wins, its sibling record is
        # still on a topic and will be leased later — the tombstone makes
        # claim_start refuse it, so a finished task is never re-executed
        # (exactly-once *execution*, not just exactly-once result).
        # A deliberate rerun of a finished task id needs a higher attempt.
        self._done: dict[str, int] = {}
        self._done_cap = done_cap
        self._next_seq = seq_source if seq_source is not None \
            else itertools.count(1)

    # -- counter views (registry-backed; the attribute names predate obs) --

    @property
    def granted(self) -> int:
        return self._c_granted.value

    @property
    def completed(self) -> int:
        return self._c_completed.value

    @property
    def failed(self) -> int:
        return self._c_failed.value

    @property
    def requeued(self) -> int:
        return self._c_requeued.value

    @property
    def stale_drops(self) -> int:
        return self._c_stale.value

    @property
    def revoked(self) -> dict:
        return {key[0]: child.value for key, child in self._c_revoked.items()}

    def count_requeued(self) -> None:
        """Called by the broker when it requeues a revoked lease's record."""
        self._c_requeued.inc()

    # -- lifecycle ---------------------------------------------------------

    def grant(self, task_id: str, holder: str, topic: str, attempt: int,
              value: dict, *, site: str = "",
              deadline_s: float | None = None,
              now: float | None = None) -> Lease | None:
        """Register a fresh GRANTED lease (replaces any stale entry for the
        task — a requeued task's new lease supersedes the fenced old one).
        A record whose attempt is *behind* a live lease is the stale
        sibling of a requeue race: it must not clobber the newer lease
        (its claim will be refused instead). ``site``/``deadline_s`` stamp
        the holder's federation site and WAN-tolerant heartbeat deadline
        (see :class:`LeaseTolerance`) onto the lease for the watchdogs.
        ``now`` lets a batched grant path stamp one shared timestamp."""
        cur = self._leases.get(task_id)
        if cur is not None and cur.live and cur.attempt > attempt:
            self._c_stale.inc()
            return None
        lease = Lease(task_id=task_id, holder=holder, topic=topic,
                      attempt=attempt, value=value, seq=next(self._next_seq),
                      site=site, deadline_s=deadline_s)
        if now is not None:
            lease.granted_at = now
        self._leases[task_id] = lease
        self._c_granted.inc()
        return lease

    def grant_batch(self, records: Sequence, holder: str, *, site: str = "",
                    deadline_s: float | None = None,
                    now: float | None = None) -> list:
        """Grant leases for a batch of fetched records in one pass under the
        caller's (shard) lock — one timestamp, one counter bump per grant,
        no per-record lock round-trips. ``records`` are broker ``Record``s
        whose ``value`` carries ``task_id``/``attempt``; non-task records
        pass through with a ``None`` lease. Returns ``[(record, lease|None),
        ...]`` in input order."""
        stamp = time.time() if now is None else now
        out = []
        leases, n_stale = self._leases, 0
        seq = self._next_seq
        for rec in records:
            # inlined grant() with counters tallied once per batch instead
            # of one locked inc per record
            task_id = rec.key
            value = rec.value
            attempt = int(value.get("attempt", 0))
            cur = leases.get(task_id)
            if cur is not None and cur.live and cur.attempt > attempt:
                n_stale += 1
                out.append((rec, None))
                continue
            # positional construction: kwarg binding is measurable at
            # 100k+ grants/s on the sharded hot path
            lease = Lease(task_id, holder, rec.topic, attempt, value,
                          next(seq), stamp, GRANTED, None, None, None,
                          None, None, site, deadline_s)
            leases[task_id] = lease
            out.append((rec, lease))
        n_granted = len(out) - n_stale
        if n_granted:
            self._c_granted.inc(n_granted)
        if n_stale:
            self._c_stale.inc(n_stale)
        return out

    def claim_start(self, task_id: str, holder: str, attempt: int,
                    cancel: threading.Event,
                    on_revoke: Callable[[], None] | None = None) -> bool:
        """GRANTED → RUNNING iff ``(holder, attempt)`` still owns an
        unrevoked lease; binds the cancel event so a later revocation can
        stop the execution. Returns False (and acks/drops a revoked or
        superseded entry) when the holder must *not* start the task."""
        if task_id in self._done:
            # the task already completed (possibly on a sibling attempt
            # that won a requeue/resubmission race): no attempt of a
            # completed task ever executes again — every resubmitter
            # (monitor, pipeline, recovery) checks terminality first, so a
            # late record here is always a stale race artifact
            lease = self._leases.get(task_id)
            if lease is not None and lease.holder == holder \
                    and lease.attempt == attempt:
                del self._leases[task_id]
            self._c_stale.inc()
            return False
        lease = self._leases.get(task_id)
        if lease is None:
            return True  # unregistered execution (direct wiring): no fencing
        if lease.holder != holder or lease.attempt != attempt:
            return False  # superseded: another holder owns the task now
        if lease.state == REVOKED:
            del self._leases[task_id]  # ack: the revocation already requeued
            return False
        if lease.state != GRANTED:
            # already RUNNING: a same-attempt duplicate record (e.g. the
            # requeued copy of a deferred lease the same agent re-leased)
            # must not start a second concurrent execution
            return False
        lease.state = RUNNING
        lease.started_at = time.time()
        lease.cancel = cancel
        lease.on_revoke = on_revoke
        return True

    def complete(self, task_id: str, holder: str | None, attempt: int | None,
                 ok: bool) -> bool:
        """The commit gate: True iff the holder may publish its verdict
        (result or error). A revoked or superseded lease returns False —
        the work was already requeued, so the stale outcome must not leave
        the agent. Terminal either way: the entry is dropped."""
        lease = self._leases.get(task_id)
        if lease is None:
            # no lease tracked: either direct wiring (no fencing) or a
            # stale sibling whose task already completed — the tombstone
            # tells the two apart
            return task_id not in self._done
        if holder is not None and lease.holder != holder:
            return False  # superseded: not this holder's lease any more
        if attempt is not None and lease.attempt != attempt:
            return False
        del self._leases[task_id]
        if lease.state == REVOKED:
            return False
        lease.state = DONE if ok else FAILED
        if ok:
            self._c_completed.inc()
            self._done[task_id] = lease.attempt
            if len(self._done) > self._done_cap:
                self._done.pop(next(iter(self._done)))
        else:
            self._c_failed.inc()
        return True

    def complete_batch(self, items: Sequence, holder: str | None,
                       ok: bool) -> list:
        """Batched :meth:`complete` under the caller's (shard) lock:
        ``items`` is ``[(task_id, attempt|None), ...]`` sharing one wave
        outcome ``ok`` (a holder commits successes and failures as separate
        waves); every entry passes through the same commit gate, with the
        completed/failed counters bumped once per batch instead of once per
        record. Returns ``[(task_id, committed, lease|None), ...]`` in
        input order."""
        out: list = []
        n_terminal = 0
        state = DONE if ok else FAILED
        leases, done = self._leases, self._done
        for task_id, attempt in items:
            lease = leases.get(task_id)
            if lease is None:
                out.append((task_id, task_id not in done, None))
                continue
            if (holder is not None and lease.holder != holder) \
                    or (attempt is not None and lease.attempt != attempt):
                out.append((task_id, False, lease))
                continue
            del leases[task_id]
            if lease.state == REVOKED:
                out.append((task_id, False, lease))
                continue
            lease.state = state
            n_terminal += 1
            if ok:
                done[task_id] = lease.attempt
            out.append((task_id, True, lease))
        while len(done) > self._done_cap:
            done.pop(next(iter(done)))
        if n_terminal:
            (self._c_completed if ok else self._c_failed).inc(n_terminal)
        return out

    def revoke(self, task_id: str, reason: str) -> Lease | None:
        """Take a live lease back: fire the cancel event (and the holder's
        ``on_revoke`` hook, e.g. ``scancel``), tag the reason, and return
        the lease so the broker can requeue its record in the same critical
        section. None if there is nothing live to revoke (already terminal,
        unknown, or mid-completion — the race the gate exists for)."""
        lease = self._leases.get(task_id)
        if lease is None or not lease.live:
            return None
        lease.state = REVOKED
        lease.reason = reason
        lease.revoked_at = time.time()
        self._c_revoked.labels(reason=reason).inc()
        if lease.cancel is not None:
            lease.cancel.set()
        if lease.on_revoke is not None:
            try:
                lease.on_revoke()
            except Exception:  # pragma: no cover - defensive
                pass
        self._sweep(lease.revoked_at)
        return lease

    def forget(self, task_id: str, holder: str) -> None:
        """Drop a lease the holder gave up without executing (reroute of a
        misplaced task — the rerouted record grants a fresh lease)."""
        lease = self._leases.get(task_id)
        if lease is not None and lease.holder == holder:
            del self._leases[task_id]

    def _sweep(self, now: float) -> None:
        """GC revoked entries whose (dead) holder will never ack."""
        stale = [t for t, l in self._leases.items()
                 if l.state == REVOKED and l.revoked_at is not None
                 and now - l.revoked_at > _REVOKED_TTL_S]
        for t in stale:
            del self._leases[t]

    # -- queries -----------------------------------------------------------

    def get(self, task_id: str) -> Lease | None:
        return self._leases.get(task_id)

    def live_views(self, task_ids=None, holder: str | None = None) -> list[dict]:
        out = []
        leases = ([self._leases.get(t) for t in task_ids]
                  if task_ids is not None else list(self._leases.values()))
        for lease in leases:
            if lease is None or not lease.live:
                continue
            if holder is not None and lease.holder != holder:
                continue
            out.append(lease.view())
        return out

    def stats(self) -> dict:
        return {
            "active": sum(1 for l in self._leases.values() if l.live),
            "granted": self.granted,
            "completed": self.completed,
            "failed": self.failed,
            "requeued": self.requeued,
            "stale_drops": self.stale_drops,
            "revoked": dict(self.revoked),
            "revoked_total": sum(self.revoked.values()),
        }


class ShardedLeaseTable:
    """Task-id-hash-sharded lease registry — grant/claim/complete/revoke on
    tasks in different shards never contend.

    Each shard is a plain :class:`LeaseTable` guarded by its own lock; a
    task's shard is a pure function of its id, so every lifecycle operation
    for one task serializes on the same lock and the per-task atomicity
    contracts are exactly those of the single-table broker. Unlike
    :class:`LeaseTable`, locking is owned *here*: callers never wrap calls
    in their own lock. The broker injects ``lock_factory`` so its
    ``single_lock`` (all shards alias the master lock) and ``debug_locks``
    (order-checked locks) modes compose; shard locks rank between the group
    lock and the partition locks in the broker's lock hierarchy — see the
    :mod:`repro.core.broker` docstring.

    Cross-shard invariants are preserved by construction: the grant
    sequence is one shared ``itertools.count`` (broker-wide monotonic
    ``Lease.seq``), the counters are one shared registry family (counter
    registration is idempotent by name), and the completion-tombstone cap
    is divided across shards."""

    def __init__(self, metrics=None, *, shards: int = 8,
                 lock_factory: Callable[[int], Any] | None = None) -> None:
        n = max(1, int(shards))
        seq = itertools.count(1)
        cap = max(256, _DONE_CAP // n)
        self._tables = [LeaseTable(metrics, seq_source=seq, done_cap=cap)
                        for _ in range(n)]
        make = lock_factory if lock_factory is not None \
            else (lambda i: threading.RLock())
        self._locks = [make(i) for i in range(n)]
        self._n = n

    @property
    def shards(self) -> int:
        return self._n

    def shard_of(self, task_id: str) -> int:
        return hash(task_id) % self._n

    # -- lifecycle ---------------------------------------------------------

    def grant(self, task_id: str, holder: str, topic: str, attempt: int,
              value: dict, *, site: str = "",
              deadline_s: float | None = None,
              now: float | None = None) -> Lease | None:
        """Per-record grant (the legacy data plane uses this; the sharded
        hot path batches through :meth:`grant_batch`)."""
        i = self.shard_of(task_id)
        with self._locks[i]:
            return self._tables[i].grant(
                task_id, holder, topic, attempt, value,
                site=site, deadline_s=deadline_s, now=now)

    def grant_batch(self, records: Sequence, holder: str, *, site: str = "",
                    deadline_s: float | None = None,
                    now: float | None = None) -> list:
        """Grant leases for a batch of task records with one critical
        section per shard touched (not per record). Returns
        ``[(record, lease|None), ...]``; order is per-shard, which is fine
        for the observability fan-out this feeds."""
        stamp = time.time() if now is None else now
        if self._n == 1:
            with self._locks[0]:
                return self._tables[0].grant_batch(
                    records, holder, site=site, deadline_s=deadline_s,
                    now=stamp)
        n = self._n
        buckets: dict[int, list] = {}
        for rec in records:
            buckets.setdefault(hash(rec.key) % n, []).append(rec)
        out: list = []
        for i in sorted(buckets):  # one shard lock at a time, ascending
            with self._locks[i]:
                out.extend(self._tables[i].grant_batch(
                    buckets[i], holder, site=site, deadline_s=deadline_s,
                    now=stamp))
        return out

    def claim_start(self, task_id: str, holder: str, attempt: int,
                    cancel: threading.Event,
                    on_revoke: Callable[[], None] | None = None
                    ) -> tuple[bool, Lease | None]:
        """GRANTED → RUNNING under the task's shard lock. Returns
        ``(ok, lease)`` — the lease (claimed in place when ok) lets the
        broker observe grant→claim latency *outside* the lock."""
        i = self.shard_of(task_id)
        with self._locks[i]:
            t = self._tables[i]
            lease = t.get(task_id)
            ok = t.claim_start(task_id, holder, attempt, cancel, on_revoke)
            return ok, lease

    def claim_start_batch(self, items: Sequence, holder: str,
                          cancel: threading.Event,
                          on_revoke: Callable[[], None] | None = None
                          ) -> list:
        """Batched :meth:`claim_start`: ``items`` is ``[(task_id, attempt),
        ...]``; all claims landing on the same shard share one critical
        section (shards visited in ascending order). Every claim in the
        batch binds the same ``cancel``/``on_revoke`` — the caller is one
        holder starting one wave of tasks. Returns ``[(task_id, ok, lease),
        ...]`` grouped by shard."""
        n = self._n
        buckets: dict[int, list] = {}
        for item in items:
            buckets.setdefault(hash(item[0]) % n, []).append(item)
        out: list = []
        now = time.time()
        for i in sorted(buckets):
            with self._locks[i]:
                t = self._tables[i]
                leases, done = t._leases, t._done
                for task_id, attempt in buckets[i]:
                    lease = leases.get(task_id)
                    # fast path: the normal GRANTED -> RUNNING transition,
                    # with one shared timestamp for the whole wave
                    if lease is not None and lease.state == GRANTED \
                            and lease.holder == holder \
                            and lease.attempt == attempt \
                            and task_id not in done:
                        lease.state = RUNNING
                        lease.started_at = now
                        lease.cancel = cancel
                        lease.on_revoke = on_revoke
                        out.append((task_id, True, lease))
                        continue
                    # anything unusual (tombstone, fencing, revoked-ack,
                    # duplicate) takes the scalar gate
                    ok = t.claim_start(task_id, holder, attempt, cancel,
                                       on_revoke)
                    out.append((task_id, ok, lease))
        return out

    def complete(self, task_id: str, holder: str | None, attempt: int | None,
                 ok: bool) -> tuple[bool, Lease | None]:
        """The commit gate, under the task's shard lock. Returns
        ``(committed, lease)`` for out-of-lock observability."""
        i = self.shard_of(task_id)
        with self._locks[i]:
            t = self._tables[i]
            lease = t.get(task_id)
            committed = t.complete(task_id, holder, attempt, ok)
            return committed, lease

    def complete_batch(self, items: Sequence, holder: str | None,
                       ok: bool) -> list:
        """Batched :meth:`complete`: ``items`` is ``[(task_id,
        attempt|None), ...]`` sharing one wave outcome ``ok``; one critical
        section per shard touched. Each entry goes through the same commit
        gate (fencing, tombstones) as the scalar path. Returns
        ``[(task_id, committed, lease), ...]`` grouped by shard."""
        n = self._n
        buckets: dict[int, list] = {}
        for item in items:
            buckets.setdefault(hash(item[0]) % n, []).append(item)
        out: list = []
        for i in sorted(buckets):
            with self._locks[i]:
                out.extend(self._tables[i].complete_batch(buckets[i],
                                                          holder, ok))
        return out

    def revoke(self, task_id: str, reason: str,
               requeue_cb: Callable[[Lease], None] | None = None
               ) -> Lease | None:
        """Fence + cancel + (optionally) requeue in ONE critical section
        under the task's shard lock: ``requeue_cb(lease)`` runs while the
        shard lock is held, so a revoked task is never both requeued and
        completed — the same atomicity the single broker lock provided.
        The callback may produce (shard lock → partition lock is the legal
        lock order) but must not touch group state."""
        i = self.shard_of(task_id)
        with self._locks[i]:
            t = self._tables[i]
            lease = t.revoke(task_id, reason)
            if lease is not None and requeue_cb is not None:
                t.count_requeued()
                requeue_cb(lease)
            return lease

    def forget(self, task_id: str, holder: str) -> None:
        i = self.shard_of(task_id)
        with self._locks[i]:
            self._tables[i].forget(task_id, holder)

    # -- queries -----------------------------------------------------------

    def get_view(self, task_id: str) -> dict | None:
        i = self.shard_of(task_id)
        with self._locks[i]:
            lease = self._tables[i].get(task_id)
            return None if lease is None else lease.view()

    def live_views(self, task_ids=None, holder: str | None = None) -> list[dict]:
        if task_ids is not None:
            out: list[dict] = []
            for tid in task_ids:
                i = self.shard_of(tid)
                with self._locks[i]:
                    out.extend(self._tables[i].live_views([tid], holder))
            return out
        out = []
        for lock, t in zip(self._locks, self._tables):
            with lock:  # one shard at a time — never two shard locks held
                out.extend(t.live_views(None, holder))
        return out

    def stats(self) -> dict:
        t0 = self._tables[0]  # counter families are shared across shards
        out = {
            "active": 0,
            "granted": t0.granted,
            "completed": t0.completed,
            "failed": t0.failed,
            "requeued": t0.requeued,
            "stale_drops": t0.stale_drops,
            "revoked": dict(t0.revoked),
            "revoked_total": sum(t0.revoked.values()),
        }
        for lock, t in zip(self._locks, self._tables):
            with lock:
                out["active"] += sum(1 for l in t._leases.values() if l.live)
        return out
