"""Unified task-lease lifecycle — the one way work is taken back.

The control plane used to have four disjoint stop-work mechanisms: the
MonitorAgent watchdog resubmitted stale tasks, the autoscaler's graceful
drain requeued deferred leases, SimSlurm's ``scancel``/walltime fired a
``cancel_event``, and the PipelineAgent fenced late results of retried
tasks — each with its own bookkeeping and its own races. The paper's own
ClusterAgent already treats reclamation as a first-class operation ("if a
task hangs or exceeds the predefined timeout, the ClusterAgent intervenes
by canceling the associated Slurm job", §3), and both ParaFold
(arXiv:2111.06340) and the Summit proteome-scale deployment
(arXiv:2201.10024) show heterogeneous campaigns stay fast only when the
scheduler can actively take resources *back*, not just hand them out.

This module is that primitive. A :class:`Lease` is the broker-tracked
handle for one attempt of one task on one holder, with a single state
machine::

    GRANTED ──→ RUNNING ──→ DONE
       │           │    └──→ FAILED
       └───────────┴───────→ REVOKED(reason)

* **GRANTED** — the holder committed the record's offset via
  :meth:`~repro.core.broker.Broker.lease_records` (the task is its
  responsibility; it may still be waiting in a deferral queue),
* **RUNNING** — execution started (:meth:`~repro.core.broker.Broker.claim_start`
  bound the task's ``cancel_event`` so a revocation can actually stop it),
* **DONE** / **FAILED** — the holder committed its verdict through the
  :meth:`~repro.core.broker.Broker.complete_lease` gate,
* **REVOKED** — :meth:`~repro.core.broker.Broker.revoke_lease` took the
  lease back: the ``cancel_event`` fires (``check_cancel`` raises inside
  the computation), any late ``complete_lease`` from the old holder
  returns False (the commit is *fenced* — no stale result or error ever
  leaves the agent), and, when requested, the task record is requeued
  onto the topic it was leased from — all in one critical section under
  the broker lock, so a revoked task is never both requeued and completed.

Every stopper is now a caller: the agent/monitor watchdogs revoke with
``reason="watchdog"``, graceful drain flushes deferred leases with
``reason="drain"``, SimSlurm walltime/scancel policing uses
``reason="scancel"``, memory policing uses ``reason="mem_overage"``, and
the PipelineAgent's preemptive fair share revokes with
``reason="preempt"`` (journaled as a ``LeaseRevoked`` event so recovery
replays revocations exactly like completions).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

# -- lease states ------------------------------------------------------------

GRANTED = "GRANTED"
RUNNING = "RUNNING"
DONE = "DONE"
FAILED = "FAILED"
REVOKED = "REVOKED"

LIVE_STATES = (GRANTED, RUNNING)


class RevokeReason:
    """Why a lease was taken back (the ``REVOKED(reason=...)`` tag)."""

    WATCHDOG = "watchdog"        # hung / timed-out / stale-heartbeat task
    PREEMPT = "preempt"          # fair-share preemption of an over-share campaign
    MEM_OVERAGE = "mem_overage"  # task exceeded its Resources.mem_mb request
    DRAIN = "drain"              # agent leaving (autoscale shrink / stop)
    SCANCEL = "scancel"          # slurm-side stop (walltime / external scancel)

    ALL = (WATCHDOG, PREEMPT, MEM_OVERAGE, DRAIN, SCANCEL)


# how long an unacknowledged REVOKED entry is kept for commit fencing before
# the periodic sweep drops it (holders that crashed never ack)
_REVOKED_TTL_S = 120.0

# completion tombstones retained for duplicate-execution fencing (a stale
# requeued/resubmitted record of an already-accepted task must never run)
_DONE_CAP = 4096


@dataclass(frozen=True)
class LeaseTolerance:
    """WAN-tolerance policy for leases held across a slow link.

    A federated site's bridge holds home-broker leases for tasks executing
    remotely; its heartbeats cross a WAN link whose round-trip can dwarf the
    uniform watchdog deadline tuned for local workers. Instead of loosening
    every deadline to the slowest link (masking genuinely hung local tasks),
    holders registered with a tolerance get a *per-site* heartbeat deadline
    stamped onto each lease they are granted::

        deadline_s = base_timeout_s * rtt_factor + slack_s

    where ``base_timeout_s`` is the watchdog's configured deadline. The
    MonitorAgent and PipelineAgent watchdogs consult the stamped deadline
    before revoking, so a healthy remote lease behind a slow-but-alive link
    survives, while cross-site revocation still fences exactly-once
    execution when the lease really is taken back."""

    slack_s: float = 0.0     # absolute extra headroom (e.g. 2 * link RTT)
    rtt_factor: float = 1.0  # multiplier on the watchdog's base deadline

    def deadline(self, base_timeout_s: float | None) -> float | None:
        """Per-site heartbeat deadline for a given base watchdog timeout.
        None base (watchdog disabled) stays None unless slack alone is
        meaningful — a pure-slack tolerance still bounds the lease."""
        if base_timeout_s is None:
            return self.slack_s if self.slack_s > 0 else None
        return base_timeout_s * self.rtt_factor + self.slack_s


@dataclass
class Lease:
    """One attempt of one task held by one agent (broker-internal record).

    ``value`` keeps the leased record's payload so a revocation can requeue
    the task without a topic scan; ``seq`` is the broker-wide monotonic
    grant sequence (journaled observability, not a fencing token — fencing
    is by ``(holder, attempt)``)."""

    task_id: str
    holder: str
    topic: str
    attempt: int
    value: dict
    seq: int
    granted_at: float = field(default_factory=time.time)
    state: str = GRANTED
    started_at: float | None = None
    revoked_at: float | None = None
    reason: str | None = None
    cancel: threading.Event | None = None
    on_revoke: Callable[[], None] | None = None
    site: str = ""                   # holder's site ("" = broker-local)
    deadline_s: float | None = None  # per-site heartbeat deadline, if any

    @property
    def live(self) -> bool:
        return self.state in LIVE_STATES

    def view(self) -> dict:
        """JSON-safe snapshot for observability / victim selection."""
        return {
            "task_id": self.task_id,
            "holder": self.holder,
            "topic": self.topic,
            "attempt": self.attempt,
            "seq": self.seq,
            "state": self.state,
            "granted_at": self.granted_at,
            "started_at": self.started_at,
            "revoked_at": self.revoked_at,
            "reason": self.reason,
            "campaign_id": self.value.get("campaign_id"),
            "site": self.site,
            "deadline_s": self.deadline_s,
        }


class LeaseTable:
    """The broker's lease registry. **Not** thread-safe on its own — every
    method is called by :class:`~repro.core.broker.Broker` with the broker
    lock held, which is what makes revoke-vs-complete atomic."""

    def __init__(self, metrics=None) -> None:
        # counters live in the obs registry (repro.obs) so /metrics and the
        # legacy stats() dict are the same numbers; a standalone table (unit
        # tests, direct wiring) gets a private registry
        from repro.obs import MetricsRegistry
        reg = metrics if metrics is not None else MetricsRegistry()
        self._c_granted = reg.counter(
            "ksa_leases_granted_total", "Leases granted (GRANTED entered)")
        self._c_completed = reg.counter(
            "ksa_leases_completed_total", "Leases committed DONE")
        self._c_failed = reg.counter(
            "ksa_leases_failed_total", "Leases committed FAILED")
        self._c_requeued = reg.counter(
            "ksa_leases_requeued_total",
            "Revoked lease records requeued by the broker")
        self._c_stale = reg.counter(
            "ksa_lease_stale_drops_total",
            "Stale sibling records refused (grant or claim)")
        self._c_revoked = reg.counter(
            "ksa_leases_revoked_total", "Leases revoked, by reason",
            labels=("reason",))
        for r in RevokeReason.ALL:  # pre-create so stats() always lists ALL
            self._c_revoked.labels(reason=r)
        self._leases: dict[str, Lease] = {}
        # task_id -> accepted attempt: completion tombstones. Stop-path
        # requeues and watchdog resubmissions race the attempt they
        # replace; when the older attempt wins, its sibling record is
        # still on a topic and will be leased later — the tombstone makes
        # claim_start refuse it, so a finished task is never re-executed
        # (exactly-once *execution*, not just exactly-once result).
        # A deliberate rerun of a finished task id needs a higher attempt.
        self._done: dict[str, int] = {}
        self._seq = 0

    # -- counter views (registry-backed; the attribute names predate obs) --

    @property
    def granted(self) -> int:
        return self._c_granted.value

    @property
    def completed(self) -> int:
        return self._c_completed.value

    @property
    def failed(self) -> int:
        return self._c_failed.value

    @property
    def requeued(self) -> int:
        return self._c_requeued.value

    @property
    def stale_drops(self) -> int:
        return self._c_stale.value

    @property
    def revoked(self) -> dict:
        return {key[0]: child.value for key, child in self._c_revoked.items()}

    def count_requeued(self) -> None:
        """Called by the broker when it requeues a revoked lease's record."""
        self._c_requeued.inc()

    # -- lifecycle ---------------------------------------------------------

    def grant(self, task_id: str, holder: str, topic: str, attempt: int,
              value: dict, *, site: str = "",
              deadline_s: float | None = None) -> Lease | None:
        """Register a fresh GRANTED lease (replaces any stale entry for the
        task — a requeued task's new lease supersedes the fenced old one).
        A record whose attempt is *behind* a live lease is the stale
        sibling of a requeue race: it must not clobber the newer lease
        (its claim will be refused instead). ``site``/``deadline_s`` stamp
        the holder's federation site and WAN-tolerant heartbeat deadline
        (see :class:`LeaseTolerance`) onto the lease for the watchdogs."""
        cur = self._leases.get(task_id)
        if cur is not None and cur.live and cur.attempt > attempt:
            self._c_stale.inc()
            return None
        self._seq += 1
        lease = Lease(task_id=task_id, holder=holder, topic=topic,
                      attempt=attempt, value=value, seq=self._seq,
                      site=site, deadline_s=deadline_s)
        self._leases[task_id] = lease
        self._c_granted.inc()
        return lease

    def claim_start(self, task_id: str, holder: str, attempt: int,
                    cancel: threading.Event,
                    on_revoke: Callable[[], None] | None = None) -> bool:
        """GRANTED → RUNNING iff ``(holder, attempt)`` still owns an
        unrevoked lease; binds the cancel event so a later revocation can
        stop the execution. Returns False (and acks/drops a revoked or
        superseded entry) when the holder must *not* start the task."""
        if task_id in self._done:
            # the task already completed (possibly on a sibling attempt
            # that won a requeue/resubmission race): no attempt of a
            # completed task ever executes again — every resubmitter
            # (monitor, pipeline, recovery) checks terminality first, so a
            # late record here is always a stale race artifact
            lease = self._leases.get(task_id)
            if lease is not None and lease.holder == holder \
                    and lease.attempt == attempt:
                del self._leases[task_id]
            self._c_stale.inc()
            return False
        lease = self._leases.get(task_id)
        if lease is None:
            return True  # unregistered execution (direct wiring): no fencing
        if lease.holder != holder or lease.attempt != attempt:
            return False  # superseded: another holder owns the task now
        if lease.state == REVOKED:
            del self._leases[task_id]  # ack: the revocation already requeued
            return False
        if lease.state != GRANTED:
            # already RUNNING: a same-attempt duplicate record (e.g. the
            # requeued copy of a deferred lease the same agent re-leased)
            # must not start a second concurrent execution
            return False
        lease.state = RUNNING
        lease.started_at = time.time()
        lease.cancel = cancel
        lease.on_revoke = on_revoke
        return True

    def complete(self, task_id: str, holder: str | None, attempt: int | None,
                 ok: bool) -> bool:
        """The commit gate: True iff the holder may publish its verdict
        (result or error). A revoked or superseded lease returns False —
        the work was already requeued, so the stale outcome must not leave
        the agent. Terminal either way: the entry is dropped."""
        lease = self._leases.get(task_id)
        if lease is None:
            # no lease tracked: either direct wiring (no fencing) or a
            # stale sibling whose task already completed — the tombstone
            # tells the two apart
            return task_id not in self._done
        if holder is not None and lease.holder != holder:
            return False  # superseded: not this holder's lease any more
        if attempt is not None and lease.attempt != attempt:
            return False
        del self._leases[task_id]
        if lease.state == REVOKED:
            return False
        lease.state = DONE if ok else FAILED
        if ok:
            self._c_completed.inc()
            self._done[task_id] = lease.attempt
            if len(self._done) > _DONE_CAP:
                self._done.pop(next(iter(self._done)))
        else:
            self._c_failed.inc()
        return True

    def revoke(self, task_id: str, reason: str) -> Lease | None:
        """Take a live lease back: fire the cancel event (and the holder's
        ``on_revoke`` hook, e.g. ``scancel``), tag the reason, and return
        the lease so the broker can requeue its record in the same critical
        section. None if there is nothing live to revoke (already terminal,
        unknown, or mid-completion — the race the gate exists for)."""
        lease = self._leases.get(task_id)
        if lease is None or not lease.live:
            return None
        lease.state = REVOKED
        lease.reason = reason
        lease.revoked_at = time.time()
        self._c_revoked.labels(reason=reason).inc()
        if lease.cancel is not None:
            lease.cancel.set()
        if lease.on_revoke is not None:
            try:
                lease.on_revoke()
            except Exception:  # pragma: no cover - defensive
                pass
        self._sweep(lease.revoked_at)
        return lease

    def forget(self, task_id: str, holder: str) -> None:
        """Drop a lease the holder gave up without executing (reroute of a
        misplaced task — the rerouted record grants a fresh lease)."""
        lease = self._leases.get(task_id)
        if lease is not None and lease.holder == holder:
            del self._leases[task_id]

    def _sweep(self, now: float) -> None:
        """GC revoked entries whose (dead) holder will never ack."""
        stale = [t for t, l in self._leases.items()
                 if l.state == REVOKED and l.revoked_at is not None
                 and now - l.revoked_at > _REVOKED_TTL_S]
        for t in stale:
            del self._leases[t]

    # -- queries -----------------------------------------------------------

    def get(self, task_id: str) -> Lease | None:
        return self._leases.get(task_id)

    def live_views(self, task_ids=None, holder: str | None = None) -> list[dict]:
        out = []
        leases = ([self._leases.get(t) for t in task_ids]
                  if task_ids is not None else list(self._leases.values()))
        for lease in leases:
            if lease is None or not lease.live:
                continue
            if holder is not None and lease.holder != holder:
                continue
            out.append(lease.view())
        return out

    def stats(self) -> dict:
        return {
            "active": sum(1 for l in self._leases.values() if l.live),
            "granted": self.granted,
            "completed": self.completed,
            "failed": self.failed,
            "requeued": self.requeued,
            "stale_drops": self.stale_drops,
            "revoked": dict(self.revoked),
            "revoked_total": sum(self.revoked.values()),
        }
