"""The user-facing computation API — the paper's ``ClusterComputing`` class.

Paper §5 / Fig. 3: "The script has to contain a class that extends the
built-in ClusterComputing class … parameters … will be serialized in the Kafka
message and then … read and made available as configuration parameters of the
task."  Users override :meth:`run`, read ``self.params``, and may call
:meth:`send_status` at any point ("computing scripts can also send status
updates at any moment of the computing process") and :meth:`send_results` /
automatic result forwarding on completion.

A registry maps ``script`` names in :class:`~repro.core.messages.TaskMessage`
to ``ClusterComputing`` subclasses so agents can instantiate them in-process
(the container analogue of KSA launching a Python script as a Slurm job).
"""
from __future__ import annotations

import threading
import time
import traceback
from typing import Any, Callable, Type

from repro.obs import sample_rss_mb

from .broker import Broker, Producer
from .messages import (ErrorMessage, ResultMessage, StatusUpdate, TaskMessage,
                       TaskStatus, topic_names)


class TaskCancelled(Exception):
    """Raised inside a task when the agent's watchdog cancels it."""


class ClusterComputing:
    """Base class for user computations (paper Fig. 3).

    Subclasses override :meth:`run` and return a JSON-serializable result.
    ``self.params`` holds the deserialized task parameters; ``self.check_cancel()``
    cooperatively honours watchdog cancellation (the paper's ClusterAgent
    ``scancel``\\ s hung jobs — in-process tasks must observe the event).
    """

    def __init__(self, task: TaskMessage, producer: Producer, prefix: str,
                 agent_id: str, cancel_event: threading.Event | None = None,
                 commit: Callable[[bool], bool] | None = None):
        self.task = task
        self.task_id = task.task_id
        self.params: dict = task.params
        self.attempt = task.attempt
        self._producer = producer
        self._topics = topic_names(prefix)
        self.agent_id = agent_id
        self._cancel = cancel_event or threading.Event()
        # the lease commit gate (Broker.complete_lease via the agent): the
        # verdict may only be published while the lease is unrevoked — a
        # revoked lease's task was already requeued, so a late result or
        # error from this holder must be suppressed, not fenced downstream.
        self._commit_cb = commit
        # mem-overage policing input (the agent samples mem_used_mb against
        # Resources.mem_mb each watchdog tick). Default: kernel-accounted
        # RSS *growth* since this task started (repro.obs.sample_rss_mb) —
        # a delta, because in-process tasks share the interpreter whose
        # baseline footprint is not this task's doing. report_mem() remains
        # as an explicit override for scripts that track their own usage.
        self._mem_reported: float | None = None
        self._rss_baseline_mb: float = sample_rss_mb()

    # -- API used by subclasses ------------------------------------------------

    def run(self) -> Any:  # pragma: no cover - abstract
        raise NotImplementedError

    def send_status(self, status: str | TaskStatus, **info: Any) -> None:
        upd = StatusUpdate(task_id=self.task_id,
                           status=str(getattr(status, "value", status)),
                           agent_id=self.agent_id, attempt=self.attempt,
                           info=info)
        self._producer.send(self._topics["jobs"], upd.to_dict(),
                            key=self.task_id)

    def send_results(self, result: dict, elapsed_s: float = 0.0) -> None:
        msg = ResultMessage(task_id=self.task_id, agent_id=self.agent_id,
                            result=result, attempt=self.attempt,
                            elapsed_s=elapsed_s)
        self._producer.send(self._topics["done"], msg.to_dict(),
                            key=self.task_id)

    def check_cancel(self) -> None:
        if self._cancel.is_set():
            raise TaskCancelled(self.task_id)

    @property
    def cancelled(self) -> bool:
        return self._cancel.is_set()

    @property
    def mem_used_mb(self) -> float:
        """Resident memory (MB) charged to this task: the explicit
        :meth:`report_mem` value when set, else the process RSS growth since
        the task was constructed (kernel-accounted via ``/proc/self/status``,
        so a misbehaving task cannot hide by simply not reporting)."""
        if self._mem_reported is not None:
            return self._mem_reported
        return max(0.0, sample_rss_mb() - self._rss_baseline_mb)

    @mem_used_mb.setter
    def mem_used_mb(self, mem_mb: float) -> None:
        self._mem_reported = float(mem_mb)

    def report_mem(self, mem_mb: float) -> None:
        """Report the task's current resident memory, overriding the RSS
        sampler. Long-running scripts that track their own usage (structure
        batches, feature caches) should call this so the agent's
        mem-overage policing can compare usage against the task's
        ``Resources.mem_mb`` request and revoke the lease instead of
        letting one task blow the pool budget."""
        self._mem_reported = float(mem_mb)

    def _commit(self, ok: bool) -> bool:
        """Commit the verdict through the lease gate; False = fenced."""
        if self._commit_cb is None:
            return True
        return self._commit_cb(ok)

    # -- driver used by agents ---------------------------------------------------

    def execute(self) -> bool:
        """Full lifecycle: RUNNING → run() → DONE + result (or ERROR).
        Returns True on success. Every verdict passes the lease commit gate
        first: if the lease was revoked mid-run, the (already requeued)
        task's stale result/error is suppressed and only a REVOKED status
        is emitted."""
        t0 = time.time()
        self.send_status(TaskStatus.RUNNING)
        try:
            result = self.run()
            self.check_cancel()
        except TaskCancelled:
            if not self._commit(False):
                # the cancel came from a lease revocation: the revoker
                # already owns redelivery (requeue or journaled retry), so
                # the monitor must not treat this as a recoverable CANCELLED
                self.send_status(TaskStatus.REVOKED)
            else:
                self.send_status(TaskStatus.CANCELLED)
            return False
        except Exception as exc:  # noqa: BLE001 - error flow is a feature
            if not self._commit(False):
                self.send_status(TaskStatus.REVOKED, error=repr(exc))
                return False
            err = ErrorMessage(task_id=self.task_id, agent_id=self.agent_id,
                               error=repr(exc), traceback=traceback.format_exc(),
                               attempt=self.attempt)
            self._producer.send(self._topics["error"], err.to_dict(),
                                key=self.task_id)
            self.send_status(TaskStatus.ERROR, error=repr(exc))
            return False
        elapsed = time.time() - t0
        if not self._commit(True):
            self.send_status(TaskStatus.REVOKED, elapsed_s=elapsed)
            return False
        if not isinstance(result, dict):
            result = {"value": result}
        self.send_results(result, elapsed_s=elapsed)
        self.send_status(TaskStatus.DONE, elapsed_s=elapsed)
        return True


# --------------------------------------------------------------------------
# Script registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, Type[ClusterComputing]] = {}


def register_script(name: str) -> Callable[[Type[ClusterComputing]], Type[ClusterComputing]]:
    def deco(cls: Type[ClusterComputing]) -> Type[ClusterComputing]:
        _REGISTRY[name] = cls
        return cls
    return deco


def resolve_script(name: str) -> Type[ClusterComputing]:
    if name not in _REGISTRY:
        raise KeyError(f"no ClusterComputing registered for script={name!r}; "
                       f"known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def registered_scripts() -> list[str]:
    return sorted(_REGISTRY)


@register_script("sleep")
class SleepComputing(ClusterComputing):
    """Trivial built-in task used by tests and latency benchmarks."""

    def run(self) -> Any:
        duration = float(self.params.get("duration", 0.01))
        deadline = time.time() + duration
        while time.time() < deadline:
            self.check_cancel()
            time.sleep(min(0.005, max(deadline - time.time(), 0.0)))
        return {"slept": duration}


@register_script("fail")
class FailComputing(ClusterComputing):
    """Built-in task that fails N times then succeeds — exercises the
    error flow + redelivery (at-least-once) machinery."""

    _counts: dict[str, int] = {}
    _lock = threading.Lock()

    def run(self) -> Any:
        fail_times = int(self.params.get("fail_times", 1))
        with self._lock:
            seen = self._counts.get(self.task_id, 0)
            self._counts[self.task_id] = seen + 1
        if seen < fail_times:
            raise RuntimeError(f"induced failure {seen + 1}/{fail_times}")
        return {"succeeded_after": seen}


@register_script("hang")
class HangComputing(ClusterComputing):
    """Hangs until cancelled — exercises the watchdog (paper: "if a task
    hangs or exceeds the predefined timeout, the ClusterAgent intervenes")."""

    def run(self) -> Any:
        while True:
            self.check_cancel()
            time.sleep(0.005)


@register_script("memhog")
class MemHogComputing(ClusterComputing):
    """Reports a resident set that overshoots the task's request —
    exercises mem-overage lease revocation. ``peak_mb`` is the reported
    RSS; from attempt ``calm_after_attempt`` onward the task behaves and
    stays at its requested budget (so a revoked-and-requeued hog can be
    observed completing on a later attempt)."""

    def run(self) -> Any:
        duration = float(self.params.get("duration", 0.3))
        peak = float(self.params.get("peak_mb", 0.0))
        calm_after = int(self.params.get("calm_after_attempt", 1))
        misbehave = self.attempt < calm_after
        deadline = time.time() + duration
        while time.time() < deadline:
            self.check_cancel()
            if misbehave:
                self.report_mem(peak)
            time.sleep(0.005)
        return {"attempt": self.attempt, "peak_mb": self.mem_used_mb}
