"""repro.pipeline — DAG campaign orchestration over the KSA control plane.

The paper's production workloads are multi-stage *campaigns*, not flat task
bags: AlphaKnot 2.0 (§4) runs structure ingest → HOMFLY-PT screening → knot
localization over millions of AlphaFold models, with each stage exhibiting a
different resource profile. This subsystem turns the broker/agent machinery
of §3 into a campaign engine, following the heterogeneous-stage split of
ParaFold (arXiv:2111.06340, CPU featurize vs GPU predict) and the
fan-out/fan-in orchestration of the Summit proteome-scale deployment
(arXiv:2201.10024).

Class → paper mapping:

* :class:`~repro.pipeline.spec.Stage` / :class:`~repro.pipeline.spec.PipelineSpec`
  — declarative DAG of registered ``ClusterComputing`` scripts (§5, Fig. 3),
  with per-stage ``Resources`` (§5's CPU/GPU/memory request, used here to
  route stages to differently-equipped pools), fan-out batching (§4's
  "batches of 4,000 structures"), join barriers, and retry/timeout policy.
* :class:`~repro.pipeline.spec.RetryPolicy` — bounds the at-least-once
  resubmission loop (§3's watchdog + the safe-multiple-attempts extension
  the paper lists as future work).
* :class:`~repro.pipeline.state.CampaignState` — the **event-sourced core**:
  campaign progress is a pure reducer folding a typed journal
  (``CampaignSubmitted`` / ``StageDispatched`` / ``LeaseGranted`` /
  ``TaskDone`` / ``TaskFailed`` / ``StageSkipped`` / ``BarrierReleased``)
  written ahead of every action to the ``PREFIX-campaigns`` topic. DAG
  semantics are therefore deterministic, broker-free unit-testable, and —
  crucially — recoverable: an orchestrator ``kill -9`` mid-campaign is
  resumed by folding the journal back (:meth:`PipelineAgent.recover` /
  ``KsaCluster.recover()``).
* :class:`~repro.pipeline.agent.PipelineAgent` — the thin executor over that
  log, and a peer of the MonitorAgent (§3): subscribes to
  ``PREFIX-done``/``PREFIX-error``, journals + folds events, submits leased
  tasks, fences duplicate results by first-wins per task so a barrier never
  double-fires, enforces per-stage ``max_in_flight`` backpressure, arbitrates
  concurrent campaigns through a
  :class:`~repro.core.scheduling.LeasePolicy` (FairShare weighted
  round-robin by default; per-campaign ``weight=`` at submit), honours
  ``Stage.skip_when`` conditional edges (skips cascade, are journaled, and
  count toward completion), and publishes progress snapshots on
  ``PREFIX-campaigns`` alongside the journal.

Campaigns are normally driven through :class:`repro.cluster.KsaCluster`
(``c.run_campaign(spec, items)`` / ``c.recover(specs)``), which wires the
pipeline agent to the same broker, prefix, and placement policy as the
execution pools.
* :class:`~repro.pipeline.status.CampaignStatus` /
  :class:`~repro.pipeline.status.StageStatus` — the campaign-level analogue of
  §3's task status table, surfaced via the MonitorAgent REST API
  (``/campaigns``).
* :func:`~repro.pipeline.driver.run_campaign` — the synchronous submit-and-wait
  front-end matching the paper's §5 submission scripts.
"""
from .agent import PipelineAgent, PipelineError
from .driver import CampaignResult, run_campaign
from .spec import PipelineSpec, RetryPolicy, SpecError, Stage
from .state import (BarrierReleased, CampaignSnapshot, CampaignState,
                    CampaignSubmitted, JournalEvent, LeaseGranted,
                    LeaseRevoked, StageDispatched, StageSkipped, TaskDone,
                    TaskFailed)
from .status import CampaignStatus, StageStatus

__all__ = [
    "BarrierReleased", "CampaignResult", "CampaignSnapshot", "CampaignState",
    "CampaignStatus",
    "CampaignSubmitted", "JournalEvent", "LeaseGranted", "LeaseRevoked",
    "PipelineAgent",
    "PipelineError", "PipelineSpec", "RetryPolicy", "SpecError", "Stage",
    "StageDispatched", "StageSkipped", "StageStatus", "TaskDone",
    "TaskFailed", "run_campaign",
]
