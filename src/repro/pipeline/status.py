"""Per-campaign / per-stage progress *views*.

These counters are the campaign-level analogue of the paper's per-task status
table (§3). Since the event-sourcing refactor the source of truth is the
:class:`~repro.pipeline.state.CampaignState` reducer (folded from the
``PREFIX-campaigns`` journal); the :class:`StageStatus` counters live inside
it and :class:`CampaignStatus` is the snapshot the agent publishes on
``PREFIX-campaigns`` and the MonitorAgent mirrors into its REST API
(``/campaigns``). The ``RUNNING`` / ``COMPLETED`` / ``FAILED`` phase
constants moved to ``CampaignState`` in :mod:`repro.pipeline.state`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Mapping

_TERMINAL = ("COMPLETED", "FAILED")


@dataclasses.dataclass
class StageStatus:
    """Progress counters for one stage of one campaign.

    ``expected`` is fixed at submit time (source = #batches, map = 1:1 with
    upstream, join = 1); ``submitted``/``done``/``failed`` advance as the DAG
    executes; ``retried`` counts watchdog/error resubmissions;
    ``duplicates`` counts fenced duplicate results (late attempts);
    ``skipped`` counts tasks short-circuited by the stage's ``skip_when``
    conditional-edge predicate (they count toward completion — a fully
    skipped stage finishes the campaign instead of stalling it);
    ``revoked`` counts journaled lease revocations (``LeaseRevoked``, e.g.
    fair-share preemption) and ``revoke_pending`` how many of those are
    back in the ready queue awaiting a regrant — they no longer hold a
    slot, so they are excluded from ``in_flight``."""

    name: str
    script: str
    expected: int = 0
    submitted: int = 0
    done: int = 0
    failed: int = 0
    retried: int = 0
    duplicates: int = 0
    errors: int = 0
    skipped: int = 0
    revoked: int = 0
    revoke_pending: int = 0

    @property
    def in_flight(self) -> int:
        return max(0, self.submitted - self.done - self.failed
                   - self.revoke_pending)

    @property
    def complete(self) -> bool:
        return self.expected > 0 and self.done + self.skipped >= self.expected

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["in_flight"] = self.in_flight
        d["complete"] = self.complete
        return d


@dataclasses.dataclass
class CampaignStatus:
    campaign_id: str
    pipeline: str
    state: str = "RUNNING"
    stages: dict[str, StageStatus] = dataclasses.field(default_factory=dict)
    started_at: float = dataclasses.field(default_factory=time.time)
    finished_at: float | None = None
    failure: str | None = None
    preemptions: int = 0  # fair-share lease revocations this campaign took

    @property
    def done(self) -> bool:
        return self.state in _TERMINAL

    def progress(self) -> float:
        total = sum(s.expected for s in self.stages.values())
        if total == 0:
            return 0.0
        return sum(s.done + s.skipped for s in self.stages.values()) / total

    def elapsed_s(self) -> float:
        end = self.finished_at if self.finished_at is not None else time.time()
        return end - self.started_at

    def to_dict(self) -> dict:
        return {
            "campaign_id": self.campaign_id,
            "pipeline": self.pipeline,
            "state": self.state,
            "progress": round(self.progress(), 4),
            "elapsed_s": round(self.elapsed_s(), 3),
            "failure": self.failure,
            "preemptions": self.preemptions,
            "stages": {n: s.to_dict() for n, s in self.stages.items()},
        }

    @classmethod
    def from_snapshot(cls, d: Mapping[str, Any]) -> "CampaignStatus":
        """Rebuild from a ``to_dict`` snapshot (monitor-side mirroring)."""
        st = cls(campaign_id=d["campaign_id"], pipeline=d.get("pipeline", ""),
                 state=d.get("state", "RUNNING"),
                 preemptions=int(d.get("preemptions", 0)))
        for name, sd in d.get("stages", {}).items():
            st.stages[name] = StageStatus(
                name=name, script=sd.get("script", ""),
                expected=int(sd.get("expected", 0)),
                submitted=int(sd.get("submitted", 0)),
                done=int(sd.get("done", 0)),
                failed=int(sd.get("failed", 0)),
                retried=int(sd.get("retried", 0)),
                duplicates=int(sd.get("duplicates", 0)),
                errors=int(sd.get("errors", 0)),
                skipped=int(sd.get("skipped", 0)),
                revoked=int(sd.get("revoked", 0)),
                revoke_pending=int(sd.get("revoke_pending", 0)))
        return st
