"""Event-sourced campaign state — the journal schema and the pure reducer.

The PipelineAgent used to keep all DAG progress in mutable in-memory
structures, so an orchestrator crash mid-campaign orphaned every in-flight
task (the durability gap ROADMAP names; proteome-scale deployments such as
the Summit workflows, arXiv:2201.10024, and ParaFold, arXiv:2111.06340, show
multi-day campaigns are only viable when the *workflow state* is restartable,
not just the workers). This module makes campaign progress a fold over a
typed event log:

* **Journal events** — the write-ahead log entries appended to the
  ``PREFIX-campaigns`` topic *before* the agent acts on them:

  - :class:`CampaignSubmitted` — a campaign exists (items, params, weight),
  - :class:`StageDispatched` — one task of a stage was planned (ready to
    submit); carries the task's extra params (batch / upstream payload),
  - :class:`LeaseGranted` — a planned task was granted ``-new`` capacity by
    the lease policy (one event per submission, initial and retries — the
    retry budget is therefore journaled, not agent memory),
  - :class:`TaskDone` / :class:`TaskFailed` — a terminal (or, for
    ``final=False``, a to-be-retried) verdict for one task,
  - :class:`LeaseRevoked` — a running lease was taken back
    (``Broker.revoke_lease``; reason ``"preempt"`` for fair-share
    preemption) and the task returned to its stage's ready queue awaiting
    a regrant — replayed by recovery exactly like completions, so a crash
    between a revocation and its regrant loses nothing,
  - :class:`StageSkipped` — a conditional edge (``Stage.skip_when``)
    short-circuited one task; skips recorded here never re-run predicates
    during replay,
  - :class:`BarrierReleased` — a join barrier fired (followed by the join
    task's own ``StageDispatched`` / ``StageSkipped``),
  - :class:`CampaignSnapshot` — a full fold of one (terminal) campaign in a
    single record, appended by :meth:`PipelineAgent.compact`; applying it
    replaces everything folded before it, which is what lets compaction
    truncate the campaign's per-event history off the topic.

* :class:`CampaignState` — the pure reducer. ``fold(spec, events)`` rebuilds
  the exact campaign progress from a journal; ``apply`` is idempotent per
  event (duplicate suffixes from at-least-once delivery are no-ops), so
  ``fold(events) == fold(events + dup_suffix)``.

* **Decide functions** — :func:`plan_sources` and :func:`plan_downstream`
  are pure ``state -> [events]`` planners (the classic event-sourcing
  decide/apply split). The agent journals what they return and folds it;
  recovery re-runs them as a *repair pass* so a crash between journal writes
  (e.g. a ``TaskDone`` persisted but its downstream ``StageDispatched``
  lost) leaves no gap. Both are guarded so re-planning is idempotent.

Because the reducer is pure (no broker, no clock, no threads), DAG semantics
— barrier single-fire, skip cascades, retry budgets — are unit-testable
deterministically without any control-plane wiring.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping, Sequence

from .spec import PipelineSpec
from .status import StageStatus

JOURNAL_KIND = "journal"


# --------------------------------------------------------------------------
# Journal events (wire schema on PREFIX-campaigns)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class JournalEvent:
    """Base journal entry. ``seq`` is the per-campaign monotonic sequence
    number (the dedupe key for at-least-once journal delivery); ``-1`` marks
    an event that has not been stamped by an agent yet."""

    campaign_id: str
    seq: int = -1
    ts: float = 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        data = {k: d.pop(k) for k in list(d)
                if k not in ("campaign_id", "seq", "ts")}
        return {"kind": JOURNAL_KIND, "type": type(self).__name__,
                "campaign_id": self.campaign_id, "seq": self.seq,
                "ts": self.ts, "data": data}


@dataclasses.dataclass(frozen=True)
class CampaignSubmitted(JournalEvent):
    pipeline: str = ""
    items: tuple = ()
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    weight: float = 1.0


@dataclasses.dataclass(frozen=True)
class StageDispatched(JournalEvent):
    stage: str = ""
    task_id: str = ""
    index: int = 0
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    dep_ids: tuple = ()


@dataclasses.dataclass(frozen=True)
class StageSkipped(JournalEvent):
    stage: str = ""
    task_id: str = ""
    index: int = 0
    dep_ids: tuple = ()


@dataclasses.dataclass(frozen=True)
class BarrierReleased(JournalEvent):
    stage: str = ""


@dataclasses.dataclass(frozen=True)
class LeaseGranted(JournalEvent):
    task_id: str = ""
    attempt: int = 0


@dataclasses.dataclass(frozen=True)
class LeaseRevoked(JournalEvent):
    """A granted/running lease was revoked (the task goes back to ready).
    Not a failure: the retry budget is untouched; ``reason`` follows
    :class:`repro.core.lease.RevokeReason` (``"preempt"`` counts toward the
    campaign's ``RetryPolicy.max_preemptions`` bound)."""

    task_id: str = ""
    reason: str = "preempt"


@dataclasses.dataclass(frozen=True)
class TaskDone(JournalEvent):
    task_id: str = ""
    result: Mapping[str, Any] | None = None


@dataclasses.dataclass(frozen=True)
class TaskFailed(JournalEvent):
    task_id: str = ""
    reason: str = ""
    cause: str = "error"        # "error" | "timeout"
    final: bool = False         # True: retry budget exhausted -> FAILED


@dataclasses.dataclass(frozen=True)
class CampaignSnapshot(JournalEvent):
    """A full fold of one campaign's journal in a single record, written by
    :meth:`~repro.pipeline.agent.PipelineAgent.compact` for terminal
    campaigns. Applying it **replaces** whatever state was folded so far, so
    ``fold(prefix + [snapshot])`` equals ``fold(full_history)`` even after
    the prefix has been truncated off the topic — the journal-compaction
    contract that keeps the ``-campaigns`` topic bounded over a stream of
    campaigns. ``tasks`` carries :class:`TaskRecord` dicts in per-stage
    creation order (results included, so an evicted campaign rebuilt from
    its snapshot still answers ``results()``)."""

    pipeline: str = ""
    state: str = "RUNNING"
    failure: str | None = None
    items: tuple = ()
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    weight: float = 1.0
    started_at: float = 0.0
    finished_at: float | None = None
    stages: Mapping[str, Mapping[str, Any]] = \
        dataclasses.field(default_factory=dict)
    tasks: tuple = ()
    joins_fired: tuple = ()
    preemptions: int = 0


def snapshot_event(state: "CampaignState") -> CampaignSnapshot:
    """Build the (unstamped) snapshot record folding ``state``."""
    stages = {}
    for n, ss in state.stages.items():
        d = ss.to_dict()
        for k in ("in_flight", "complete", "duplicates", "name", "script"):
            d.pop(k, None)  # derived / respawned / observability-only
        stages[n] = d
    tasks = tuple(state.tasks[tid].to_dict()
                  for n in state.by_stage for tid in state.by_stage[n])
    return CampaignSnapshot(
        campaign_id=state.campaign_id, pipeline=state.pipeline,
        state=state.state, failure=state.failure, items=tuple(state.items),
        params=dict(state.params), weight=state.weight,
        started_at=state.started_at, finished_at=state.finished_at,
        stages=stages, tasks=tasks,
        joins_fired=tuple(sorted(state.joins_fired)),
        preemptions=state.preemptions)


EVENT_TYPES: dict[str, type] = {
    cls.__name__: cls
    for cls in (CampaignSubmitted, StageDispatched, StageSkipped,
                BarrierReleased, LeaseGranted, LeaseRevoked, TaskDone,
                TaskFailed, CampaignSnapshot)
}


def is_journal_record(value: Mapping[str, Any]) -> bool:
    """Distinguish journal entries from CampaignEvent progress snapshots on
    the shared ``PREFIX-campaigns`` topic."""
    return value.get("kind") == JOURNAL_KIND and value.get("type") in EVENT_TYPES


def event_from_dict(value: Mapping[str, Any]) -> JournalEvent:
    cls = EVENT_TYPES[value["type"]]
    data = dict(value.get("data", {}))
    # msgpack round-trips tuples as lists; restore the frozen-field shapes
    for k in ("items", "dep_ids", "joins_fired", "tasks"):
        if k in data and isinstance(data[k], list):
            data[k] = tuple(data[k])
    return cls(campaign_id=value["campaign_id"], seq=int(value.get("seq", -1)),
               ts=float(value.get("ts", 0.0)), **data)


# --------------------------------------------------------------------------
# Task records + the reducer
# --------------------------------------------------------------------------


@dataclasses.dataclass
class TaskRecord:
    """One planned task of one stage (all attempts share this record)."""

    task_id: str
    stage: str
    index: int                      # creation order within the stage
    params: dict = dataclasses.field(default_factory=dict)
    dep_ids: tuple = ()
    attempts: int = 0               # journaled submissions (LeaseGranted)
    done: bool = False
    failed: bool = False
    skipped: bool = False           # conditional edge: never submitted
    revokes: int = 0                # journaled LeaseRevoked events
    revoke_pending: bool = False    # revoked, back in ready, not regranted
    result: dict | None = None

    @property
    def terminal(self) -> bool:
        return self.done or self.failed or self.skipped

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class CampaignState:
    """Pure reducer over the journal of one campaign.

    Also carries the campaign-phase constants (``RUNNING`` / ``COMPLETED`` /
    ``FAILED``) that used to live in ``pipeline.status`` — one name for both
    the state machine and its vocabulary. Mutating entry points are
    :meth:`apply` (one event, idempotent) and :meth:`fold` (a whole journal);
    :meth:`count_duplicate` is the one non-journaled mutation (a fenced
    duplicate result is observability, not domain state — the counter resets
    to zero on replay).
    """

    RUNNING = "RUNNING"
    COMPLETED = "COMPLETED"
    FAILED = "FAILED"

    def __init__(self, spec: PipelineSpec, campaign_id: str):
        self.spec = spec
        self.campaign_id = campaign_id
        self.pipeline = spec.name
        self.state = self.RUNNING
        self.failure: str | None = None
        self.started_at: float = 0.0
        self.finished_at: float | None = None
        self.items: list = []
        self.params: dict = {}
        self.weight: float = 1.0
        self.stages: dict[str, StageStatus] = {}
        self.tasks: dict[str, TaskRecord] = {}
        self.by_stage: dict[str, list[str]] = {}
        self.ready: dict[str, list[str]] = {}
        self.joins_fired: set[str] = set()
        self.preemptions = 0              # journaled reason="preempt" revokes
        self.seq = -1                     # highest applied journal seq
        # derived index: (upstream_task_id, stage) pairs already planned —
        # what makes plan_downstream() repair-idempotent without O(n^2) scans
        self._mapped: set[tuple[str, str]] = set()

    # -- queries -----------------------------------------------------------

    @property
    def done(self) -> bool:
        return self.state in (self.COMPLETED, self.FAILED)

    @property
    def initialized(self) -> bool:
        return bool(self.stages)

    def stage_complete(self, name: str) -> bool:
        return self.stages[name].complete

    # -- the fold ----------------------------------------------------------

    @classmethod
    def fold(cls, spec: PipelineSpec, campaign_id: str,
             events: Iterable[JournalEvent]) -> "CampaignState":
        st = cls(spec, campaign_id)
        for ev in events:
            st.apply(ev)
        return st

    def apply(self, ev: JournalEvent) -> bool:
        """Fold one event; returns whether it changed state. Idempotent both
        by ``seq`` (stamped events at or below the high-water mark are
        skipped) and semantically (re-applying an unstamped event is a
        no-op), so a duplicated journal suffix folds to the same state."""
        if ev.seq >= 0:
            if ev.seq <= self.seq:
                return False
            self.seq = ev.seq
        if not self.initialized and \
                not isinstance(ev, (CampaignSubmitted, CampaignSnapshot)):
            # truncated head (journal compaction cut mid-history): events
            # before the campaign's creation record are uninterpretable —
            # skip them; the snapshot that follows restores state wholesale
            return False
        handler = getattr(self, f"_apply_{type(ev).__name__}")
        return handler(ev)

    def _apply_CampaignSubmitted(self, ev: CampaignSubmitted) -> bool:
        if self.initialized:
            return False
        self.pipeline = ev.pipeline or self.spec.name
        self.items = list(ev.items)
        self.params = dict(ev.params)
        self.weight = float(ev.weight)
        self.started_at = ev.ts
        expected = self.spec.expected_counts(len(self.items))
        for st in self.spec.topological():
            self.stages[st.name] = StageStatus(
                name=st.name, script=st.script, expected=expected[st.name])
            self.by_stage[st.name] = []
            self.ready[st.name] = []
        return True

    def _plan(self, stage: str, task_id: str, index: int, params: Mapping,
              dep_ids: Sequence[str], skipped: bool) -> TaskRecord | None:
        if task_id in self.tasks:
            return None
        rec = TaskRecord(task_id=task_id, stage=stage, index=index,
                         params=dict(params), dep_ids=tuple(dep_ids),
                         skipped=skipped)
        self.tasks[task_id] = rec
        self.by_stage[stage].append(task_id)
        for dep in rec.dep_ids:
            self._mapped.add((dep, stage))
        return rec

    def _apply_StageDispatched(self, ev: StageDispatched) -> bool:
        rec = self._plan(ev.stage, ev.task_id, ev.index, ev.params,
                         ev.dep_ids, skipped=False)
        if rec is None:
            return False
        self.ready[ev.stage].append(ev.task_id)
        return True

    def _apply_StageSkipped(self, ev: StageSkipped) -> bool:
        rec = self._plan(ev.stage, ev.task_id, ev.index, {}, ev.dep_ids,
                         skipped=True)
        if rec is None:
            return False
        self.stages[ev.stage].skipped += 1
        self._maybe_complete(ev.ts)
        return True

    def _apply_BarrierReleased(self, ev: BarrierReleased) -> bool:
        if ev.stage in self.joins_fired:
            return False
        self.joins_fired.add(ev.stage)
        return True

    def _apply_LeaseGranted(self, ev: LeaseGranted) -> bool:
        rec = self.tasks.get(ev.task_id)
        if rec is None or rec.terminal or ev.attempt < rec.attempts:
            return False
        rec.attempts = ev.attempt + 1
        ss = self.stages[rec.stage]
        if ev.attempt == 0:
            ss.submitted += 1
        else:
            ss.retried += 1
        self._clear_revoke_pending(rec)
        try:
            self.ready[rec.stage].remove(ev.task_id)
        except ValueError:
            pass
        return True

    def _apply_LeaseRevoked(self, ev: LeaseRevoked) -> bool:
        rec = self.tasks.get(ev.task_id)
        if rec is None or rec.terminal or rec.attempts == 0 \
                or rec.revoke_pending or self.done:
            return False
        rec.revokes += 1
        rec.revoke_pending = True
        ss = self.stages[rec.stage]
        ss.revoked += 1
        ss.revoke_pending += 1
        if ev.reason == "preempt":
            self.preemptions += 1
        # back of the ready queue: the lease pump regrants it under the
        # normal fair-share arbitration (journaled as a fresh LeaseGranted)
        self.ready[rec.stage].append(ev.task_id)
        return True

    def _clear_revoke_pending(self, rec: TaskRecord) -> None:
        if rec.revoke_pending:
            rec.revoke_pending = False
            ss = self.stages[rec.stage]
            ss.revoke_pending = max(0, ss.revoke_pending - 1)
            # a pending task sits in its ready queue awaiting a regrant; a
            # terminal verdict arriving first must pull it back out so the
            # pump can never grant a finished task
            try:
                self.ready[rec.stage].remove(rec.task_id)
            except ValueError:
                pass

    def _apply_TaskDone(self, ev: TaskDone) -> bool:
        rec = self.tasks.get(ev.task_id)
        if rec is None or rec.terminal or self.done:
            return False
        rec.done = True
        rec.result = dict(ev.result) if ev.result is not None else None
        self._clear_revoke_pending(rec)
        self.stages[rec.stage].done += 1
        self._maybe_complete(ev.ts)
        return True

    def _apply_TaskFailed(self, ev: TaskFailed) -> bool:
        rec = self.tasks.get(ev.task_id)
        if rec is None or rec.terminal:
            return False
        ss = self.stages[rec.stage]
        if ev.cause == "error":
            ss.errors += 1
        if ev.final:
            rec.failed = True
            self._clear_revoke_pending(rec)
            ss.failed += 1
            self.state = self.FAILED
            self.failure = ev.reason
            self.finished_at = ev.ts
        return True

    def _apply_CampaignSnapshot(self, ev: CampaignSnapshot) -> bool:
        """Wholesale restore: a snapshot *replaces* everything folded so far
        (which may be nothing, or a truncated — and therefore meaningless —
        prefix of the original history)."""
        self.pipeline = ev.pipeline or self.spec.name
        self.state = ev.state
        self.failure = ev.failure
        self.items = list(ev.items)
        self.params = dict(ev.params)
        self.weight = float(ev.weight)
        self.started_at = float(ev.started_at)
        self.finished_at = ev.finished_at
        self.stages = {}
        self.tasks = {}
        self.by_stage = {}
        self.ready = {}
        self._mapped = set()
        for st in self.spec.topological():
            sd = dict(ev.stages.get(st.name, {}))
            self.stages[st.name] = StageStatus(
                name=st.name, script=st.script,
                expected=int(sd.get("expected", 0)),
                submitted=int(sd.get("submitted", 0)),
                done=int(sd.get("done", 0)),
                failed=int(sd.get("failed", 0)),
                retried=int(sd.get("retried", 0)),
                errors=int(sd.get("errors", 0)),
                skipped=int(sd.get("skipped", 0)),
                revoked=int(sd.get("revoked", 0)),
                revoke_pending=int(sd.get("revoke_pending", 0)))
            self.by_stage[st.name] = []
            self.ready[st.name] = []
        for td in ev.tasks:  # per-stage creation order (see snapshot_event)
            rec = TaskRecord(
                task_id=td["task_id"], stage=td["stage"],
                index=int(td.get("index", 0)),
                params=dict(td.get("params", {})),
                dep_ids=tuple(td.get("dep_ids", ())),
                attempts=int(td.get("attempts", 0)),
                done=bool(td.get("done", False)),
                failed=bool(td.get("failed", False)),
                skipped=bool(td.get("skipped", False)),
                revokes=int(td.get("revokes", 0)),
                revoke_pending=bool(td.get("revoke_pending", False)),
                result=(dict(td["result"])
                        if td.get("result") is not None else None))
            self.tasks[rec.task_id] = rec
            self.by_stage[rec.stage].append(rec.task_id)
            for dep in rec.dep_ids:
                self._mapped.add((dep, rec.stage))
            if not rec.terminal and (rec.attempts == 0 or rec.revoke_pending):
                self.ready[rec.stage].append(rec.task_id)
        self.joins_fired = set(ev.joins_fired)
        self.preemptions = int(ev.preemptions)
        return True

    def _maybe_complete(self, ts: float) -> None:
        if self.done:
            return
        if all(self.stages[n].complete for n in self.stages):
            self.state = self.COMPLETED
            self.finished_at = ts

    # -- non-journaled observability --------------------------------------

    def count_duplicate(self, task_id: str) -> None:
        """A fenced duplicate/late result. Deliberately not an event: the
        counter restarts at zero after a replay."""
        rec = self.tasks.get(task_id)
        if rec is not None:
            self.stages[rec.stage].duplicates += 1

    # -- equality (replay-idempotence contract) ----------------------------

    def snapshot(self) -> dict:
        """Domain state only — ``seq`` and duplicate counters are
        bookkeeping, excluded so ``fold(ev) == fold(ev + dup_suffix)``."""
        stages = {}
        for n, s in self.stages.items():
            d = s.to_dict()
            d.pop("duplicates", None)
            stages[n] = d
        return {
            "campaign_id": self.campaign_id,
            "pipeline": self.pipeline,
            "state": self.state,
            "failure": self.failure,
            "weight": self.weight,
            "items": list(self.items),
            "params": dict(self.params),
            "stages": stages,
            "tasks": {t: r.to_dict() for t, r in sorted(self.tasks.items())},
            "by_stage": self.by_stage,
            "ready": self.ready,
            "joins_fired": sorted(self.joins_fired),
            "preemptions": self.preemptions,
        }

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, CampaignState):
            return NotImplemented
        return self.snapshot() == other.snapshot()

    __hash__ = None  # mutable


# --------------------------------------------------------------------------
# Decide functions (pure planners: state -> [events])
# --------------------------------------------------------------------------


def _task_id(campaign_id: str, stage: str, index: int) -> str:
    return f"{campaign_id}-{stage}-{index:05d}"


def plan_sources(state: CampaignState) -> list[JournalEvent]:
    """Source-stage tasks for the campaign's items (fan-out batching).
    Idempotent: already-planned task ids are skipped, so it doubles as the
    recovery repair pass for a journal truncated mid-seed."""
    evs: list[JournalEvent] = []
    for st in state.spec.sources():
        if st.fan_out is None:
            batches = [state.items]
        else:
            batches = [state.items[i:i + st.fan_out]
                       for i in range(0, len(state.items), st.fan_out)] \
                or [[]]
        for bi, batch in enumerate(batches):
            tid = _task_id(state.campaign_id, st.name, bi)
            if tid in state.tasks:
                continue
            evs.append(StageDispatched(
                campaign_id=state.campaign_id, stage=st.name, task_id=tid,
                index=bi, params={"batch": list(batch), "batch_index": bi}))
    return evs


def plan_downstream(state: CampaignState, task_id: str) -> list[JournalEvent]:
    """Events that follow one task reaching a terminal state (done or
    skipped): map tasks 1:1, skip cascades, and join barriers (exactly once,
    with the assembled upstream payload). Pure and guard-checked — planning
    the same task twice, or re-planning during recovery repair, yields no
    events. Callers apply each returned event before planning the next task
    (indexes are read from the folded state)."""
    rec = state.tasks[task_id]
    if not (rec.done or rec.skipped):
        return []
    cid = state.campaign_id
    evs: list[JournalEvent] = []
    for ds in state.spec.downstream(rec.stage):
        if not ds.join:
            if (task_id, ds.name) in state._mapped:
                continue  # already planned (replayed journal)
            idx = len(state.by_stage[ds.name])
            tid = _task_id(cid, ds.name, idx)
            if rec.skipped or (ds.skip_when is not None
                               and ds.skip_when(rec.result)):
                evs.append(StageSkipped(campaign_id=cid, stage=ds.name,
                                        task_id=tid, index=idx,
                                        dep_ids=(task_id,)))
            else:
                evs.append(StageDispatched(
                    campaign_id=cid, stage=ds.name, task_id=tid, index=idx,
                    params={"upstream": rec.result, "dep_index": rec.index},
                    dep_ids=(task_id,)))
        elif (ds.name not in state.joins_fired
              or not state.by_stage[ds.name]) and \
                all(state.stage_complete(d) for d in ds.depends_on):
            # second disjunct: torn write — BarrierReleased journaled but the
            # crash ate the join task's dispatch; re-plan the task without
            # re-firing the (idempotent) barrier
            if ds.name not in state.joins_fired:
                evs.append(BarrierReleased(campaign_id=cid, stage=ds.name))
            upstream: dict[str, list] = {}
            dep_ids: list[str] = []
            for dep in ds.depends_on:
                live = [t for t in state.by_stage[dep]
                        if not state.tasks[t].skipped]
                upstream[dep] = [state.tasks[t].result for t in live]
                dep_ids.extend(live)
            idx = len(state.by_stage[ds.name])
            tid = _task_id(cid, ds.name, idx)
            if ds.skip_when is not None and ds.skip_when(upstream):
                evs.append(StageSkipped(campaign_id=cid, stage=ds.name,
                                        task_id=tid, index=idx,
                                        dep_ids=tuple(dep_ids)))
            else:
                evs.append(StageDispatched(
                    campaign_id=cid, stage=ds.name, task_id=tid, index=idx,
                    params={"upstream": upstream}, dep_ids=tuple(dep_ids)))
    return evs


def group_journal(records: Iterable[Mapping[str, Any]]
                  ) -> dict[str, list[JournalEvent]]:
    """Split raw ``PREFIX-campaigns`` records into per-campaign event lists,
    sorted by ``seq`` with duplicates dropped (at-least-once journal reads
    and partially-flushed tails both produce repeats). Snapshot records are
    ignored."""
    by_campaign: dict[str, dict[int, JournalEvent]] = {}
    for value in records:
        if not is_journal_record(value):
            continue
        ev = event_from_dict(value)
        seqs = by_campaign.setdefault(ev.campaign_id, {})
        seqs.setdefault(ev.seq, ev)
    return {cid: [seqs[s] for s in sorted(seqs)]
            for cid, seqs in by_campaign.items()}
