"""PipelineAgent — a thin executor over the event-sourced campaign journal.

The agent is a *peer* of the MonitorAgent (§3): it subscribes to the
``PREFIX-done`` / ``PREFIX-error`` topics in its own consumer group (broadcast
copy — monitors and pipeline agents each see every record) and drives the
campaign state machine. Since the event-sourcing refactor it holds **no**
authoritative mutable progress of its own: every decision is appended as a
typed :mod:`repro.pipeline.state` event to a write-ahead journal on the
``PREFIX-campaigns`` topic *before* the agent acts, then folded into the pure
:class:`~repro.pipeline.state.CampaignState` reducer. An orchestrator
``kill -9`` therefore loses nothing a replay cannot rebuild — see
:meth:`recover`.

Responsibilities (unchanged semantics, now journal-backed):

* when an upstream task completes, emit next-stage ``TaskMessage``\\ s (map
  stages 1:1, join stages exactly once per barrier),
* **duplicate-result fencing**: the first result per task wins; late results
  from re-attempted tasks — including attempts replayed after a recovery —
  are counted and dropped, so a barrier can never double-fire,
* **backpressure**: per-stage ``max_in_flight`` bounds how many tasks of a
  stage are on the ``-new`` topic at once; the rest wait in a ready queue,
* **fair sharing**: a pluggable :class:`~repro.core.scheduling.LeasePolicy`
  (FairShare weighted round-robin by default) decides whose ready task is
  submitted next; every grant is journaled as ``LeaseGranted``,
* **conditional edges**: ``Stage.skip_when`` short-circuits pointless tasks;
  skips are journaled (``StageSkipped``) so replay never re-runs predicates,
* **watchdog**: a task with no result after ``RetryPolicy.timeout_s`` is
  resubmitted with a bumped attempt; the retry budget is the journaled
  ``LeaseGranted`` count in ``CampaignState``, so resubmissions after a
  recovery never double-count attempts taken before the crash. Every
  resubmission first revokes the stale holder's lease
  (:meth:`~repro.core.broker.Broker.revoke_lease`) so the old execution is
  cancelled and its late verdict fenced at the broker, not merely ignored,
* **preemptive fair share**: when the lease policy reports a severely
  over-share campaign while a peer with ready work is starved
  (:meth:`~repro.core.scheduling.LeasePolicy.preempt`), the over-share
  campaign's longest-running lease is revoked
  (``reason="preempt"``, journaled as ``LeaseRevoked``) and requeued
  through the normal pump — bounded per campaign by
  ``RetryPolicy.max_preemptions``, without consuming the retry budget,
* progress snapshots are still published on ``PREFIX-campaigns`` for the
  MonitorAgent's ``/campaigns`` REST endpoint (interleaved with the journal;
  records carry a ``kind`` discriminator).

Recovery (:meth:`recover`): read the journal back via
:meth:`~repro.core.broker.Broker.read_from`, fold each live campaign's
events, run the pure repair planners over any gap a crash left between
journal writes, re-register the campaign, and resubmit only tasks with no
terminal event — after an explicit replay read of ``-done`` absorbs results
produced while no orchestrator was alive, so finished work is never
re-executed and duplicates are re-fenced against the replayed state.
"""
from __future__ import annotations

import dataclasses
import logging
import math
import threading
import time
from typing import Any, Iterable, Mapping

from repro.core.broker import Broker, Consumer, Producer
from repro.core.lease import RevokeReason
from repro.core.messages import (CampaignEvent, ErrorMessage, ResultMessage,
                                 TaskMessage, new_task_id, topic_names)
from repro.core.scheduling import FairShare, LeasePolicy, PlacementPolicy
from repro.core.submitter import Submitter

from .spec import PipelineSpec, Stage
from .state import (JOURNAL_KIND, CampaignSnapshot, CampaignState,
                    CampaignSubmitted, JournalEvent, LeaseGranted,
                    LeaseRevoked, StageSkipped, TaskDone, TaskFailed,
                    group_journal, plan_downstream, plan_sources,
                    snapshot_event)
from .status import CampaignStatus

log = logging.getLogger(__name__)


class PipelineError(RuntimeError):
    pass


class _CampaignRun:
    """Runtime envelope around one campaign's pure state: the spec (code —
    predicates and scripts are not journaled), wall-clock watchdog timers,
    and the completion latch. Everything else lives in ``self.state``."""

    def __init__(self, spec: PipelineSpec, campaign_id: str,
                 recovered: bool = False):
        self.spec = spec
        self.campaign_id = campaign_id
        self.state = CampaignState(spec, campaign_id)
        self.last_submit: dict[str, float] = {}
        self.completion = threading.Event()
        self.last_publish = 0.0
        self.recovered = recovered
        self.created_at = time.time()
        self.compacted_seq = -1  # state.seq at the last compact() snapshot

    @property
    def status(self) -> CampaignStatus:
        """A live view over the reducer state (stage objects are shared, so
        counters advance in place, matching the pre-refactor behaviour)."""
        st = CampaignStatus(campaign_id=self.campaign_id,
                            pipeline=self.state.pipeline,
                            state=self.state.state)
        st.stages = self.state.stages
        st.started_at = self.state.started_at or self.created_at
        st.finished_at = self.state.finished_at
        st.failure = self.state.failure
        st.preemptions = self.state.preemptions
        return st

    def max_preemptions(self) -> int:
        """The campaign-wide preemption bound: max over its stages'
        ``RetryPolicy.max_preemptions`` (0 = never preempt this campaign)."""
        return max((st.retry.max_preemptions
                    for st in self.spec.stages.values()), default=0)


class PipelineAgent:
    """Subscribes to ``-done``/``-error`` and advances registered campaigns.

    Multiple campaigns (even over different :class:`PipelineSpec`\\ s) can run
    concurrently through one agent; tasks from campaigns this agent does not
    own are ignored (unknown task_id), so several pipeline agents can share a
    prefix the way several MonitorAgents can (§3). ``journal=False`` disables
    the write-ahead journal (state is still folded from events in memory) —
    for benchmarks quantifying the append overhead, and embedders that accept
    losing recoverability.
    """

    def __init__(self, broker: Broker, prefix: str = "ksa", *,
                 agent_id: str | None = None,
                 poll_interval_s: float = 0.02,
                 default_task_timeout_s: float | None = None,
                 publish_interval_s: float = 0.25,
                 retain_finished: int | None = 32,
                 placement: PlacementPolicy | None = None,
                 lease: LeasePolicy | None = None,
                 max_in_flight_total: int | None = None,
                 journal: bool = True):
        self.broker = broker
        self.prefix = prefix
        self.topics = topic_names(prefix)
        self.agent_id = agent_id or f"pipeline-{id(self) & 0xffff:04x}"
        self.poll_interval_s = poll_interval_s
        self.default_task_timeout_s = default_task_timeout_s
        self.publish_interval_s = publish_interval_s
        # long-lived agents serve a stream of campaigns; keep only the most
        # recent `retain_finished` finished runs (None = keep all).
        self.retain_finished = retain_finished
        self._lease = lease or FairShare()
        self.max_in_flight_total = max_in_flight_total
        self.journal = journal
        # the journal must never age out under a broker-wide retention cap —
        # replay needs every event back to the oldest live campaign.
        broker.create_topic(self.topics["campaigns"], retention_records=None)
        self._submitter = Submitter(broker, prefix, placement=placement)
        self._producer = Producer(broker)
        gid = f"{prefix}-pipeline-{self.agent_id}"
        self._consumer = Consumer(
            broker, [self.topics["done"], self.topics["error"]],
            group_id=gid, member_id=f"{gid}-member")
        self._campaigns: dict[str, _CampaignRun] = {}
        self._task_index: dict[str, str] = {}  # task_id -> campaign_id
        # counters live in the broker's obs registry; the old attribute
        # names (events_journaled / preemptions) are property views below
        metrics = broker.metrics
        self._c_journal = metrics.counter(
            "ksa_journal_events_total",
            "Write-ahead campaign journal events appended",
            labels=("agent",)).labels(agent=self.agent_id)
        self._c_preempt = metrics.counter(
            "ksa_pipeline_preemptions_total",
            "Fair-share preemptive lease revocations issued",
            labels=("agent",)).labels(agent=self.agent_id)
        self._h_fold = metrics.histogram(
            "ksa_journal_fold_seconds",
            "Journal -> CampaignState fold time (recovery / compaction)")
        self._h_compact = metrics.histogram(
            "ksa_journal_compact_seconds",
            "Full journal compaction pass duration")
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._crashed = threading.Event()  # test hook: simulate kill -9
        self._thread: threading.Thread | None = None

    # -- counter views (registry-backed; names predate repro.obs) ----------

    @property
    def events_journaled(self) -> int:
        return self._c_journal.value

    @property
    def preemptions(self) -> int:
        """Fair-share lease revocations issued (all runs)."""
        return self._c_preempt.value

    # -- journal / fold plumbing ----------------------------------------------

    def _emit(self, run: _CampaignRun, ev: JournalEvent) -> None:
        """Write-ahead: stamp, journal, then fold. Call with the lock held.
        Everything the agent does to campaign state goes through here."""
        ev = dataclasses.replace(ev, seq=run.state.seq + 1, ts=time.time())
        if self.journal:
            self._producer.send(self.topics["campaigns"], ev.to_dict(),
                                key=run.campaign_id)
            self._c_journal.inc()
        run.state.apply(ev)
        tid = getattr(ev, "task_id", "")
        if tid:  # planned/skipped tasks become addressable for fencing
            self._task_index[tid] = run.campaign_id
            self.broker.spans.add(tid, "journal", ev.ts, ev.ts,
                                  event=type(ev).__name__, seq=ev.seq,
                                  campaign=run.campaign_id,
                                  agent=self.agent_id)

    def _submit_record(self, run: _CampaignRun, task_id: str) -> None:
        """Grant a lease (journaled) and put the task on ``-new``."""
        rec = run.state.tasks[task_id]
        attempt = rec.attempts
        if attempt > 0:
            # a retry / regrant: revoke whatever lease a stale holder still
            # has on the previous attempt — the unified retry fencing. The
            # old execution is cancelled and its late verdict fenced at the
            # broker commit gate, not merely ignored at ingest; no requeue
            # (this very call is the resubmission).
            self.broker.revoke_lease(task_id, RevokeReason.WATCHDOG,
                                     requeue=False)
        self._emit(run, LeaseGranted(campaign_id=run.campaign_id,
                                     task_id=task_id, attempt=attempt))
        run.last_submit[task_id] = time.time()
        st = run.spec.stages[rec.stage]
        task = TaskMessage(
            task_id=task_id,
            script=st.script,
            params={**run.state.params, **dict(st.params), **rec.params},
            resources=st.resources,
            timeout_s=st.timeout_s,
            attempt=attempt,
            campaign_id=run.campaign_id,
            stage=rec.stage,
            dep_ids=list(rec.dep_ids),
            trace={"trace_id": task_id, "parent": run.campaign_id},
        )
        self._submitter.submit_task(task)

    # -- campaign submission -------------------------------------------------

    def submit_campaign(self, spec: PipelineSpec, items: Iterable | None = None,
                        *, params: Mapping[str, Any] | None = None,
                        campaign_id: str | None = None,
                        weight: float = 1.0) -> str:
        """Plan a campaign and submit its source-stage tasks. Returns the
        campaign id; progress via :meth:`status`, blocking via :meth:`wait`.
        ``weight`` sets this campaign's share of `-new` capacity under the
        agent's lease policy (FairShare: a weight-3 campaign drains three
        ready tasks for every one of a weight-1 peer)."""
        # a zero/negative weight starves the campaign under weighted round-
        # robin and NaN poisons every credit comparison in FairShare —
        # reject all of them here, at the API edge, with a clear error
        if not math.isfinite(weight) or weight <= 0:
            raise PipelineError(
                f"campaign weight must be a positive finite number "
                f"(got {weight!r})")
        # fail fast on unroutable stage resources (e.g. a label naming no
        # class) — raising here beats stalling mid-campaign in the loop
        for st in spec.topological():
            probe = TaskMessage(task_id=f"probe-{st.name}", script=st.script,
                                resources=st.resources)
            try:
                self._submitter.placement.route(self.prefix, probe)
            except ValueError as exc:
                raise PipelineError(
                    f"stage {st.name!r} is unroutable: {exc}") from exc
        items = list(items) if items is not None else []
        cid = campaign_id or new_task_id(f"camp-{spec.name}")
        with self._lock:
            if cid in self._campaigns:
                raise PipelineError(f"campaign {cid!r} already exists")
            run = _CampaignRun(spec, cid)
            self._campaigns[cid] = run
            self._emit(run, CampaignSubmitted(
                campaign_id=cid, pipeline=spec.name, items=tuple(items),
                params=dict(params or {}), weight=weight))
            for ev in plan_sources(run.state):
                self._emit(run, ev)
            self._pump_all()
            self._publish(run, force=True)
        return cid

    # -- backpressure / fair-share pump ---------------------------------------

    def _next_stage(self, run: _CampaignRun) -> Stage | None:
        """The first stage (topological order) with a ready task that fits
        under its ``max_in_flight`` bound, or None."""
        for st in run.spec.topological():
            if not run.state.ready[st.name]:
                continue
            bound = st.max_in_flight
            if bound is None or \
                    run.state.stages[st.name].in_flight < bound:
                return st
        return None

    def _pump_all(self) -> None:
        """Drain ready queues into ``-new`` capacity, one task at a time;
        the lease policy picks which campaign goes next (FairShare weighted
        round-robin by default). ``max_in_flight_total`` bounds the agent's
        outstanding tasks across all campaigns. Call with the lock held.

        The candidate set and the outstanding count are computed once and
        maintained incrementally: the lock is held throughout, so no other
        thread can make a campaign submittable mid-drain — candidates only
        ever shrink. This keeps a paper-scale fan-out (tens of thousands of
        source tasks) O(tasks), not O(tasks × campaigns × stages)."""
        outstanding = 0
        if self.max_in_flight_total is not None:
            outstanding = sum(
                ss.in_flight
                for r in self._campaigns.values() if not r.state.done
                for ss in r.state.stages.values())
        candidates = {cid: r.state.weight
                      for cid, r in self._campaigns.items()
                      if not r.state.done
                      and self._next_stage(r) is not None}
        while candidates:
            if self.max_in_flight_total is not None \
                    and outstanding >= self.max_in_flight_total:
                return
            cid = self._lease.select(candidates)
            run = self._campaigns[cid]
            st = self._next_stage(run)
            if st is None:  # safety net; normally pruned after submit
                del candidates[cid]
                continue
            self._submit_record(run, run.state.ready[st.name][0])
            outstanding += 1
            if self._next_stage(run) is None:
                del candidates[cid]

    # -- ingestion -------------------------------------------------------------

    def _ingest(self, topic: str, value: dict) -> None:
        if topic == self.topics["done"]:
            res = ResultMessage.from_dict(value)
            self._on_result(res)
        elif topic == self.topics["error"]:
            err = ErrorMessage.from_dict(value)
            self._on_error(err)

    def _on_result(self, res: ResultMessage) -> None:
        with self._lock:
            cid = self._task_index.get(res.task_id)
            if cid is None:
                return  # not one of ours (flat task or another agent's)
            run = self._campaigns[cid]
            rec = run.state.tasks[res.task_id]
            if rec.terminal or run.state.done:
                # fencing: duplicate results, late results for retry-exhausted
                # tasks, replayed attempts absorbed after a recovery, and
                # stragglers of an already-failed campaign never advance the
                # DAG (a FAILED verdict must stay final).
                run.state.count_duplicate(res.task_id)
                return
            self._emit(run, TaskDone(campaign_id=cid, task_id=res.task_id,
                                     result=res.result))
            self._advance(run, res.task_id)
            self._pump_all()
            self._finalize(run)
            self._publish(run)

    def _advance(self, run: _CampaignRun, task_id: str) -> None:
        """Plan (and journal) everything that follows a terminal task; skip
        cascades feed back into the worklist so an entire skipped subtree is
        planned in one pass."""
        queue = [task_id]
        while queue:
            tid = queue.pop(0)
            for ev in plan_downstream(run.state, tid):
                self._emit(run, ev)
                if isinstance(ev, StageSkipped):
                    queue.append(ev.task_id)
        self._finalize(run)

    def _on_error(self, err: ErrorMessage) -> None:
        with self._lock:
            cid = self._task_index.get(err.task_id)
            if cid is None:
                return
            run = self._campaigns[cid]
            rec = run.state.tasks[err.task_id]
            if rec.terminal or run.state.done:
                return
            if err.attempt < rec.attempts - 1:
                return  # fenced: an older attempt failing after a resubmit
            self._retry_or_fail(run, err.task_id, cause="error",
                                reason=f"error: {err.error}")

    # -- watchdog / retries ------------------------------------------------------

    def _retry_or_fail(self, run: _CampaignRun, task_id: str, *,
                       cause: str, reason: str) -> None:
        rec = run.state.tasks[task_id]
        st = run.spec.stages[rec.stage]
        # preemption regrants (journaled LeaseRevoked) are requeues, not
        # failures — they do not consume the retry budget
        if rec.attempts - rec.revokes < st.retry.max_attempts:
            if cause == "error":
                self._emit(run, TaskFailed(campaign_id=run.campaign_id,
                                           task_id=task_id, reason=reason,
                                           cause=cause, final=False))
            self._submit_record(run, task_id)
            log.info("campaign %s: resubmitted %s (attempt %d, %s)",
                     run.campaign_id, task_id, rec.attempts - 1, reason)
        else:
            # budget exhausted: revoke any still-running zombie so it stops
            # burning a slot and its eventual verdict is fenced at the broker
            self.broker.revoke_lease(task_id, RevokeReason.WATCHDOG,
                                     requeue=False)
            self._emit(run, TaskFailed(
                campaign_id=run.campaign_id, task_id=task_id,
                reason=(f"stage {rec.stage!r} task {task_id} exhausted "
                        f"{st.retry.max_attempts} attempts ({reason})"),
                cause=cause, final=True))
            self._finalize(run)
            log.warning("campaign %s FAILED: %s",
                        run.campaign_id, run.state.failure)
            # trigger condition: a campaign entering FAILED latches a
            # post-mortem blackbox dump with the events leading up to it
            self.broker.blackbox.record(
                "campaign_failed", campaign_id=run.campaign_id,
                task_id=task_id, reason=run.state.failure)
            self.broker.blackbox.dump(
                "campaign_failed",
                {"campaign_id": run.campaign_id,
                 "failure": run.state.failure})

    def _watchdog(self) -> None:
        now = time.time()
        with self._lock:
            for run in self._campaigns.values():
                if run.state.done:
                    continue
                for st in run.spec.topological():
                    timeout = st.retry.timeout_s or self.default_task_timeout_s
                    if timeout is None:
                        continue
                    for tid in run.state.by_stage[st.name]:
                        rec = run.state.tasks[tid]
                        if rec.terminal or rec.attempts == 0 \
                                or rec.revoke_pending:
                            # revoke-pending tasks are in the ready queue
                            # awaiting a regrant — the pump owns them, not
                            # the watchdog
                            continue
                        last = run.last_submit.get(tid, run.created_at)
                        if now - last > timeout and \
                                now - last > self._lease_deadline(tid,
                                                                  timeout):
                            self._retry_or_fail(
                                run, tid, cause="timeout",
                                reason=f"no result after {timeout:.1f}s")
                        if run.state.done:
                            return

    def _lease_deadline(self, task_id: str, base_timeout_s: float) -> float:
        """The effective no-result deadline for one task: the stage timeout,
        stretched to the lease's WAN-tolerant ``deadline_s`` when the task
        is held across a federation site (:class:`~repro.core.lease.
        LeaseTolerance` stamps it at grant) — a stage relayed over a slow
        link is not a straggler just because the uniform timeout says so."""
        lease = self.broker.lease_view(task_id)
        if lease is None:
            return base_timeout_s
        deadline = lease.get("deadline_s")
        if deadline is None:
            return base_timeout_s
        return max(base_timeout_s, deadline)

    # -- preemptive fair share ---------------------------------------------------

    def _maybe_preempt(self) -> None:
        """Ask the lease policy whether some campaign is severely over its
        share while a peer with ready work is starved; if so, revoke the
        over-share campaign's longest-running lease through
        :meth:`Broker.revoke_lease` and journal it as ``LeaseRevoked`` so
        recovery replays the revocation. Revoke-then-journal: the revoke is
        the atomic authority (it returns False if the task completed
        concurrently — a finished task is never preempted), and a crash
        between the two degrades to a plain watchdog retry."""
        with self._lock:
            shares: dict[str, tuple[float, int, bool, bool]] = {}
            for cid, r in self._campaigns.items():
                if r.state.done:
                    continue
                in_flight = sum(ss.in_flight
                                for ss in r.state.stages.values())
                shares[cid] = (r.state.weight, in_flight,
                               self._next_stage(r) is not None,
                               r.state.preemptions < r.max_preemptions())
            if len(shares) < 2:
                return
            victim_cid = self._lease.preempt(shares)
            if victim_cid is None:
                return
            run = self._campaigns[victim_cid]
            cap = run.max_preemptions()
            if run.state.preemptions >= cap:
                return  # policy ignored the preemptible flag: hold the line
            # longest-running live lease of the victim campaign (RUNNING
            # beats GRANTED: a deferred lease holds no compute yet)
            candidates = [tid for tid, rec in run.state.tasks.items()
                          if rec.attempts > 0 and not rec.terminal
                          and not rec.revoke_pending]
            best, best_key = None, None
            for view in self.broker.live_leases(candidates):
                key = (0 if view["state"] == "RUNNING" else 1,
                       view.get("started_at") or view["granted_at"])
                if best_key is None or key < best_key:
                    best, best_key = view["task_id"], key
            if best is None:
                return
            if not self.broker.revoke_lease(best, RevokeReason.PREEMPT,
                                            requeue=False):
                return  # lost the race to a completion: nothing to take back
            self._emit(run, LeaseRevoked(campaign_id=victim_cid,
                                         task_id=best,
                                         reason=RevokeReason.PREEMPT))
            self._c_preempt.inc()
            log.info("campaign %s: preempted %s (%d/%d preemptions used)",
                     victim_cid, best, run.state.preemptions, cap)
            self._pump_all()
            self._publish(run)

    def _finalize(self, run: _CampaignRun) -> None:
        """Latch a terminal reducer state into the runtime side effects
        (completion event, forced snapshot, retention eviction)."""
        if not run.state.done or run.completion.is_set():
            return
        run.completion.set()
        self._publish(run, force=True)
        self._evict_finished()

    def _evict_finished(self) -> None:
        """Drop the oldest finished campaigns beyond ``retain_finished`` so a
        resident agent serving a campaign stream doesn't grow without bound.
        Callers must fetch results before the run ages out of the window (the
        journal keeps the events; :meth:`recover` with
        ``include_finished=True`` can rebuild an evicted campaign)."""
        if self.retain_finished is None:
            return
        finished = sorted((r for r in self._campaigns.values()
                           if r.state.done),
                          key=lambda r: r.state.finished_at or 0.0)
        for run in finished[:max(0, len(finished) - self.retain_finished)]:
            self.forget(run.campaign_id)

    def forget(self, campaign_id: str) -> None:
        """Release a finished campaign's task table and results."""
        with self._lock:
            run = self._campaigns.get(campaign_id)
            if run is None or not run.state.done:
                return
            for tid in run.state.tasks:
                self._task_index.pop(tid, None)
            del self._campaigns[campaign_id]
            self._lease.forget(campaign_id)

    # -- crash recovery ---------------------------------------------------------

    def recover(self, specs: Mapping[str, PipelineSpec] | Iterable[PipelineSpec],
                *, include_finished: bool = False) -> list[str]:
        """Reconstruct campaigns from the ``PREFIX-campaigns`` journal after
        an orchestrator crash. Returns the campaign ids registered.

        ``specs`` maps pipeline names to their :class:`PipelineSpec` (or is an
        iterable of specs) — the spec is code (scripts, ``skip_when``
        predicates) and is deliberately not journaled, so the caller must
        re-supply it; campaigns whose pipeline has no spec are skipped with a
        warning.

        For every campaign whose replayed state is still live:

        1. fold the journal into a fresh :class:`CampaignState` (duplicate
           and truncated-tail journal entries are deduped/dropped),
        2. run the pure repair planners to fill any gap a crash left between
           journal writes (a ``TaskDone`` whose downstream dispatch was never
           journaled),
        3. resubmit only tasks with **no terminal event**: previously leased
           tasks get a bumped, journaled attempt (counted against the same
           ``RetryPolicy`` budget the crashed agent was using — replayed
           retries are not re-counted); tasks already at their budget are
           left to the watchdog,
        4. never-leased ready tasks drain through the normal fair-share pump.

        Results that landed on ``-done`` while no orchestrator was alive are
        absorbed by an explicit replay read *before* deciding what to
        resubmit (a completed task is terminal, not resubmitted) — relying on
        the consumer loop alone would race it: a started agent may have
        polled and dropped those records as not-ours before the campaign was
        registered. Duplicates (e.g. the pre-crash attempt finishing after
        its post-recovery resubmission) are fenced against the replayed
        state; lost ``-error`` records degrade to watchdog timeouts.
        ``include_finished=True`` also registers campaigns whose journal
        folds to a terminal state (to re-read their results); they count
        toward ``retain_finished`` as usual.
        """
        if isinstance(specs, Mapping):
            by_name = dict(specs)
        else:
            by_name = {s.name: s for s in specs}
        records = [r.value
                   for r in self.broker.read_from(self.topics["campaigns"])]
        journals = group_journal(records)
        recovered: list[str] = []
        with self._lock:
            # every result the cluster has ever produced for this prefix;
            # read under the lock so nothing can slip between this scan and
            # campaign registration (the loop needs the lock to ingest)
            downtime_results = [
                ResultMessage.from_dict(r.value)
                for r in self.broker.read_from(self.topics["done"])]
            for cid, events in journals.items():
                if cid in self._campaigns:
                    continue  # already live on this agent
                # a compacted campaign's journal may start at its snapshot
                # (the CampaignSubmitted was truncated away) — both carry
                # the pipeline name needed to look up the spec
                sub = next((e for e in events
                            if isinstance(e, (CampaignSubmitted,
                                              CampaignSnapshot))), None)
                if sub is None:
                    log.warning("journal for %s has no CampaignSubmitted "
                                "or snapshot (truncated head?) — skipping",
                                cid)
                    continue
                spec = by_name.get(sub.pipeline)
                if spec is None:
                    log.warning("no spec supplied for pipeline %r — skipping "
                                "campaign %s", sub.pipeline, cid)
                    continue
                t_fold = time.perf_counter()
                state = CampaignState.fold(spec, cid, events)
                self._h_fold.observe(time.perf_counter() - t_fold)
                if state.done and not include_finished:
                    continue  # finished (possibly evicted) campaign
                run = _CampaignRun(spec, cid, recovered=True)
                run.state = state
                self._campaigns[cid] = run
                for tid in state.tasks:
                    self._task_index[tid] = cid
                self._repair(run)
                # absorb results produced while no orchestrator was alive:
                # first result per task wins, exactly like live ingestion
                for res in downtime_results:
                    rec = state.tasks.get(res.task_id)
                    if rec is None or rec.terminal or state.done:
                        # unknown, already folded from the journal (the
                        # usual case — not a duplicate), or moot
                        continue
                    self._emit(run, TaskDone(campaign_id=cid,
                                             task_id=res.task_id,
                                             result=res.result))
                    self._advance(run, res.task_id)
                now = time.time()
                for tid, rec in list(state.tasks.items()):
                    if rec.terminal or rec.attempts == 0 or rec.revoke_pending:
                        # revoke-pending: the journaled revocation already
                        # returned the task to its ready queue; the pump
                        # regrants it (replayed exactly like a completion)
                        continue
                    st = run.spec.stages[rec.stage]
                    if rec.attempts - rec.revokes < st.retry.max_attempts:
                        # no terminal event for this lease: resubmit with a
                        # bumped (journaled) attempt; the stale attempt's
                        # result, if it ever lands, is fenced as a duplicate
                        self._submit_record(run, tid)
                    else:
                        # budget already spent pre-crash; give the in-flight
                        # attempt a fresh watchdog window instead of failing
                        # the campaign on sight
                        run.last_submit[tid] = now
                self._finalize(run)
                self._publish(run, force=True)
                recovered.append(cid)
                log.info("recovered campaign %s (%s, %d events, state=%s)",
                         cid, sub.pipeline, len(events), state.state)
            self._pump_all()
        return recovered

    def _repair(self, run: _CampaignRun) -> None:
        """Re-run the pure planners over replayed state to journal anything a
        crash dropped between a fact event and its follow-up planning events.
        Both planners are guard-checked, so this is a no-op on a clean
        journal."""
        seq_before = run.state.seq
        for ev in plan_sources(run.state):
            self._emit(run, ev)
        for tid in [t for t, r in run.state.tasks.items() if r.terminal]:
            self._advance(run, tid)
        if run.state.seq != seq_before:
            # only journal repairs that actually re-emitted something —
            # a clean-journal no-op is not a lifecycle event
            self.broker.blackbox.record(
                "journal_repair", campaign_id=run.campaign_id,
                events=run.state.seq - seq_before)

    # -- journal compaction -----------------------------------------------------

    def compact(self, specs: Mapping[str, PipelineSpec]
                | Iterable[PipelineSpec] | None = None) -> dict:
        """Bound the ``PREFIX-campaigns`` journal (ROADMAP: the topic used to
        retain every event forever, since recovery needs history back to the
        oldest live campaign).

        Two steps, both crash-safe:

        1. **snapshot** — every terminal campaign is folded into a single
           :class:`~repro.pipeline.state.CampaignSnapshot` journal record
           (write-ahead, like any other event). Registered campaigns are
           snapshotted directly; with ``specs`` supplied, journal-only
           terminal campaigns (evicted past ``retain_finished``, or another
           agent's finished runs whose pipeline we know) are folded from the
           journal and snapshotted too.
        2. **truncate** — each partition's prefix is deleted
           (:meth:`~repro.core.broker.Broker.truncate_before`, the
           ``delete_records`` analogue) up to the first record still needed:
           a live/unknown campaign's journal event, or a compacted
           campaign's snapshot. Because records are keyed by campaign id, a
           compacted campaign's events that interleave *behind* a live
           campaign's first record survive until a later compact — prefix
           truncation is conservative, never lossy.

        ``recover()`` then folds snapshot-then-events: applying a snapshot
        wholesale-replaces whatever (possibly truncated) prefix preceded it,
        so a compacted terminal campaign rebuilds with full result parity
        (``include_finished=True``) and live campaigns are untouched.
        Returns ``{"campaigns": [...], "truncated": n, "retained": n}``.
        This scans the topic once — explicitly invoked maintenance, not the
        control loop (broker *stats* stay scan-free)."""
        if specs is None:
            by_name: dict[str, PipelineSpec] = {}
        elif isinstance(specs, Mapping):
            by_name = dict(specs)
        else:
            by_name = {s.name: s for s in specs}
        topic = self.topics["campaigns"]
        truncated = retained = 0
        t_compact = time.perf_counter()
        with self._lock:
            # 1a. snapshot registered terminal campaigns (write-ahead).
            # Re-running compact as periodic maintenance must be churn-free:
            # a campaign whose state is unchanged since its last snapshot
            # (run.compacted_seq) is only re-marked for retention.
            compacted: dict[str, int] = {}  # campaign_id -> snapshot seq
            for run in self._campaigns.values():
                if not run.state.done:
                    continue
                if run.compacted_seq != run.state.seq:
                    self._emit(run, snapshot_event(run.state))
                    run.compacted_seq = run.state.seq
                compacted[run.campaign_id] = run.compacted_seq
            # 1b. with specs: fold + snapshot journal-only terminal campaigns
            if by_name:
                journals = group_journal(
                    [r.value for r in self.broker.read_from(topic)])
                for cid, events in journals.items():
                    if cid in self._campaigns or cid in compacted:
                        continue
                    if len(events) == 1 and \
                            isinstance(events[0], CampaignSnapshot):
                        # already fully compacted: just retain the snapshot
                        compacted[cid] = events[0].seq
                        continue
                    sub = next((e for e in events
                                if isinstance(e, (CampaignSubmitted,
                                                  CampaignSnapshot))), None)
                    spec = by_name.get(sub.pipeline) if sub else None
                    if spec is None:
                        continue  # unknown pipeline: keep its journal as-is
                    t_fold = time.perf_counter()
                    state = CampaignState.fold(spec, cid, events)
                    self._h_fold.observe(time.perf_counter() - t_fold)
                    if not state.done:
                        continue
                    ev = dataclasses.replace(snapshot_event(state),
                                             seq=state.seq + 1,
                                             ts=time.time())
                    self._producer.send(topic, ev.to_dict(), key=cid)
                    self._c_journal.inc()
                    compacted[cid] = ev.seq
            # 2. per-partition prefix truncation up to the first keeper
            for p in range(self.broker.partitions_for(topic)):
                recs = self.broker.read_from(topic, partition=p)
                cut = None
                for rec in recs:
                    if self._compact_keep(rec.value, compacted):
                        cut = rec.offset
                        break
                if cut is None and recs:  # nothing to keep: drop everything
                    cut = recs[-1].offset + 1
                if cut is not None:
                    truncated += self.broker.truncate_before(
                        topic, cut, partition=p)
            retained = len(self.broker.read_from(topic))
        self._h_compact.observe(time.perf_counter() - t_compact)
        log.info("compacted %d campaign(s): %d records truncated, %d "
                 "retained", len(compacted), truncated, retained)
        return {"campaigns": sorted(compacted), "truncated": truncated,
                "retained": retained}

    def _compact_keep(self, value: Mapping[str, Any],
                      compacted: Mapping[str, int]) -> bool:
        """Must this ``-campaigns`` record survive the current compaction?"""
        cid = value.get("campaign_id", "")
        if value.get("kind") == JOURNAL_KIND:
            if cid not in compacted:
                return True  # live (or another agent's) campaign: keep all
            # only the freshly-written snapshot replaces the history; older
            # snapshots and per-event records are superseded
            return (value.get("type") == CampaignSnapshot.__name__
                    and int(value.get("seq", -1)) >= compacted[cid])
        # progress snapshots: droppable once their campaign is compacted
        return cid not in compacted

    # -- progress publishing (PREFIX-campaigns) -----------------------------------

    def _publish(self, run: _CampaignRun, force: bool = False) -> None:
        now = time.time()
        if not force and now - run.last_publish < self.publish_interval_s:
            return
        run.last_publish = now
        ev = CampaignEvent(
            campaign_id=run.campaign_id, pipeline=run.state.pipeline,
            state=run.state.state, agent_id=self.agent_id,
            stages={n: s.to_dict() for n, s in run.state.stages.items()},
            recovered=run.recovered, preemptions=run.state.preemptions)
        self._producer.send(self.topics["campaigns"], ev.to_dict(),
                            key=run.campaign_id)

    # -- queries -----------------------------------------------------------------

    def status(self, campaign_id: str) -> CampaignStatus:
        with self._lock:
            return self._campaigns[campaign_id].status

    def campaigns(self) -> dict[str, CampaignStatus]:
        with self._lock:
            return {c: r.status for c, r in self._campaigns.items()}

    def wait(self, campaign_id: str, timeout: float = 60.0) -> CampaignStatus:
        with self._lock:
            run = self._campaigns[campaign_id]
        run.completion.wait(timeout)
        return run.status

    def stage_tasks(self, campaign_id: str) -> list:
        """``[(stage_name, [task_id, ...]), ...]`` in topological order —
        the per-stage task map :meth:`repro.cluster.KsaCluster.campaign_report`
        joins against the broker span store."""
        with self._lock:
            run = self._campaigns[campaign_id]
            by_stage = run.state.by_stage
            return [(st.name, list(by_stage.get(st.name, ())))
                    for st in run.spec.topological()]

    def results(self, campaign_id: str) -> dict[str, list]:
        """Per-stage results in task-creation order (completed tasks only)."""
        with self._lock:
            state = self._campaigns[campaign_id].state
            return {
                n: [state.tasks[t].result for t in tids
                    if state.tasks[t].result is not None]
                for n, tids in state.by_stage.items()
            }

    def final_result(self, campaign_id: str) -> Any:
        """The joined result: for a single-task terminal stage (the usual
        join barrier) the result dict itself, else {stage: [results...]}."""
        with self._lock:
            run = self._campaigns[campaign_id]
            state = run.state
            terms = run.spec.terminals()
            if len(terms) == 1 and len(state.by_stage[terms[0].name]) == 1:
                tid = state.by_stage[terms[0].name][0]
                return state.tasks[tid].result
            return {t.name: [state.tasks[tid].result
                             for tid in state.by_stage[t.name]]
                    for t in terms}

    def stats(self) -> dict:
        with self._lock:
            return {
                "agent_id": self.agent_id,
                "campaigns": len(self._campaigns),
                "running": sum(1 for r in self._campaigns.values()
                               if not r.state.done),
                "lease": type(self._lease).__name__,
                "weights": {c: r.state.weight
                            for c, r in self._campaigns.items()
                            if not r.state.done},
                "journal": self.journal,
                "events_journaled": self.events_journaled,
                "preemptions": self.preemptions,
                "recovered_campaigns": sum(
                    1 for r in self._campaigns.values() if r.recovered),
            }

    # -- main loop ------------------------------------------------------------------

    def start(self) -> "PipelineAgent":
        self._thread = threading.Thread(target=self._loop,
                                        name=f"{self.agent_id}-loop",
                                        daemon=True)
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set() and not self._crashed.is_set():
            try:
                batches = self._consumer.poll(timeout=self.poll_interval_s)
                for tp, recs in batches.items():
                    for rec in recs:
                        self._ingest(tp.topic, rec.value)
                if batches:
                    self._consumer.commit()
                self._watchdog()
                with self._lock:
                    self._pump_all()
                self._maybe_preempt()
            except Exception:  # pragma: no cover - defensive
                log.exception("pipeline agent %s loop error", self.agent_id)
                time.sleep(self.poll_interval_s)
        # a crashed agent leaves its group membership to expire, as a dead
        # process would — only a graceful stop closes the consumer.
        if not self._crashed.is_set():
            self._consumer.close()

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)

    def crash(self) -> None:
        """Test hook: die abruptly — no drain, no group leave, and no further
        journal appends or task submissions (both producers are killed, as a
        dead process's would be). The journal already on the broker is all a
        recovering agent gets — exactly the ``kill -9`` contract."""
        self._crashed.set()
        self._producer.kill()
        self._submitter._producer.kill()
