"""PipelineAgent — advances DAG campaigns over the KSA control plane.

The agent is a *peer* of the MonitorAgent (§3): it subscribes to the
``PREFIX-done`` / ``PREFIX-error`` topics in its own consumer group (broadcast
copy — monitors and pipeline agents each see every record) and drives the
campaign state machine:

* when an upstream task completes, emit next-stage ``TaskMessage``\\ s (map
  stages 1:1, join stages exactly once per barrier),
* **duplicate-result fencing**: the first result per task wins; late results
  from re-attempted tasks are counted and dropped, so a barrier can never
  double-fire (the safe-multiple-attempts extension the paper names as future
  work),
* **backpressure**: per-stage ``max_in_flight`` bounds how many tasks of a
  stage are on the ``-new`` topic at once; the rest wait in a ready queue,
* **fair sharing**: when several campaigns have ready tasks, a pluggable
  :class:`~repro.core.scheduling.LeasePolicy` decides whose task is submitted
  next — :class:`~repro.core.scheduling.FairShare` (default) drains them in
  weighted round-robin keyed by ``campaign_id`` (weights set per campaign at
  submit time), replacing the first-come FIFO contention,
* **conditional edges**: a stage's ``skip_when`` predicate short-circuits
  tasks whose upstream result makes them pointless (e.g. no screen survivors
  → skip localize); skips cascade downstream and count toward completion, so
  the campaign finishes COMPLETED, not FAILED,
* **watchdog**: a task with no result after ``RetryPolicy.timeout_s`` is
  resubmitted with a bumped attempt (the monitor's straggler mitigation,
  scoped per stage); ``max_attempts`` exhaustion fails the campaign,
* progress snapshots are published on ``PREFIX-campaigns`` for the
  MonitorAgent's ``/campaigns`` REST endpoint.
"""
from __future__ import annotations

import logging
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Iterable, Mapping

from repro.core.broker import Broker, Consumer, Producer
from repro.core.messages import (CampaignEvent, ErrorMessage, ResultMessage,
                                 TaskMessage, new_task_id, topic_names)
from repro.core.scheduling import FairShare, LeasePolicy, PlacementPolicy
from repro.core.submitter import Submitter

from .spec import PipelineSpec, Stage
from .status import CampaignState, CampaignStatus, StageStatus

log = logging.getLogger(__name__)


class PipelineError(RuntimeError):
    pass


@dataclass
class _PTask:
    """One planned task of one stage (all attempts share this record)."""

    stage: str
    task: TaskMessage                 # message of the latest attempt
    index: int                        # creation order within the stage
    attempts: int = 0                 # submissions so far
    last_submit: float = 0.0
    done: bool = False
    failed: bool = False
    skipped: bool = False             # conditional edge: never submitted
    result: dict | None = None


class _CampaignRun:
    def __init__(self, campaign_id: str, spec: PipelineSpec,
                 items: list, params: dict, weight: float = 1.0):
        self.campaign_id = campaign_id
        self.spec = spec
        self.items = items
        self.params = params
        self.weight = weight
        self.status = CampaignStatus(campaign_id=campaign_id,
                                     pipeline=spec.name)
        expected = spec.expected_counts(len(items))
        for st in spec.topological():
            self.status.stages[st.name] = StageStatus(
                name=st.name, script=st.script, expected=expected[st.name])
        self.tasks: dict[str, _PTask] = {}
        self.by_stage: dict[str, list[str]] = {n: [] for n in spec.stages}
        self.ready: dict[str, deque[str]] = {n: deque() for n in spec.stages}
        self.joins_fired: set[str] = set()
        self.completion = threading.Event()
        self.last_publish = 0.0

    def stage_complete(self, name: str) -> bool:
        return self.status.stages[name].complete


class PipelineAgent:
    """Subscribes to ``-done``/``-error`` and advances registered campaigns.

    Multiple campaigns (even over different :class:`PipelineSpec`\\ s) can run
    concurrently through one agent; tasks from campaigns this agent does not
    own are ignored (unknown task_id), so several pipeline agents can share a
    prefix the way several MonitorAgents can (§3).
    """

    def __init__(self, broker: Broker, prefix: str = "ksa", *,
                 agent_id: str | None = None,
                 poll_interval_s: float = 0.02,
                 default_task_timeout_s: float | None = None,
                 publish_interval_s: float = 0.25,
                 retain_finished: int | None = 32,
                 placement: PlacementPolicy | None = None,
                 lease: LeasePolicy | None = None,
                 max_in_flight_total: int | None = None):
        self.broker = broker
        self.prefix = prefix
        self.topics = topic_names(prefix)
        self.agent_id = agent_id or f"pipeline-{id(self) & 0xffff:04x}"
        self.poll_interval_s = poll_interval_s
        self.default_task_timeout_s = default_task_timeout_s
        self.publish_interval_s = publish_interval_s
        # long-lived agents serve a stream of campaigns; keep only the most
        # recent `retain_finished` finished runs (None = keep all).
        self.retain_finished = retain_finished
        # how concurrent campaigns share `-new` capacity: FairShare weighted
        # round-robin by default; max_in_flight_total optionally bounds the
        # agent-wide number of outstanding tasks (None = per-stage bounds
        # only, matching the pre-lease behaviour).
        self._lease = lease or FairShare()
        self.max_in_flight_total = max_in_flight_total
        self._submitter = Submitter(broker, prefix, placement=placement)
        self._producer = Producer(broker)
        gid = f"{prefix}-pipeline-{self.agent_id}"
        self._consumer = Consumer(
            broker, [self.topics["done"], self.topics["error"]],
            group_id=gid, member_id=f"{gid}-member")
        self._campaigns: dict[str, _CampaignRun] = {}
        self._task_index: dict[str, str] = {}  # task_id -> campaign_id
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # -- campaign submission -------------------------------------------------

    def submit_campaign(self, spec: PipelineSpec, items: Iterable | None = None,
                        *, params: Mapping[str, Any] | None = None,
                        campaign_id: str | None = None,
                        weight: float = 1.0) -> str:
        """Plan a campaign and submit its source-stage tasks. Returns the
        campaign id; progress via :meth:`status`, blocking via :meth:`wait`.
        ``weight`` sets this campaign's share of `-new` capacity under the
        agent's lease policy (FairShare: a weight-3 campaign drains three
        ready tasks for every one of a weight-1 peer)."""
        if weight <= 0:
            raise PipelineError(f"campaign weight must be positive ({weight})")
        # fail fast on unroutable stage resources (e.g. a label naming no
        # class) — raising here beats stalling mid-campaign in the loop
        for st in spec.topological():
            probe = TaskMessage(task_id=f"probe-{st.name}", script=st.script,
                                resources=st.resources)
            try:
                self._submitter.placement.route(self.prefix, probe)
            except ValueError as exc:
                raise PipelineError(
                    f"stage {st.name!r} is unroutable: {exc}") from exc
        items = list(items) if items is not None else []
        cid = campaign_id or new_task_id(f"camp-{spec.name}")
        with self._lock:
            if cid in self._campaigns:
                raise PipelineError(f"campaign {cid!r} already exists")
            run = _CampaignRun(cid, spec, items, dict(params or {}),
                               weight=weight)
            self._campaigns[cid] = run
            for st in spec.sources():
                if st.fan_out is None:
                    batches = [items]
                else:
                    batches = [items[i:i + st.fan_out]
                               for i in range(0, len(items), st.fan_out)] \
                        or [[]]
                for bi, batch in enumerate(batches):
                    self._plan_task(run, st, {"batch": list(batch),
                                              "batch_index": bi}, [])
            self._pump_all()
            self._publish(run, force=True)
        return cid

    def _plan_task(self, run: _CampaignRun, st: Stage,
                   extra: Mapping[str, Any], dep_ids: list) -> None:
        idx = len(run.by_stage[st.name])
        task = TaskMessage(
            task_id=f"{run.campaign_id}-{st.name}-{idx:05d}",
            script=st.script,
            params={**run.params, **dict(st.params), **dict(extra)},
            resources=st.resources,
            timeout_s=st.timeout_s,
            campaign_id=run.campaign_id,
            stage=st.name,
            dep_ids=list(dep_ids),
        )
        pt = _PTask(stage=st.name, task=task, index=idx)
        run.tasks[task.task_id] = pt
        run.by_stage[st.name].append(task.task_id)
        run.ready[st.name].append(task.task_id)
        self._task_index[task.task_id] = run.campaign_id

    def _plan_skip(self, run: _CampaignRun, st: Stage) -> None:
        """Conditional edge: record a task as skipped (never submitted) and
        cascade — its own downstream map tasks are skipped too, and join
        barriers treat it as complete-with-no-result."""
        idx = len(run.by_stage[st.name])
        task = TaskMessage(
            task_id=f"{run.campaign_id}-{st.name}-{idx:05d}",
            script=st.script, campaign_id=run.campaign_id, stage=st.name)
        pt = _PTask(stage=st.name, task=task, index=idx, skipped=True)
        run.tasks[task.task_id] = pt
        run.by_stage[st.name].append(task.task_id)
        self._task_index[task.task_id] = run.campaign_id
        run.status.stages[st.name].skipped += 1
        self._advance(run, pt)

    # -- backpressure / fair-share pump ---------------------------------------

    def _next_stage(self, run: _CampaignRun) -> Stage | None:
        """The first stage (topological order) with a ready task that fits
        under its ``max_in_flight`` bound, or None."""
        for st in run.spec.topological():
            if not run.ready[st.name]:
                continue
            bound = st.max_in_flight
            if bound is None or run.status.stages[st.name].in_flight < bound:
                return st
        return None

    def _pump_all(self) -> None:
        """Drain ready queues into ``-new`` capacity, one task at a time;
        the lease policy picks which campaign goes next (FairShare weighted
        round-robin by default). ``max_in_flight_total`` bounds the agent's
        outstanding tasks across all campaigns. Call with the lock held.

        The candidate set and the outstanding count are computed once and
        maintained incrementally: the lock is held throughout, so no other
        thread can make a campaign submittable mid-drain — candidates only
        ever shrink. This keeps a paper-scale fan-out (tens of thousands of
        source tasks) O(tasks), not O(tasks × campaigns × stages)."""
        outstanding = 0
        if self.max_in_flight_total is not None:
            outstanding = sum(
                ss.in_flight
                for r in self._campaigns.values() if not r.status.done
                for ss in r.status.stages.values())
        candidates = {cid: r.weight for cid, r in self._campaigns.items()
                      if not r.status.done
                      and self._next_stage(r) is not None}
        while candidates:
            if self.max_in_flight_total is not None \
                    and outstanding >= self.max_in_flight_total:
                return
            cid = self._lease.select(candidates)
            run = self._campaigns[cid]
            st = self._next_stage(run)
            if st is None:  # safety net; normally pruned after submit
                del candidates[cid]
                continue
            tid = run.ready[st.name].popleft()
            pt = run.tasks[tid]
            pt.attempts += 1
            pt.last_submit = time.time()
            run.status.stages[st.name].submitted += 1
            self._submitter.submit_task(pt.task)
            outstanding += 1
            if self._next_stage(run) is None:
                del candidates[cid]

    # -- ingestion -------------------------------------------------------------

    def _ingest(self, topic: str, value: dict) -> None:
        if topic == self.topics["done"]:
            res = ResultMessage.from_dict(value)
            self._on_result(res)
        elif topic == self.topics["error"]:
            err = ErrorMessage.from_dict(value)
            self._on_error(err)

    def _on_result(self, res: ResultMessage) -> None:
        with self._lock:
            cid = self._task_index.get(res.task_id)
            if cid is None:
                return  # not one of ours (flat task or another agent's)
            run = self._campaigns[cid]
            pt = run.tasks[res.task_id]
            ss = run.status.stages[pt.stage]
            if pt.done or pt.failed or pt.skipped or run.status.done:
                # fencing: duplicate results, late results for retry-exhausted
                # tasks, and stragglers of an already-failed campaign never
                # advance the DAG (a FAILED verdict must stay final).
                ss.duplicates += 1
                return
            pt.done = True
            pt.result = res.result
            ss.done += 1
            self._advance(run, pt)
            self._pump_all()
            self._check_complete(run)
            self._publish(run)

    def _advance(self, run: _CampaignRun, pt: _PTask) -> None:
        for ds in run.spec.downstream(pt.stage):
            if not ds.join:
                if pt.skipped or (ds.skip_when is not None
                                  and ds.skip_when(pt.result)):
                    self._plan_skip(run, ds)
                else:
                    self._plan_task(run, ds,
                                    {"upstream": pt.result,
                                     "dep_index": pt.index},
                                    [pt.task.task_id])
            elif ds.name not in run.joins_fired and \
                    all(run.stage_complete(d) for d in ds.depends_on):
                run.joins_fired.add(ds.name)
                upstream: dict[str, list] = {}
                dep_ids: list[str] = []
                for dep in ds.depends_on:
                    live = [t for t in run.by_stage[dep]
                            if not run.tasks[t].skipped]
                    upstream[dep] = [run.tasks[t].result for t in live]
                    dep_ids.extend(live)
                if ds.skip_when is not None and ds.skip_when(upstream):
                    self._plan_skip(run, ds)
                else:
                    self._plan_task(run, ds, {"upstream": upstream}, dep_ids)

    def _on_error(self, err: ErrorMessage) -> None:
        with self._lock:
            cid = self._task_index.get(err.task_id)
            if cid is None:
                return
            run = self._campaigns[cid]
            pt = run.tasks[err.task_id]
            if pt.done or pt.failed or pt.skipped:
                return
            if err.attempt < pt.task.attempt:
                return  # fenced: an older attempt failing after a resubmit
            run.status.stages[pt.stage].errors += 1
            self._retry_or_fail(run, pt, reason=f"error: {err.error}")

    # -- watchdog / retries ------------------------------------------------------

    def _retry_or_fail(self, run: _CampaignRun, pt: _PTask,
                       reason: str) -> None:
        st = run.spec.stages[pt.stage]
        ss = run.status.stages[pt.stage]
        if pt.attempts < st.retry.max_attempts:
            pt.task = pt.task.retry()
            pt.attempts += 1
            pt.last_submit = time.time()
            ss.retried += 1
            self._submitter.submit_task(pt.task)
            log.info("campaign %s: resubmitted %s (attempt %d, %s)",
                     run.campaign_id, pt.task.task_id, pt.task.attempt,
                     reason)
        else:
            pt.failed = True
            ss.failed += 1
            run.status.state = CampaignState.FAILED
            run.status.failure = (f"stage {pt.stage!r} task "
                                  f"{pt.task.task_id} exhausted "
                                  f"{st.retry.max_attempts} attempts "
                                  f"({reason})")
            run.status.finished_at = time.time()
            run.completion.set()
            self._publish(run, force=True)
            log.warning("campaign %s FAILED: %s",
                        run.campaign_id, run.status.failure)
            self._evict_finished()

    def _watchdog(self) -> None:
        now = time.time()
        with self._lock:
            for run in self._campaigns.values():
                if run.status.done:
                    continue
                for st in run.spec.topological():
                    timeout = st.retry.timeout_s or self.default_task_timeout_s
                    if timeout is None:
                        continue
                    for tid in run.by_stage[st.name]:
                        pt = run.tasks[tid]
                        if pt.done or pt.failed or pt.skipped \
                                or pt.attempts == 0:
                            continue
                        if now - pt.last_submit > timeout:
                            self._retry_or_fail(
                                run, pt,
                                reason=f"no result after {timeout:.1f}s")
                        if run.status.done:
                            return

    def _check_complete(self, run: _CampaignRun) -> None:
        if run.status.done:
            return
        if all(run.stage_complete(n) for n in run.spec.stages):
            run.status.state = CampaignState.COMPLETED
            run.status.finished_at = time.time()
            run.completion.set()
            self._publish(run, force=True)
            self._evict_finished()

    def _evict_finished(self) -> None:
        """Drop the oldest finished campaigns beyond ``retain_finished`` so a
        resident agent serving a campaign stream doesn't grow without bound.
        Callers must fetch results before the run ages out of the window."""
        if self.retain_finished is None:
            return
        finished = sorted((r for r in self._campaigns.values()
                           if r.status.done),
                          key=lambda r: r.status.finished_at or 0.0)
        for run in finished[:max(0, len(finished) - self.retain_finished)]:
            self.forget(run.campaign_id)

    def forget(self, campaign_id: str) -> None:
        """Release a finished campaign's task table and results."""
        with self._lock:
            run = self._campaigns.get(campaign_id)
            if run is None or not run.status.done:
                return
            for tid in run.tasks:
                self._task_index.pop(tid, None)
            del self._campaigns[campaign_id]
            self._lease.forget(campaign_id)

    # -- progress publishing (PREFIX-campaigns) -----------------------------------

    def _publish(self, run: _CampaignRun, force: bool = False) -> None:
        now = time.time()
        if not force and now - run.last_publish < self.publish_interval_s:
            return
        run.last_publish = now
        ev = CampaignEvent(
            campaign_id=run.campaign_id, pipeline=run.spec.name,
            state=run.status.state, agent_id=self.agent_id,
            stages={n: s.to_dict() for n, s in run.status.stages.items()})
        self._producer.send(self.topics["campaigns"], ev.to_dict(),
                            key=run.campaign_id)

    # -- queries -----------------------------------------------------------------

    def status(self, campaign_id: str) -> CampaignStatus:
        with self._lock:
            return self._campaigns[campaign_id].status

    def campaigns(self) -> dict[str, CampaignStatus]:
        with self._lock:
            return {c: r.status for c, r in self._campaigns.items()}

    def wait(self, campaign_id: str, timeout: float = 60.0) -> CampaignStatus:
        with self._lock:
            run = self._campaigns[campaign_id]
        run.completion.wait(timeout)
        return run.status

    def results(self, campaign_id: str) -> dict[str, list]:
        """Per-stage results in task-creation order (completed tasks only)."""
        with self._lock:
            run = self._campaigns[campaign_id]
            return {
                n: [run.tasks[t].result for t in tids
                    if run.tasks[t].result is not None]
                for n, tids in run.by_stage.items()
            }

    def final_result(self, campaign_id: str) -> Any:
        """The joined result: for a single-task terminal stage (the usual
        join barrier) the result dict itself, else {stage: [results...]}."""
        with self._lock:
            run = self._campaigns[campaign_id]
            terms = run.spec.terminals()
            if len(terms) == 1 and len(run.by_stage[terms[0].name]) == 1:
                tid = run.by_stage[terms[0].name][0]
                return run.tasks[tid].result
            return {t.name: [run.tasks[tid].result
                             for tid in run.by_stage[t.name]]
                    for t in terms}

    def stats(self) -> dict:
        with self._lock:
            return {
                "agent_id": self.agent_id,
                "campaigns": len(self._campaigns),
                "running": sum(1 for r in self._campaigns.values()
                               if not r.status.done),
                "lease": type(self._lease).__name__,
                "weights": {c: r.weight for c, r in self._campaigns.items()
                            if not r.status.done},
            }

    # -- main loop ------------------------------------------------------------------

    def start(self) -> "PipelineAgent":
        self._thread = threading.Thread(target=self._loop,
                                        name=f"{self.agent_id}-loop",
                                        daemon=True)
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                batches = self._consumer.poll(timeout=self.poll_interval_s)
                for tp, recs in batches.items():
                    for rec in recs:
                        self._ingest(tp.topic, rec.value)
                if batches:
                    self._consumer.commit()
                self._watchdog()
                with self._lock:
                    self._pump_all()
            except Exception:  # pragma: no cover - defensive
                log.exception("pipeline agent %s loop error", self.agent_id)
                time.sleep(self.poll_interval_s)
        self._consumer.close()

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
