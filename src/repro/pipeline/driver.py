"""Synchronous campaign front-end.

``run_campaign`` is the pipeline analogue of the paper's submit-then-wait
scripts (§5): it spins a :class:`PipelineAgent` (or reuses one), submits the
campaign, streams progress to a callback, and returns the joined final result
once the DAG has drained. Worker/Cluster/Monitor agents are expected to be
running against the same broker+prefix — the driver orchestrates, it does not
execute.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Mapping

from repro.core.broker import Broker

from .agent import PipelineAgent, PipelineError
from .spec import PipelineSpec
from .state import CampaignState
from .status import CampaignStatus


@dataclasses.dataclass
class CampaignResult:
    campaign_id: str
    status: CampaignStatus
    results: dict[str, list]   # per-stage results, task-creation order
    final: Any                 # the terminal (usually join) stage's result
    elapsed_s: float


def run_campaign(spec: PipelineSpec, items: Iterable | None = None, *,
                 broker: Broker, prefix: str = "ksa",
                 params: Mapping[str, Any] | None = None,
                 agent: PipelineAgent | None = None,
                 default_task_timeout_s: float | None = None,
                 placement: Any = None,
                 weight: float = 1.0,
                 progress: Callable[[CampaignStatus], None] | None = None,
                 progress_interval_s: float = 0.25,
                 timeout_s: float = 600.0) -> CampaignResult:
    """Run one campaign to completion and return its joined result.

    Raises :class:`PipelineError` if the campaign fails (a stage exhausted its
    retry budget) and :class:`TimeoutError` if it does not finish in
    ``timeout_s``. ``placement`` routes stage tasks to resource-class topics
    (defaults to the standard cpu/gpu split); ``weight`` is the campaign's
    fair-share weight when the agent serves several campaigns at once.
    """
    own_agent = agent is None
    if own_agent:
        agent = PipelineAgent(
            broker, prefix, placement=placement,
            default_task_timeout_s=default_task_timeout_s).start()
    try:
        t0 = time.time()
        cid = agent.submit_campaign(spec, items, params=params, weight=weight)
        deadline = t0 + timeout_s
        while True:
            st = agent.wait(cid, timeout=progress_interval_s)
            if progress is not None:
                progress(st)
            if st.done:
                break
            if time.time() > deadline:
                raise TimeoutError(
                    f"campaign {cid} did not finish in {timeout_s:.0f}s "
                    f"(progress {st.progress():.0%})")
        if st.state == CampaignState.FAILED:
            raise PipelineError(f"campaign {cid} failed: {st.failure}")
        return CampaignResult(
            campaign_id=cid,
            status=st,
            results=agent.results(cid),
            final=agent.final_result(cid),
            elapsed_s=time.time() - t0,
        )
    finally:
        if own_agent:
            agent.stop()
