"""Declarative DAG campaign specifications.

A :class:`PipelineSpec` is a directed acyclic graph of :class:`Stage`\\ s, each
naming a registered ``ClusterComputing`` script. Three stage shapes cover the
paper's campaign patterns (§4) and the ParaFold/Summit decompositions the
pipeline subsystem is modeled on:

* **source** (no ``depends_on``): seeded from the campaign's input items,
  optionally fanned out into batches of ``fan_out`` items (the paper's
  "batches of 4,000 structures, each batch submitted as a single task"),
* **map** (one dependency, ``join=False``): one downstream task per completed
  upstream task; the upstream result rides along as ``params["upstream"]``,
* **join** (``join=True``, one or more dependencies): a fan-in barrier — fires
  exactly one task once *every* task of every upstream stage has a result,
  with ``params["upstream"] = {stage_name: [results...]}``.

Per-stage :class:`~repro.core.messages.Resources` route heterogeneous stages
to differently-equipped pools (ParaFold's CPU-featurize vs GPU-predict split);
:class:`RetryPolicy` bounds attempts and sets the watchdog timeout;
``max_in_flight`` bounds concurrent tasks per stage (backpressure).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterable, Mapping, Sequence

from repro.core.messages import Resources


class SpecError(ValueError):
    """Raised when a PipelineSpec is malformed (cycle, bad dep, ...)."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Per-stage fault-tolerance knobs.

    ``max_attempts`` counts total submissions of one task (initial + retries);
    ``timeout_s`` is the pipeline agent's per-task watchdog — a task with no
    result after this long is resubmitted with a bumped attempt (straggler
    mitigation; duplicate results are fenced downstream).

    ``max_preemptions`` opts the campaign into **preemptive fair share**: how
    many times the lease policy may revoke one of the campaign's running
    leases (``Broker.revoke_lease(reason="preempt")``, journaled as
    ``LeaseRevoked``) to hand the slot to a starved peer. The bound is
    per *campaign* — the effective cap is the maximum over its stages —
    and preemptions do **not** consume the ``max_attempts`` retry budget
    (a requeue is not a failure). 0 (the default) disables preemption of
    this campaign entirely."""

    max_attempts: int = 3
    timeout_s: float | None = None
    max_preemptions: int = 0


@dataclasses.dataclass(frozen=True)
class Stage:
    name: str
    script: str
    depends_on: tuple[str, ...] = ()
    join: bool = False
    fan_out: int | None = None        # source stages: items per task
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    resources: Resources = dataclasses.field(default_factory=Resources)
    max_in_flight: int | None = None  # backpressure bound (None = unbounded)
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    timeout_s: float | None = None    # per-task execution cancel (agent-side)
    # conditional edge / early-exit (ROADMAP): when the predicate holds on
    # the upstream result, the task is *skipped* instead of submitted — the
    # stage (and the campaign) completes with the skip counted, never FAILED.
    # Map stages: called with the one upstream task's result dict. Join
    # stages: called with the assembled {stage: [results...]} mapping
    # (skipped upstream tasks contribute no entry). Skips cascade: a map
    # task downstream of a skipped task is itself skipped.
    skip_when: Callable[[Any], bool] | None = None

    def __post_init__(self) -> None:
        if isinstance(self.depends_on, str):  # common foot-gun
            object.__setattr__(self, "depends_on", (self.depends_on,))
        else:
            object.__setattr__(self, "depends_on", tuple(self.depends_on))
        if self.join and not self.depends_on:
            raise SpecError(f"join stage {self.name!r} needs dependencies")
        if not self.join and len(self.depends_on) > 1:
            raise SpecError(
                f"map stage {self.name!r} may have exactly one dependency "
                f"(got {self.depends_on}); use join=True to fan in")
        if self.fan_out is not None:
            if self.depends_on:
                raise SpecError(
                    f"fan_out is only valid on source stages ({self.name!r})")
            if self.fan_out <= 0:
                raise SpecError(f"fan_out must be positive ({self.name!r})")
        if self.max_in_flight is not None and self.max_in_flight <= 0:
            raise SpecError(f"max_in_flight must be positive ({self.name!r})")
        if self.skip_when is not None and self.is_source:
            raise SpecError(
                f"skip_when needs an upstream result ({self.name!r} is a "
                f"source stage)")

    @property
    def is_source(self) -> bool:
        return not self.depends_on


class PipelineSpec:
    """A validated DAG of stages with helpers the agent plans from."""

    def __init__(self, name: str, stages: Sequence[Stage]):
        self.name = name
        self.stages: dict[str, Stage] = {}
        for st in stages:
            if st.name in self.stages:
                raise SpecError(f"duplicate stage name {st.name!r}")
            self.stages[st.name] = st
        if not self.stages:
            raise SpecError("pipeline has no stages")
        for st in self.stages.values():
            for dep in st.depends_on:
                if dep not in self.stages:
                    raise SpecError(
                        f"stage {st.name!r} depends on unknown stage {dep!r}")
        self._order = self._toposort()
        if not any(st.is_source for st in self.stages.values()):
            raise SpecError("pipeline has no source stage")

    def _toposort(self) -> list[str]:
        indeg = {n: len(st.depends_on) for n, st in self.stages.items()}
        ready = sorted(n for n, d in indeg.items() if d == 0)
        order: list[str] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            for m, st in self.stages.items():
                if n in st.depends_on:
                    indeg[m] -= 1
                    if indeg[m] == 0:
                        ready.append(m)
        if len(order) != len(self.stages):
            cyclic = sorted(set(self.stages) - set(order))
            raise SpecError(f"pipeline has a cycle through {cyclic}")
        return order

    # -- planning helpers ---------------------------------------------------

    def topological(self) -> list[Stage]:
        return [self.stages[n] for n in self._order]

    def sources(self) -> list[Stage]:
        return [st for st in self.topological() if st.is_source]

    def downstream(self, name: str) -> list[Stage]:
        return [st for st in self.topological() if name in st.depends_on]

    def terminals(self) -> list[Stage]:
        consumed = {d for st in self.stages.values() for d in st.depends_on}
        return [st for st in self.topological() if st.name not in consumed]

    def expected_counts(self, n_items: int) -> dict[str, int]:
        """Tasks per stage for a campaign over ``n_items`` input items —
        fully determined up front: source = #batches, map = its upstream's
        count (1:1), join = 1."""
        out: dict[str, int] = {}
        for st in self.topological():
            if st.is_source:
                if st.fan_out is None:
                    out[st.name] = 1
                else:
                    out[st.name] = max(1, math.ceil(n_items / st.fan_out))
            elif st.join:
                out[st.name] = 1
            else:
                out[st.name] = out[st.depends_on[0]]
        return out

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "stages": [
                {
                    "name": st.name, "script": st.script,
                    "depends_on": list(st.depends_on), "join": st.join,
                    "fan_out": st.fan_out,
                    "max_in_flight": st.max_in_flight,
                    "resources": st.resources.to_dict(),
                    "retry": dataclasses.asdict(st.retry),
                    "conditional": st.skip_when is not None,
                }
                for st in self.topological()
            ],
        }
