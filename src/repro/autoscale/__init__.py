"""repro.autoscale — backlog-driven elastic pool scaling with graceful drain.

The paper runs statically provisioned agents (§4: one ClusterAgent per
cluster, sized by hand), so a bursty campaign leaves the GPU pool idle while
the CPU screen stage backlogs — the utilization gap ParaFold
(arXiv:2111.06340) closes by splitting CPU/GPU phases and APACE
(arXiv:2308.07954) closes by provisioning AlphaFold elastically. This
subsystem closes it inside the KSA control plane:

* **sense** — per-resource-class queue depth and drain rate from
  :meth:`repro.core.broker.Broker.queue_stats` (incremental counters on the
  produce/commit paths; no record scans);
* **decide** — a pluggable, *pure* :class:`~repro.autoscale.policy.ScalingPolicy`;
  the default :class:`~repro.autoscale.policy.TargetBacklogPolicy` targets a
  backlog-per-slot with hysteresis, cooldowns, min/max bounds, and
  scale-to-zero for tainted pools;
* **act** — :class:`~repro.autoscale.controller.AutoscaleController` grows
  pools through :class:`~repro.cluster.KsaCluster` (``add_worker`` /
  ``add_slurm``, including SimSlurm node spin-up latency as a visible cold
  start) and shrinks them through the agents' graceful drain
  (:meth:`~repro.core.agents.AgentBase.request_drain`): subscriptions stop,
  deferred leases are requeued, in-flight tasks finish, then the agent
  deregisters — no task lost, none double-run.

Usage through the facade::

    from repro.autoscale import AutoscaleConfig, PoolSpec

    cfg = AutoscaleConfig(pools=(
        PoolSpec("cpu", min_agents=1, max_agents=4, slots=2),
        PoolSpec("gpu", min_agents=0, max_agents=4, slots=1),
    ))
    with KsaCluster(autoscale=cfg) as c:
        c.run_campaign(spec, items)     # pools follow the backlog
        print(c.autoscaler.status())    # also on GET /autoscale (http=True)
"""
from .controller import AutoscaleController
from .policy import (AutoscaleConfig, AutoscaleError, PoolSignal, PoolSpec,
                     ScalingPolicy, TargetBacklogPolicy)
from .rate import RateTracker

__all__ = [
    "AutoscaleConfig", "AutoscaleController", "AutoscaleError", "PoolSignal",
    "PoolSpec", "RateTracker", "ScalingPolicy", "TargetBacklogPolicy",
]
