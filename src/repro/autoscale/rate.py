"""RateTracker — windowed rate estimation over a monotonic counter.

The autoscale controller estimates each pool's drain rate from successive
``Broker.queue_stats`` ``consumed`` samples; the federation spillover
controller needs the identical estimate to decide whether a site's backlog
outruns its local drain capacity (time-to-drain = depth / rate). This is
that shared primitive, extracted so both control loops sample and read the
same way: append ``(ts, counter)`` pairs, read the slope over a trailing
window.

Not thread-safe on its own — both controllers sample from a single control
loop thread (or under their own lock).
"""
from __future__ import annotations

from collections import deque

__all__ = ["RateTracker"]


class RateTracker:
    """Sliding-window rate over a cumulative counter.

    ``sample(ts, value)`` appends an observation; ``rate(now)`` returns the
    per-second slope between the oldest sample inside ``window_s`` and the
    newest sample, or 0.0 when fewer than two usable samples exist (cold
    start, or the counter stalled at one timestamp). A monotonic counter
    therefore reads as ≥ 0; a counter reset reads as a transient 0/negative
    until the window refills, which both callers treat as "no drain".
    """

    __slots__ = ("window_s", "_samples")

    def __init__(self, window_s: float, history: int = 512) -> None:
        self.window_s = window_s
        self._samples: deque[tuple[float, float]] = deque(maxlen=history)

    def sample(self, ts: float, value: float) -> None:
        self._samples.append((ts, value))

    def rate(self, now: float) -> float:
        if not self._samples:
            return 0.0
        old = None
        for ts, value in self._samples:
            if now - ts <= self.window_s:
                old = (ts, value)
                break
        new = self._samples[-1]
        if old is None or new[0] <= old[0]:
            return 0.0
        return (new[1] - old[1]) / (new[0] - old[0])

    def __len__(self) -> int:
        return len(self._samples)
